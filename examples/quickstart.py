"""Quickstart: the paper in 60 seconds.

Runs matrix factorization on the simulated parameter server under BSP,
lazy SSP and ESSP, and prints the two headline results:
 1. the staleness (clock-differential) distributions (paper Fig 1-left),
 2. convergence per clock (paper Fig 2).

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import bsp, essp, simulate, ssp, staleness

app = make_mf_app(MFConfig())
T, s = 150, 5

print(f"MF-SGD on the PS simulator: {app.n_workers} workers, "
      f"dim={app.dim}, staleness bound s={s}\n")

for name, cfg in [("BSP ", bsp()), (f"SSP({s})", ssp(s)),
                  (f"ESSP({s})", essp(s))]:
    tr = jax.jit(lambda c=cfg: simulate(app, c, T))()
    bins, probs = staleness.histogram(tr, lo=-(s + 2))
    bar = " ".join(f"{b}:{p:.2f}"
                   for b, p in zip(bins, probs, strict=True) if p > 0.005)
    loss = np.asarray(tr.loss_ref)
    print(f"{name}  loss {loss[0]:.4f} -> {loss[T//2]:.4f} -> {loss[-1]:.4f}")
    print(f"      staleness histogram  {bar}\n")

print("expected: SSP ~uniform over the window, ESSP concentrated at -1,")
print("ESSP converging at BSP-like speed per clock.")
