"""Batched serving demo: prefill + KV-cache decode on a reduced config.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
