"""End-to-end training driver (deliverable b): train a ~100M-param dense
model for a few hundred steps on synthetic data, on CPU.

Default is sized so a few hundred steps finish in tens of minutes on one
CPU core; --preset tiny runs in ~1 minute for CI.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax

from repro.configs.base import AttnConfig, ModelConfig
from repro.data.synthetic import TokenGenConfig, token_batches
from repro.models.registry import build_model
from repro.optim.optimizers import adamw, cosine_schedule
from repro.psdist.grad_sync import GradSync
from repro.train.loop import train
from repro.train.state import init_state, make_train_step

PRESETS = {
    # ~115M params
    "100m": ModelConfig(name="e2e-100m", family="dense", n_layers=10,
                        d_model=768, d_ff=2304, vocab_size=50304,
                        attn=AttnConfig(n_heads=12, n_kv_heads=4,
                                        head_dim=64),
                        tie_embeddings=True, remat=False),
    # ~8M params, for CI
    "tiny": ModelConfig(name="e2e-tiny", family="dense", n_layers=4,
                        d_model=256, d_ff=768, vocab_size=4096,
                        attn=AttnConfig(n_heads=4, n_kv_heads=2,
                                        head_dim=64),
                        tie_embeddings=True, remat=False),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--staleness", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params/1e6:.1f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")

    opt = adamw(cosine_schedule(args.lr, args.steps // 10, args.steps))
    sync = GradSync(args.consistency, args.staleness)
    state = init_state(model, opt, sync, jax.random.PRNGKey(0))
    step = make_train_step(model, opt, sync)
    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch=args.batch)
    state, history = train(step, state, token_batches(dcfg, args.steps),
                           args.steps, log_every=20)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'CONVERGING' if last < 0.7 * first else 'check setup'})")
    return history


if __name__ == "__main__":
    main()
