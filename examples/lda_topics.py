"""Distributed LDA via collapsed Gibbs on the PS (the paper's 2nd app).

Trains on a synthetic corpus with known topics and shows that the stale
(ESSP) sampler recovers topic structure: per-topic top words align with the
generating topics.

    PYTHONPATH=src python examples/lda_topics.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.apps.lda import LDAConfig, make_lda_app
from repro.core import essp, simulate

cfg = LDAConfig(n_docs=64, doc_len=96, vocab=200, n_topics=10)
app = make_lda_app(cfg)
print(f"LDA: {cfg.n_docs} docs x {cfg.doc_len} tokens, V={cfg.vocab}, "
      f"K={cfg.n_topics}, {cfg.n_workers} workers, ESSP(3)\n")

tr = jax.jit(lambda: simulate(app, essp(3), 120))()
nll = np.asarray(tr.loss_ref)
print(f"predictive NLL per token: {nll[0]:.3f} -> {nll[len(nll)//2]:.3f} "
      f"-> {nll[-1]:.3f}\n")

nkw = np.asarray(tr.x_final).reshape(cfg.n_topics, cfg.vocab)
print("top-8 words per learned topic:")
for k in range(cfg.n_topics):
    top = np.argsort(-nkw[k])[:8]
    print(f"  topic {k:2d}: {top.tolist()}")
