"""Consistency-model comparison incl. VAP and the robustness experiment.

Reproduces, at laptop scale: Fig 2 (convergence), the staleness-robustness
result (C3) and the VAP impracticality argument (forced synchronization
explodes as the value bound tightens).

    PYTHONPATH=src python examples/consistency_comparison.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import essp, simulate, ssp, vap
from repro.core.timemodel import TimeModel

# --- robustness to staleness (aggressive step size) -----------------------
app_hot = make_mf_app(MFConfig(lr=1.4))
print("=== robustness: final loss at aggressive lr (C3) ===")
print(f"{'s':>4s} {'SSP':>10s} {'ESSP':>10s}")
for s in (0, 3, 7, 15):
    row = []
    for mk in (ssp, essp):
        tr = jax.jit(lambda c=mk(s): simulate(app_hot, c, 150))()
        row.append(float(np.mean(np.asarray(tr.loss_ref)[-20:])))
    print(f"{s:4d} {row[0]:10.4f} {row[1]:10.4f}")

# --- VAP: value bound vs forced synchronization ----------------------------
app = make_mf_app(MFConfig())
print("\n=== VAP: forced synchronous deliveries per clock (C5) ===")
for v0 in (1.0, 0.1, 0.01):
    tr = jax.jit(lambda v=v0: simulate(app, vap(v, staleness=6), 80))()
    print(f"v0={v0:5.2f}: {np.asarray(tr.forced).sum()/80:6.1f} forced/clock"
          f"   (P*(P-1)={app.n_workers*(app.n_workers-1)} would be full sync)")

# --- wall-clock model (Fig 1-right / Fig 2 time axis) ----------------------
tm = TimeModel()
print("\n=== modeled comm/comp split at s=5 (C6) ===")
for name, cfg, kind in [("SSP", ssp(5), "ssp"), ("ESSP", essp(5), "essp")]:
    tr = jax.jit(lambda c=cfg: simulate(app, c, 150))()
    br = tm.breakdown(tr, kind)
    print(f"{name}: total {br['total_s']:6.1f}s   comm share "
          f"{100*br['comm_frac']:5.1f}%")
