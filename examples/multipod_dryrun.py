"""Lower + compile one (arch x shape) on the production meshes and print
its roofline terms — the per-pair version of the full dry-run sweep.

    PYTHONPATH=src python examples/multipod_dryrun.py qwen3-0.6b decode_32k
"""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).parent.parent
arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-0.6b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

for extra in ([], ["--multi-pod"]):
    subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape] + extra,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
        cwd=ROOT, check=True)
