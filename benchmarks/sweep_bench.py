"""Batched sweep engine vs the sequential per-config loop (compile counts +
wall clock).

The seed implementation ran every paper figure as a Python loop of
``jax.jit(lambda: simulate(app, cfg, T))()`` — one trace + XLA compile per
configuration, because the numeric knobs were baked into the graph as
constants.  The sweep engine compiles one vmapped program per consistency
family and feeds the whole (config × seed) grid through it.

This benchmark measures both paths on the same staleness × seed grid and
reports compile counts (via trace counters) and wall time.  Acceptance
target: >= 3x wall-clock reduction on CPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate, ssp, sweep
from repro.core.ps import PSApp
from repro.core.sweep import family_window, trace_count

from .common import emit, save_json, sweep_meta


def _quad_app(P: int = 8, d: int = 256, eta: float = 0.3) -> PSApp:
    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        step = eta / jnp.sqrt(1.0 + clock)
        return -step * g / P, local

    return PSApp(name="quad", dim=d, n_workers=P, x0=jnp.ones((d,)) * 2.0,
                 local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


def view_profile(T: int = 60, dims=(256, 1024, 4096)):
    """Simulation cost vs parameter dimension ``d`` (ROADMAP d-scaling).

    The ring-view kernel streams d-blocks, so larger apps should be
    *view-bound*: steady-state us/clock ~linear in ``d`` (log-log slope <=
    ~1), not dominated by compile or fixed overheads.  This is the evidence
    behind lifting `MFConfig`'s default rank.
    """
    rows = []
    for d in dims:
        res = sweep(_quad_app(d=d), [ssp(3)], T, seeds=1, timeit=True)
        rows.append({"d": d, "us_per_clock": res.t_exec_s * 1e6 / T,
                     "t_compile_s": res.t_first_s - res.t_exec_s})
        emit(f"sweep_bench/view_profile_d{d}", rows[-1]["us_per_clock"])
    lg = np.log(np.asarray([r["us_per_clock"] for r in rows]))
    ld = np.log(np.asarray([float(r["d"]) for r in rows]))
    slope = float(np.polyfit(ld, lg, 1)[0])
    emit("sweep_bench/view_profile_slope", 0.0, f"loglog_slope={slope:.2f}")
    return {"rows": rows, "loglog_slope": slope,
            "view_bound": bool(slope <= 1.15)}


def run(T: int = 100, n_seeds: int = 2, staleness_grid=None,
        seed0: int = 0):
    if staleness_grid is None:
        staleness_grid = tuple(range(12))
    app = _quad_app()
    configs = [ssp(s) for s in staleness_grid]
    seeds = np.arange(seed0, seed0 + n_seeds)
    # Same harmonized ring window on both paths so the simulated physics
    # (and compiled shapes) are identical; only the batching differs.
    W = family_window(configs)

    # -- sequential: one jit per config (the seed benchmark pattern) -------
    seq_compiles = {"count": 0}

    def run_one(cfg):
        def fn(sd):
            seq_compiles["count"] += 1
            return simulate(app, cfg.replace(window=W), T, seed=sd)
        return jax.jit(fn)

    t0 = time.perf_counter()
    seq_losses = []
    for cfg in configs:
        fn = run_one(cfg)
        for sd in seeds:
            tr = jax.block_until_ready(fn(jnp.uint32(sd)))
            seq_losses.append(np.asarray(tr.loss_ref))
    t_seq = time.perf_counter() - t0

    # -- batched: one compiled program for the whole grid ------------------
    n_before = trace_count()
    t0 = time.perf_counter()
    res = sweep(app, configs, T, seeds=seeds)
    t_batched = time.perf_counter() - t0
    batched_compiles = trace_count() - n_before

    # per-config traces must match the sequential path
    max_err = 0.0
    for i in range(len(configs)):
        for j in range(n_seeds):
            got = np.asarray(res.trace(i, j).loss_ref)
            want = seq_losses[i * n_seeds + j]
            max_err = max(max_err, float(np.abs(got - want).max()))
    assert max_err < 1e-5, f"batched trace diverged: {max_err}"

    speedup = t_seq / max(t_batched, 1e-9)
    out = {
        "n_configs": len(configs), "n_seeds": n_seeds, "T": T,
        "sequential": {"wall_s": t_seq, "compiles": seq_compiles["count"]},
        "batched": {"wall_s": t_batched, "compiles": batched_compiles,
                    **sweep_meta(res)},
        "speedup": speedup, "max_trace_err": max_err,
        "pass_3x": bool(speedup >= 3.0),
        "view_profile": view_profile(),
    }
    emit("sweep_bench/sequential", t_seq * 1e6,
         f"compiles={seq_compiles['count']}")
    emit("sweep_bench/batched", t_batched * 1e6,
         f"compiles={batched_compiles}")
    emit("sweep_bench/speedup", 0.0,
         f"x{speedup:.1f};max_err={max_err:.1e}")
    save_json("sweep_bench", out)
    return out


if __name__ == "__main__":
    r = run()
    print({k: r[k] for k in ("speedup", "pass_3x")},
          r["sequential"], {k: r["batched"][k]
                            for k in ("wall_s", "compiles")})
