"""Observability bench: obs overhead budget + the CI obs smoke lane.

Two measured layers:

**Overhead (``measure_overhead`` / the ``obs_overhead_ok`` claim):** the
telemetry substrate promises its on-device accumulators are cheap enough
to leave on — obs-on must run within 5% of obs-off.  Measured as
interleaved min-of-N on the jitted simulator (min, not median: the
accumulators add *deterministic* device work, so the minimum isolates it
from host noise), with a small absolute slack so a sub-millisecond run
on a fast host cannot trip the ratio on timer jitter.  The record lands
in ``BENCH_obs.json`` via `benchmarks.robustness` (the claim-gated
suite) and standalone runs of this module.

**Smoke (``--smoke``, the CI obs lane):** one short churned 2-pod run on
the 16-worker topology with obs enabled, end to end through the
substrate: Trace bit-identity obs-on vs obs-off, accumulators drained
into a `MetricsRegistry`, the JSONL event stream collected + schema-
validated + round-tripped, the Perfetto export checked for per-worker
lanes and churn outage windows, and the markdown run report rendered.
Artifacts (``obs_events.jsonl`` / ``obs_trace.perfetto.json`` /
``obs_report.md``) land in the results dir for CI upload next to the
``BENCH_*.json`` records.

Standalone (``python -m benchmarks.obs_bench``) forces a 16-device host
platform (the CI obs lane's topology) before jax initializes; under
``benchmarks/run.py`` it runs on whatever topology the process has.
"""
from __future__ import annotations

import os
import sys
import time

# Only the standalone invocation owns the process and may pick its device
# topology; a plain import must never mutate the environment.
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model  # noqa: E402
from repro.core import essp, simulate                       # noqa: E402
from repro.core.consistency import podded                   # noqa: E402
from repro.core.delays import make_churn                    # noqa: E402
from repro.obs import (MetricsRegistry, ObsSpec,            # noqa: E402
                       drain_device, record_compiles, record_timing)
from repro.obs import events as obs_events                  # noqa: E402
from repro.obs import perfetto as obs_perfetto              # noqa: E402
from repro.obs import promtext as obs_promtext              # noqa: E402
from repro.obs import report as obs_report                  # noqa: E402

from . import common                                        # noqa: E402
from .common import emit, save_bench_json, save_json, \
    wire_bound_time_model                                   # noqa: E402

OVERHEAD_BUDGET = 0.05          # obs-on within 5% of obs-off
OVERHEAD_SLACK_S = 2e-3         # absolute jitter floor per run


def measure_overhead(T: int = 120, P: int = 8, reps: int = 5,
                     seed: int = 0) -> dict:
    """Interleaved min-of-N obs-on vs obs-off simulator timing."""
    app = make_mf_app(MFConfig(n_workers=P))
    cfg = essp(2)
    fns = {}
    for name, obs in (("off", None), ("on", ObsSpec())):
        fn = jax.jit(lambda sd, o=obs: simulate(app, cfg, T, seed=sd,
                                                obs=o))
        jax.block_until_ready(fn(np.uint32(seed)))          # compile+warm
        fns[name] = fn
    ts = {"off": [], "on": []}
    for _ in range(reps):
        for name, fn in fns.items():                        # interleaved
            t0 = time.perf_counter()
            jax.block_until_ready(fn(np.uint32(seed)))
            ts[name].append(time.perf_counter() - t0)
    t_off, t_on = min(ts["off"]), min(ts["on"])
    ok = t_on <= t_off * (1.0 + OVERHEAD_BUDGET) + OVERHEAD_SLACK_S
    return {"t_obs_off_s": t_off, "t_obs_on_s": t_on,
            "overhead_ratio": t_on / t_off if t_off > 0 else None,
            "T": T, "P": P, "reps": reps, "ok": bool(ok)}


def bench_obs_record() -> dict:
    """Measure overhead and write the ``BENCH_obs.json`` perf record.

    Called by `benchmarks.robustness.run` (so the ``obs_overhead_ok``
    claim rides the harness claim gate) and by standalone runs here.
    """
    ov = measure_overhead()
    emit("obs/overhead", ov["t_obs_on_s"] * 1e6,
         f"ratio={ov['overhead_ratio']:.3f};ok={ov['ok']}")
    metrics = {"t_obs_off_s": ov["t_obs_off_s"],
               "t_obs_on_s": ov["t_obs_on_s"],
               "overhead_ratio": ov["overhead_ratio"]}
    claim = {"obs_overhead_ok": ov["ok"]}
    save_bench_json("obs", metrics, claim=claim)
    return {"overhead": ov, "metrics": metrics, "claim": claim}


WORKERS, PODS = 16, 2


def smoke(T: int = 24, seed: int = 0) -> dict:
    """The CI obs lane: churned pods run -> validated stream + trace.

    Asserts the acceptance criteria end to end and leaves the JSONL /
    Perfetto / report artifacts in the results dir.  Returns the
    evidence dict.
    """
    from .pods_bench import S_INTRA, S_XPOD, T_NET_XPOD, _runtime_for

    app = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                               n_workers=WORKERS, batch=64, lr=0.5))
    cfg = podded(essp(S_INTRA), PODS, s_xpod=S_XPOD,
                 t_net_xpod=T_NET_XPOD)
    sched = make_churn(T, WORKERS, n_pods=PODS,
                       pod_outages=((1, T // 3, 3 * T // 4),))
    rt = _runtime_for(WORKERS, PODS)
    tm = wire_bound_time_model(app, mf_time_model().t_comp, PODS)

    tr_on = rt.run(app, cfg, T, seed=seed, schedule=sched, obs=ObsSpec())
    tr_off = rt.run(app, cfg, T, seed=seed, schedule=sched)
    ident = all(
        np.array_equal(np.asarray(getattr(tr_on, f)),
                       np.asarray(getattr(tr_off, f)))
        for f in ("staleness", "forced", "delivered", "live", "loss_ref",
                  "ship_floats"))
    assert ident, "obs-on Trace diverged from obs-off (bit-identity)"
    assert tr_on.obs is not None and tr_off.obs is None

    reg = MetricsRegistry()
    drain_device(reg, tr_on.obs)
    record_compiles(reg)
    record_timing(reg, tr_on, cfg.model, tm, fold=(0, seed), cfg=cfg,
                  schedule=sched)

    ev = obs_events.collect_events(tr_on, cfg, tm, schedule=sched,
                                   run="obs-smoke", registry=reg)
    obs_events.validate_events(ev)
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    jsonl = os.path.join(common.RESULTS_DIR, "obs_events.jsonl")
    obs_events.write_jsonl(ev, jsonl)
    assert obs_events.read_jsonl(jsonl) == ev, "JSONL round-trip drifted"

    trace_path = os.path.join(common.RESULTS_DIR, "obs_trace.perfetto.json")
    perf = obs_perfetto.write_trace(ev, trace_path)
    lanes = {e["args"]["name"] for e in perf["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    lanes_ok = ("clocks" in lanes
                and all(f"worker {p}" in lanes for p in range(WORKERS)))
    outages = [e for e in perf["traceEvents"]
               if e.get("cat") == "churn" and e["ph"] == "X"]
    outage_ok = len(outages) == WORKERS // PODS  # the dead pod's workers
    assert lanes_ok, f"missing Perfetto worker lanes: {sorted(lanes)}"
    assert outage_ok, f"expected {WORKERS // PODS} outage windows, " \
                      f"got {len(outages)}"

    # the neutral twin of the churned stream: same family/topology, no
    # schedule — the CI obs lane monitors it with --fail-on-alarm (any
    # verdict on a healthy fleet is a false alarm) and diffs churned vs
    # baseline through repro.obs.diff for the attribution artifact
    tr_base = rt.run(app, cfg, T, seed=seed, obs=ObsSpec())
    ev_base = obs_events.collect_events(tr_base, cfg, tm,
                                        run="obs-smoke-baseline")
    obs_events.validate_events(ev_base)
    jsonl_base = os.path.join(common.RESULTS_DIR,
                              "obs_events_baseline.jsonl")
    obs_events.write_jsonl(ev_base, jsonl_base)

    # OpenMetrics text artifact next to the JSONL (the scrape-side view)
    prom_path = os.path.join(common.RESULTS_DIR, "obs_metrics.prom")
    obs_promtext.write(prom_path, reg)

    report_path = os.path.join(common.RESULTS_DIR, "obs_report.md")
    summary = obs_report.trace_summary(tr_on, cfg, tm, label="obs-smoke",
                                       fold=(0, seed), schedule=sched)
    with open(report_path, "w") as f:
        f.write(obs_report.render_report(
            "obs smoke: churned 2-pod eager run", [summary], registry=reg,
            notes=(f"{WORKERS} workers / {PODS} pods / {T} clocks, "
                   f"pod 1 down clocks {T // 3}-{3 * T // 4}",)))

    claim = {"bit_identical": bool(ident), "stream_valid": True,
             "perfetto_lanes_ok": bool(lanes_ok),
             "outage_windows_ok": bool(outage_ok)}
    emit("obs/smoke", 0.0, ";".join(f"{k}={v}" for k, v in claim.items()))
    return {"mesh": dict(rt.mesh.shape), "n_events": len(ev),
            "artifacts": [jsonl, jsonl_base, prom_path, trace_path,
                          report_path],
            "metrics": reg.flat(), "claim": claim}


def run() -> dict:
    """Standalone: smoke + overhead record (the full obs evidence)."""
    out = smoke()
    rec = bench_obs_record()
    out["overhead"] = rec["overhead"]
    out["claim"] = dict(out["claim"], **rec["claim"])
    save_json("obs", {k: v for k, v in out.items() if k != "metrics"})
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="the CI obs lane: emit + validate stream/trace")
    a = ap.parse_args()
    if a.smoke:
        print(smoke()["claim"])
    else:
        print(run()["claim"])
