"""Paper Fig 1 (left): staleness (clock-differential) distributions.

Runs MF on the PS simulator under BSP / SSP(s) / ESSP(s) and reports the
normalized histogram of clock differentials; the paper's claim C1 is that
SSP is ~uniform over the window while ESSP concentrates at -1.

All three configs run through the batched sweep engine: one compiled
program per consistency-model family instead of one per data point.
"""
from __future__ import annotations

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import bsp, essp, ssp, staleness, sweep

from .common import emit, save_json, sweep_meta, us_per_config


def run(T: int = 200, s: int = 5, seed: int = 0):
    app = make_mf_app(MFConfig())
    named = [("bsp", bsp()), (f"ssp{s}", ssp(s)), (f"essp{s}", essp(s))]
    res = sweep(app, [c for _, c in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"sweep": sweep_meta(res)}
    for i, (name, _) in enumerate(named):
        tr = res.trace(i)
        # skip_warmup keeps the histogram consistent with summary(), which
        # always drops the cold-start reads (cview still at the initial -1)
        bins, probs = staleness.histogram(tr, lo=-(s + 2), skip_warmup=True)
        summ = staleness.summary(tr)
        out[name] = {"bins": bins.tolist(), "probs": probs.tolist(),
                     "summary": summ, "us": us}
        emit(f"staleness_profile/{name}", us,
             f"mean_staleness={summ['mean']:.2f};"
             f"frac_at_-1={probs[bins == -1][0]:.2f}")
    # headline claim numbers
    frac_essp = out[f"essp{s}"]["probs"][out[f"essp{s}"]["bins"].index(-1)]
    peak_ssp = max(out[f"ssp{s}"]["probs"])
    out["claim_C1"] = {
        "essp_mass_at_minus1": frac_essp,
        "ssp_peak_bin_mass": peak_ssp,
        "pass": bool(frac_essp > 0.6 and peak_ssp < 0.4),
    }
    save_json("staleness_profile", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C1"])
