"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus claim summaries at the
end).  Roofline tables are separate (they read dry-run artifacts):
``python -m benchmarks.roofline``.

The harness deliberately does NOT force a multi-device host platform: on
small hosts, 8 fake devices oversubscribe the cores and distort every
timing row.  `benchmarks.psrun_bench` (8 devices) and
`benchmarks.pods_bench` (16, the CI pods-lane topology) force their own
host platforms when run standalone, which is where the sharded clocks/sec
numbers come from; inside this harness they run on whatever topology the
process has (their traces — and therefore their convergence claims — are
mesh-independent by the oracle contract).
"""
from __future__ import annotations

import argparse
import resource
import sys
import time
import traceback


def failed_claims(claim, prefix="") -> list:
    """Recursively collect the paths of boolean claim leaves that are
    False.  Non-boolean leaves (counts, seconds, ratios) are context, not
    gates; every boolean in a claim dict is positively phrased ("pass",
    "ok", "..._stable") by convention, so False means the claim tripped."""
    out = []
    if isinstance(claim, dict):
        for k, v in claim.items():
            out += failed_claims(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(claim, bool) and not claim:
        out.append(prefix)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="directory for all JSON artifacts (per-suite "
                         "results and the machine-readable BENCH_*.json "
                         "perf records); default: $BENCH_DIR or "
                         "experiments/bench")
    args = ap.parse_args(argv)
    t0 = time.time()
    from . import (analysis_bench, autotune_bench, comm_bench,
                   comm_comp, common, detect_bench, faults_bench,
                   kernels_bench, lda_convergence, lm_consistency,
                   mf_convergence, pods_bench, psrun_bench, robustness,
                   staleness_profile, stragglers, sweep_bench,
                   theory_validation)
    if args.json_dir:
        common.set_results_dir(args.json_dir)

    claims, errors = {}, {}

    def suite(name, fn):
        """Run one suite; a crash is recorded (and fails the harness) but
        never silences the remaining suites' rows and artifacts.  Each
        BENCH_*.json the suite wrote gets ``meta.timing`` stamped (suite
        wall seconds + process peak RSS — RSS is monotonic process-wide,
        so it reads as "peak by the end of this suite")."""
        common.pop_written()
        t0 = time.perf_counter()
        try:
            claims[name] = fn()
        except Exception:
            errors[name] = traceback.format_exc()
            print(f"\n!! suite {name} crashed:\n{errors[name]}",
                  file=sys.stderr)
        finally:
            common.annotate_bench_meta(common.pop_written(), {
                "suite": name,
                "wall_s": round(time.perf_counter() - t0, 3),
                "peak_rss_mb": round(resource.getrusage(
                    resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
            })

    print("name,us_per_call,derived")
    suite("C1_staleness_profile", lambda: staleness_profile.run()["claim_C1"])
    suite("C2_mf", lambda: mf_convergence.run()["claim_C2"])
    suite("C2_lda", lambda: lda_convergence.run()["claim_C2_lda"])
    suite("C6_comm_comp", lambda: comm_comp.run()["claim_C6"])
    suite("C3_robustness", lambda: robustness.run()["claim_C3"])
    suite("stragglers", lambda: stragglers.run()["claim"])
    suite("lm_consistency_pod", lambda: lm_consistency.run()["claim"])

    def _theory():
        theory = theory_validation.run()
        claims["C4_variance"] = theory["variance"]
        return theory["vap"]

    suite("C5_vap", _theory)

    def _sweep():
        sb = sweep_bench.run()
        return {"speedup": round(sb["speedup"], 1), "pass_3x": sb["pass_3x"]}

    suite("sweep_engine", _sweep)
    suite("autotune", lambda: autotune_bench.run()["claim"])
    suite("psrun_eager_beats_lazy", lambda: psrun_bench.run()["claim"])
    suite("pods_eager_beats_gated", lambda: pods_bench.run()["claim"])
    suite("comm_substrate", lambda: comm_bench.run()["claim"])
    suite("kernels", lambda: kernels_bench.run())
    suite("analysis", lambda: analysis_bench.run()["claim"])
    suite("detect_quality", lambda: detect_bench.run()["claim"])
    suite("wire_faults", lambda: faults_bench.run()["claim"])

    print("\n=== paper-fidelity claim summary ===")
    for k, v in claims.items():
        print(f"{k}: {v}")
    tripped = failed_claims(claims)
    status = 0
    if tripped:
        print(f"\nFAILED claims: {', '.join(tripped)}", file=sys.stderr)
        status = 1
    if errors:
        print(f"FAILED suites: {', '.join(errors)}", file=sys.stderr)
        status = 1
    print(f"\ntotal bench wall: {time.time()-t0:.1f}s")
    return status


if __name__ == "__main__":
    sys.exit(main())
