"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus claim summaries at the
end).  Roofline tables are separate (they read dry-run artifacts):
``python -m benchmarks.roofline``.

The harness deliberately does NOT force a multi-device host platform: on
small hosts, 8 fake devices oversubscribe the cores and distort every
timing row.  `benchmarks.psrun_bench` (8 devices) and
`benchmarks.pods_bench` (16, the CI pods-lane topology) force their own
host platforms when run standalone, which is where the sharded clocks/sec
numbers come from; inside this harness they run on whatever topology the
process has (their traces — and therefore their convergence claims — are
mesh-independent by the oracle contract).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="directory for all JSON artifacts (per-suite "
                         "results and the machine-readable BENCH_*.json "
                         "perf records); default: $BENCH_DIR or "
                         "experiments/bench")
    args = ap.parse_args(argv)
    t0 = time.time()
    from . import (autotune_bench, comm_bench, comm_comp, common,
                   kernels_bench, lda_convergence, lm_consistency,
                   mf_convergence, pods_bench, psrun_bench, robustness,
                   staleness_profile, stragglers, sweep_bench,
                   theory_validation)
    if args.json_dir:
        common.set_results_dir(args.json_dir)

    claims = {}
    print("name,us_per_call,derived")
    claims["C1_staleness_profile"] = staleness_profile.run()["claim_C1"]
    claims["C2_mf"] = mf_convergence.run()["claim_C2"]
    claims["C2_lda"] = lda_convergence.run()["claim_C2_lda"]
    claims["C6_comm_comp"] = comm_comp.run()["claim_C6"]
    claims["C3_robustness"] = robustness.run()["claim_C3"]
    claims["stragglers"] = stragglers.run()["claim"]
    claims["lm_consistency_pod"] = lm_consistency.run()["claim"]
    theory = theory_validation.run()
    claims["C4_variance"] = theory["variance"]
    claims["C5_vap"] = theory["vap"]
    sb = sweep_bench.run()
    claims["sweep_engine"] = {"speedup": round(sb["speedup"], 1),
                              "pass_3x": sb["pass_3x"]}
    claims["autotune"] = autotune_bench.run()["claim"]
    claims["psrun_eager_beats_lazy"] = psrun_bench.run()["claim"]
    claims["pods_eager_beats_gated"] = pods_bench.run()["claim"]
    claims["comm_substrate"] = comm_bench.run()["claim"]
    kernels_bench.run()

    print("\n=== paper-fidelity claim summary ===")
    for k, v in claims.items():
        print(f"{k}: {v}")
    print(f"\ntotal bench wall: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
