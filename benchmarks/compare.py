"""Perf-trajectory gate: compare two directories of BENCH_*.json records.

The scheduled CI lane saves machine-readable perf records
(``common.save_bench_json``: ``{"bench", "schema", "n_devices",
"metrics", "claim"}``) as a build artifact.  This tool diffs the current
run against the previous artifact and **exits nonzero when any metric
regresses by more than the threshold** (default 15%) or a claim that
passed before now trips — the trajectory must not silently decay.

Direction is inferred from the metric name: throughput-flavored metrics
(``clocks_per_sec``, ``speedup``, ``compression``, ``reduction``,
``throughput``) regress downward, everything else (seconds, clocks,
floats-on-wire) regresses upward.  ``None`` metrics (e.g. a threshold
never reached) and metrics missing from the baseline (new benchmarks) are
reported but never gate; a current ``None`` where the baseline had a
value IS a regression (the run stopped reaching its threshold).  The
``meta.*`` envelope (harness wall-time/peak-RSS stamped by
``benchmarks.run``) is context, never diffed — harness cost is tracked,
not gated.  A missing baseline directory or file passes trivially — the
first run of a new lane seeds the trajectory.

A regressed record is also *explained*, not just flagged: each pair with
regressions runs through `repro.obs.diff.diff_bench`, which attributes
the movement across staleness / straggler / wire / churn components by
metric name and prints the likely component with its driver metric (a
flipped claim pins its component outright).  Attribution is advisory —
it never changes the exit code — and degrades to nothing if the
``repro`` package is not importable.

Usage: ``python -m benchmarks.compare BASELINE_DIR CURRENT_DIR
[--threshold 0.15]``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

HIGHER_BETTER = ("clocks_per_sec", "speedup", "compression", "reduction",
                 "throughput")


def _higher_better(name: str) -> bool:
    return any(tok in name for tok in HIGHER_BETTER)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _claim_bools(claim, prefix="") -> dict:
    out = {}
    if isinstance(claim, dict):
        for k, v in claim.items():
            out.update(_claim_bools(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(claim, bool):
        out[prefix] = claim
    return out


def compare_bench(base: dict, cur: dict, threshold: float) -> dict:
    """Diff one benchmark record pair -> {rows, regressions}."""
    rows, regressions = [], []
    bm, cm = base.get("metrics", {}), cur.get("metrics", {})
    for name in sorted(cm):
        if name.startswith("meta."):
            continue            # harness observability, not a perf metric
        b, c = bm.get(name), cm[name]
        if name not in bm:
            rows.append((name, b, c, None, "new"))
            continue
        if b is None and c is None:
            rows.append((name, b, c, None, "n/a"))
            continue
        if c is None:
            rows.append((name, b, c, None, "REGRESSED (lost threshold)"))
            regressions.append(f"{name}: {b} -> None")
            continue
        if b is None or not isinstance(b, (int, float)) \
                or not isinstance(c, (int, float)):
            rows.append((name, b, c, None, "seeded"))
            continue
        if b == 0:
            rows.append((name, b, c, None, "zero-baseline"))
            continue
        rel = (c - b) / abs(b)
        bad = -rel if _higher_better(name) else rel
        status = "ok"
        if bad > threshold:
            status = f"REGRESSED ({bad:+.1%})"
            regressions.append(f"{name}: {b:g} -> {c:g} ({rel:+.1%})")
        rows.append((name, b, c, rel, status))
    cb, bb = (_claim_bools(cur.get("claim", {})),
              _claim_bools(base.get("claim", {})))
    for name, was in sorted(bb.items()):
        now = cb.get(name)
        if was and now is False:
            regressions.append(f"claim {name}: True -> False")
            rows.append((f"claim:{name}", was, now, None, "REGRESSED"))
    return {"rows": rows, "regressions": regressions}


def _attribute(base: dict, cur: dict) -> list:
    """Component attribution lines for a regressed record pair
    (`repro.obs.diff.diff_bench`); empty when ``repro`` is unavailable
    (the comparator itself stays stdlib-only)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    try:
        from repro.obs.diff import diff_bench, explain
    except ImportError:
        return []
    return explain(diff_bench(base, cur))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="directory with the previous run's "
                                     "BENCH_*.json records")
    ap.add_argument("current", help="directory with this run's records")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative regression gate (default 0.15 = 15%%)")
    args = ap.parse_args(argv)

    cur_files = sorted(glob.glob(os.path.join(args.current, "BENCH_*.json")))
    if not cur_files:
        print(f"no BENCH_*.json in {args.current}", file=sys.stderr)
        return 2
    if not os.path.isdir(args.baseline):
        print(f"no baseline directory {args.baseline} — seeding the "
              f"trajectory, nothing to gate")
        return 0

    all_regressions = []
    for path in cur_files:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline, name)
        cur = _load(path)
        print(f"\n== {cur.get('bench', name)} ==")
        if not os.path.exists(base_path):
            print("   (no baseline record — seeding)")
            continue
        base = _load(base_path)
        res = compare_bench(base, cur, args.threshold)
        for mname, b, c, rel, status in res["rows"]:
            delta = "" if rel is None else f" {rel:+.1%}"
            print(f"   {mname}: {b} -> {c}{delta}  [{status}]")
        if res["regressions"]:
            for line in _attribute(base, cur):
                print(f"   ~ {line}")
        all_regressions += [f"{name}: {r}" for r in res["regressions"]]

    if all_regressions:
        print(f"\n{len(all_regressions)} regression(s) past "
              f"{args.threshold:.0%}:", file=sys.stderr)
        for r in all_regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print(f"\nno regressions past {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
