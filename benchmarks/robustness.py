"""Robustness: staleness settings (paper C3) + fleet churn degradation.

Two measured layers:

**C3 (kept from the seed):** with an aggressive step size, lazy SSP
becomes unstable/diverges at high staleness (staleness effectively
amplifies the step), while ESSP's concentrated staleness profile keeps
convergence stable across all s.  The (model x staleness) grid runs
through the sweep engine: one compiled program per model family.

**Churn (the elastic-PS tentpole, measured):** which consistency family
degrades gracefully when the fleet misbehaves?  Every family
(BSP / clock-gated SSP / dense-eager ESSP / async / VAP / compressed-eager
``xeager``) runs the same MF problem on 2 pods under a matrix of
`core.delays.ChurnSchedule` scenarios —

- ``worker_churn``   — staggered single-worker outages,
- ``pod_outage``     — a whole pod down for a third of the run (drain
  policy), and ``pod_outage_drop`` — same outage, in-flight dropped,
- ``regime_shift``   — a mid-run straggler-regime shift (a block of
  workers slows to a fraction of the healthy delivery rate),
- ``bw_crunch``      — the cross-pod tier's bandwidth collapses for a
  window (`TimeModel.bw_scale`: modeled seconds, the traces are
  bandwidth-independent)

— reporting clocks-to-loss (threshold: the healthy BSP loss at 60% of the
run), **lost clocks** vs the family's own healthy baseline, and modeled
wall seconds over the bandwidth-faithful tier.  All of it is
deterministic given the seed (trace values are mesh-independent by the
oracle contract), so the headline claims gate in CI:

1. ``eager_recovers_before_gated`` — under every churn scenario the eager
   families (ESSP dense and compressed) reach the loss threshold in no
   more clocks than clock-gated sync;
2. ``eager_degrades_gracefully`` — eager's *lost clocks* under churn
   never exceed gated's (the graceful-degradation ordering);
3. ``all_families_survive`` — no family diverges under any scenario (the
   live-set contract holds end to end).

``smoke()`` is the reduced per-push variant for the CI churn lane: it
re-checks the deterministic layer only — simulator/runtime bit-identity
on the survivor set (dense + compressed) and claim (1) on a short run.

Standalone (``python -m benchmarks.robustness``) forces a 16-device host
platform (the CI churn lane's topology) before jax initializes; under
``benchmarks/run.py`` it runs on whatever topology the process has.
"""
from __future__ import annotations

import os
import sys

# Only the standalone invocation owns the process and may pick its device
# topology; a plain import must never mutate the environment.
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model  # noqa: E402
from repro.core import essp, simulate, ssp, sweep           # noqa: E402
from repro.core.consistency import (ConsistencyConfig,      # noqa: E402
                                    bsp, compressed, podded, vap)
from repro.core.delays import make_churn                    # noqa: E402
from repro.obs.report import churn_grid_table               # noqa: E402

from .common import (clocks_to_threshold, emit, save_bench_json,  # noqa: E402
                     save_json, sweep_meta, us_per_config,
                     wire_bound_time_model)

STALENESS_GRID = (0, 3, 7, 15)

# Churn matrix geometry: the pods_bench topology (16 workers, 2 pods) at
# the same equal-total-staleness pairing and compression knobs, so the
# robustness numbers compose with the throughput ones.
from .pods_bench import AGG, QUANT, S_INTRA, S_XPOD, T_NET_XPOD, TOPK  # noqa: E402

CHURN_WORKERS, CHURN_PODS = 16, 2


def churn_families(n_pods: int = CHURN_PODS):
    """The consistency families racing the churn matrix (name, cfg)."""
    mk = lambda c: podded(c, n_pods, s_xpod=S_XPOD, t_net_xpod=T_NET_XPOD)
    return [
        ("bsp", mk(bsp())),
        ("gated", mk(ssp(S_INTRA))),            # clock-gated cross-pod pull
        ("eager", mk(essp(S_INTRA))),           # dense eager cross-pod push
        ("async", mk(ConsistencyConfig(model="async"))),
        ("vap", mk(vap(0.5, staleness=S_INTRA + S_XPOD))),
        ("xeager", compressed(                  # compressed eager, equal
            podded(essp(S_INTRA), n_pods,       # total staleness budget
                   s_xpod=S_XPOD - (AGG - 1), t_net_xpod=T_NET_XPOD),
            agg_clocks=AGG, topk_frac=TOPK, quant=QUANT)),
    ]


def churn_scenarios(T: int, P: int = CHURN_WORKERS,
                    n_pods: int = CHURN_PODS):
    """The failure matrix, scaled to a T-clock run (name, schedule)."""
    t = lambda frac: int(T * frac)
    return [
        ("baseline", None),
        ("worker_churn", make_churn(T, P, worker_outages=(
            (1, t(.125), t(.375)), (9, t(.3), t(.6)),
            (4, t(.55), t(.8))))),
        ("pod_outage", make_churn(T, P, n_pods=n_pods,
                                  pod_outages=((1, t(.3), t(.6)),))),
        ("pod_outage_drop", make_churn(T, P, n_pods=n_pods,
                                       pod_outages=((1, t(.3), t(.6)),),
                                       drop_inflight=True)),
        ("regime_shift", make_churn(T, P, regime_shift=(t(.5), P // 4,
                                                        0.25))),
        ("bw_crunch", make_churn(T, P, n_pods=n_pods,
                                 bw_drop=(t(.25), t(.625), 0.2))),
    ]


def _lost(c_scenario, c_baseline):
    """Clocks lost to the failure (None = never recovered)."""
    if c_scenario is None or c_baseline is None:
        return None
    return int(c_scenario - c_baseline)


def _leq(a, b):
    """a recovers no later than b (None = never; never <= never is False
    for a, vacuously True when only b never recovers)."""
    return a is not None and (b is None or a <= b)


def run_churn(T: int = 160, seed: int = 0,
              families=None, scenarios=None) -> dict:
    """The churn degradation matrix (see module doc).  Deterministic given
    the seed: every number derives from simulator traces + the TimeModel.
    """
    app = make_mf_app(MFConfig(n_workers=CHURN_WORKERS))
    families = churn_families() if families is None else families
    scenarios = churn_scenarios(T) if scenarios is None else scenarios
    tm = wire_bound_time_model(app, mf_time_model().t_comp, CHURN_PODS)
    out: dict = {"T": T, "workers": CHURN_WORKERS, "n_pods": CHURN_PODS,
                 "time_model": {"t_comp": tm.t_comp,
                                "bandwidth_xpod": tm.bandwidth_xpod}}

    # one jitted entry per family; schedules ride as jit arguments (the
    # same-structure ones share the trace, per the engines' compile story)
    fns = {name: jax.jit(lambda sd, sch, a=app, c=cfg:
                         simulate(a, c, T, seed=sd, schedule=sch))
           for name, cfg in families}
    traces = {(f, s): fns[f](np.uint32(seed), sch)
              for f, _ in families for s, sch in scenarios}

    thresh = float(np.asarray(traces[("bsp", "baseline")].loss_ref)
                   [int(T * 0.6)])
    out["loss_thresh"] = thresh
    grid: dict = {}
    for fname, cfg in families:
        rows: dict = {}
        for sname, sched in scenarios:
            tr = traces[(fname, sname)]
            loss = np.asarray(tr.loss_ref)
            c = clocks_to_threshold(loss, thresh)
            wall = np.cumsum(np.asarray(tm.per_clock(
                tr, cfg.model, fold=(0, seed), cfg=cfg,
                schedule=sched)[0]))
            rows[sname] = {
                "clocks_to_thresh": c,
                "modeled_wall_to_thresh_s": (None if c is None
                                             else float(wall[c - 1])),
                "loss_final": float(loss[-1]),
                "diverged": bool(~np.isfinite(loss).all()
                                 or loss[-1] > loss[0]),
            }
        base_c = rows["baseline"]["clocks_to_thresh"]
        for sname, _ in scenarios:
            rows[sname]["lost_clocks"] = _lost(
                rows[sname]["clocks_to_thresh"], base_c)
        grid[fname] = rows
    out["grid"] = grid
    # the family x scenario matrix as one obs.report table (replaces the
    # seed's hand-rolled per-scenario CSV rows)
    out["grid_table"] = churn_grid_table(grid, [s for s, _ in scenarios])
    print("\n" + out["grid_table"] + "\n", flush=True)

    churn_names = [s for s, sch in scenarios if sch is not None]
    claim = {
        # (1) eager reaches the threshold in no more clocks than gated
        # sync, under every churn scenario (the acceptance ordering)
        "eager_recovers_before_gated": all(
            _leq(grid["eager"][s]["clocks_to_thresh"],
                 grid["gated"][s]["clocks_to_thresh"])
            and _leq(grid["xeager"][s]["clocks_to_thresh"],
                     grid["gated"][s]["clocks_to_thresh"])
            for s in churn_names),
        # (2) graceful degradation: eager never loses more clocks to the
        # failure than gated does
        "eager_degrades_gracefully": all(
            _lost_leq(grid["eager"][s]["lost_clocks"],
                      grid["gated"][s]["lost_clocks"])
            for s in churn_names),
        # (3) nobody diverges under any scenario
        "all_families_survive": not any(
            r["diverged"] for rows in grid.values() for r in rows.values()),
    }
    out["claim_churn"] = claim
    emit("robustness/churn/claims", 0.0,
         ";".join(f"{k}={v}" for k, v in claim.items()))
    return out


def _lost_leq(a, b):
    """Lost-clock ordering: None (never recovered) is worst."""
    if b is None:
        return True
    return a is not None and a <= b


def run_c3(T: int = 200, seed: int = 0) -> dict:
    """Paper C3: SSP fragile / ESSP stable across the staleness grid."""
    # "step size chosen large while still converging with staleness 0"
    cfg_mf = MFConfig(lr=1.4, lr_decay=True)
    app = make_mf_app(cfg_mf)
    named = [(name, s, mk(s))
             for name, mk in (("ssp", ssp), ("essp", essp))
             for s in STALENESS_GRID]
    res = sweep(app, [c for _, _, c in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"lr": cfg_mf.lr, "ssp": {}, "essp": {}, "sweep": sweep_meta(res)}
    for i, (name, s, _) in enumerate(named):
        tr = res.trace(i)
        loss = np.asarray(tr.loss_ref)
        final = float(np.mean(loss[-20:]))
        # oscillation measure over the tail ("shaky" convergence)
        shake = float(np.std(np.diff(loss[T // 2:])))
        diverged = bool(~np.isfinite(loss).all() or final > loss[0])
        out[name][s] = {"final": final, "shake": shake,
                        "diverged": diverged}
        emit(f"robustness/{name}_s{s}", us,
             f"final={final:.4f};shake={shake:.5f};div={diverged}")
    hi = max(out["ssp"].keys())
    out["claim_C3"] = {
        "ssp_high_s_worse": bool(
            out["ssp"][hi]["final"] > 1.5 * out["ssp"][0]["final"]
            or out["ssp"][hi]["diverged"]
            or out["ssp"][hi]["shake"] > 3 * out["essp"][hi]["shake"]),
        "essp_stable_all_s": bool(all(
            (not v["diverged"]) and v["final"] < 2.5 * out["essp"][0]["final"]
            for v in out["essp"].values())),
    }
    return out


def run(T: int = 200, seed: int = 0, T_churn: int = 160):
    out = run_c3(T, seed)
    churn = run_churn(T_churn, seed)
    out["churn"] = churn
    out["claim_C3"] = dict(out["claim_C3"], **churn["claim_churn"])
    save_json("robustness", out)
    # machine-readable perf record (CI artifact): the trajectory tracker
    metrics = {}
    for fname, rows in churn["grid"].items():
        for sname, r in rows.items():
            metrics[f"{fname}/{sname}/clocks_to_thresh"] = \
                r["clocks_to_thresh"]
            metrics[f"{fname}/{sname}/modeled_wall_to_thresh_s"] = \
                r["modeled_wall_to_thresh_s"]
    save_bench_json("robustness", metrics,
                    claim=dict(churn["claim_churn"],
                               ssp_high_s_worse=out["claim_C3"]
                               ["ssp_high_s_worse"],
                               essp_stable_all_s=out["claim_C3"]
                               ["essp_stable_all_s"]))
    # obs overhead record (BENCH_obs.json) rides the same claim gate
    from .obs_bench import bench_obs_record
    rec = bench_obs_record()
    out["obs_overhead"] = rec["overhead"]
    out["claim_C3"] = dict(out["claim_C3"], **rec["claim"])
    return out


def smoke(T: int = 60, seed: int = 0) -> dict:
    """The CI churn lane's per-push gate: deterministic layer only.

    (a) simulator/runtime bit-identity on the survivor set — dense and
    compressed-eager configs under a pod outage (the acceptance contract);
    (b) the eager-recovers-before-gated ordering on a reduced matrix
    (gated/eager/xeager x baseline/pod_outage).  Asserts and returns the
    evidence dict.
    """
    from repro.pods import PodsRuntime, cross_validate_pods
    from repro.psrun import PSRuntime
    from repro.psrun.validate import cross_validate
    from .pods_bench import _runtime_for

    app_small = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8,
                                     true_rank=8, n_workers=CHURN_WORKERS,
                                     batch=64, lr=0.5))
    sched = make_churn(12, CHURN_WORKERS, n_pods=CHURN_PODS,
                       pod_outages=((1, 4, 9),))
    rt = _runtime_for(CHURN_WORKERS, CHURN_PODS)
    out: dict = {"mesh": dict(rt.mesh.shape)}
    for name, cfg in (("dense", podded(essp(S_INTRA), CHURN_PODS,
                                       s_xpod=S_XPOD,
                                       t_net_xpod=T_NET_XPOD)),
                      ("compressed", compressed(
                          podded(essp(S_INTRA), CHURN_PODS,
                                 s_xpod=S_XPOD - (AGG - 1),
                                 t_net_xpod=T_NET_XPOD),
                          agg_clocks=AGG, topk_frac=TOPK, quant=QUANT))):
        if isinstance(rt, PodsRuntime):
            chk = cross_validate_pods(app_small, cfg, 12, runtime=rt,
                                      seed=seed, schedule=sched)
        else:  # single-device fallback: flat runtime, same contract
            chk = cross_validate(app_small, cfg, 12, runtime=rt,
                                 seed=seed, schedule=sched)
        out[f"oracle_churn_{name}"] = chk["ok"]
        emit(f"robustness/smoke/oracle_{name}", 0.0,
             f"bit_identical={chk['ok']}")
        assert chk["ok"], \
            f"{name} path diverged from the oracle under churn: {chk}"

    fams = [(n, c) for n, c in churn_families()
            if n in ("bsp", "gated", "eager", "xeager")]
    scens = [(n, s) for n, s in churn_scenarios(T)
             if n in ("baseline", "pod_outage")]
    res = run_churn(T, seed, families=fams, scenarios=scens)
    out["grid"] = res["grid"]
    out["claim"] = res["claim_churn"]
    assert out["claim"]["eager_recovers_before_gated"], res["grid"]
    assert out["claim"]["all_families_survive"], res["grid"]

    # (c) the failure detector sees the same outage the oracle seeded:
    # monitor the churned stream blind, grade against the schedule
    from repro.core.delays import score_detections
    from repro.obs import ObsSpec
    from repro.obs import events as obs_events
    from repro.obs.monitor import DetectorParams, monitor_stream
    cfg_dense = dict(churn_families())["eager"]
    tr = simulate(app_small, cfg_dense, 12, seed=seed, schedule=sched,
                  obs=ObsSpec())
    tm = wire_bound_time_model(app_small, mf_time_model().t_comp,
                               CHURN_PODS)
    ev = obs_events.collect_events(tr, cfg_dense, tm, schedule=sched,
                                   run="churn-smoke")
    mon = monitor_stream(ev, DetectorParams(timeout_clocks=2))
    budget = int(cfg_dense.staleness) + 1
    score = score_detections(np.asarray(sched.live), mon.verdicts,
                             budget)
    out["detector_score"] = {k: score[k] for k in
                             ("n_outages", "n_false_alarms",
                              "max_latency", "all_detected_in_budget")}
    emit("robustness/smoke/detector", 0.0,
         ";".join(f"{k}={v}" for k, v in out["detector_score"].items()))
    assert score["all_detected_in_budget"], score
    out["claim"] = dict(out["claim"],
                        detector_in_budget=score[
                            "all_detected_in_budget"])
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced deterministic gate (the CI churn lane)")
    a = ap.parse_args()
    if a.smoke:
        print(smoke()["claim"])
    else:
        r = run()
        print(r["claim_C3"])
