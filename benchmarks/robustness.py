"""Robustness to the staleness setting (paper C3).

With an aggressive step size, lazy SSP becomes unstable/diverges at high
staleness (staleness effectively amplifies the step), while ESSP's
concentrated staleness profile keeps convergence stable across all s.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import essp, simulate, ssp

from .common import emit, save_json, timed


def run(T: int = 200, seed: int = 0):
    # "step size chosen large while still converging with staleness 0"
    cfg_mf = MFConfig(lr=1.4, lr_decay=True)
    app = make_mf_app(cfg_mf)
    out = {"lr": cfg_mf.lr, "ssp": {}, "essp": {}}
    for s in (0, 3, 7, 15):
        for name, mk in (("ssp", ssp), ("essp", essp)):
            c = mk(s) if s > 0 else mk(0)
            fn = jax.jit(lambda cc=c: simulate(app, cc, T, seed=seed))
            us = timed(fn, warmup=1, iters=1)
            tr = fn()
            loss = np.asarray(tr.loss_ref)
            final = float(np.mean(loss[-20:]))
            # oscillation measure over the tail ("shaky" convergence)
            shake = float(np.std(np.diff(loss[T // 2:])))
            diverged = bool(~np.isfinite(loss).all() or final > loss[0])
            out[name][s] = {"final": final, "shake": shake,
                            "diverged": diverged}
            emit(f"robustness/{name}_s{s}", us,
                 f"final={final:.4f};shake={shake:.5f};div={diverged}")
    hi = max(out["ssp"].keys())
    out["claim_C3"] = {
        "ssp_high_s_worse": bool(
            out["ssp"][hi]["final"] > 1.5 * out["ssp"][0]["final"]
            or out["ssp"][hi]["diverged"]
            or out["ssp"][hi]["shake"] > 3 * out["essp"][hi]["shake"]),
        "essp_stable_all_s": bool(all(
            (not v["diverged"]) and v["final"] < 2.5 * out["essp"][0]["final"]
            for v in out["essp"].values())),
    }
    save_json("robustness", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C3"])
