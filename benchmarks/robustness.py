"""Robustness to the staleness setting (paper C3).

With an aggressive step size, lazy SSP becomes unstable/diverges at high
staleness (staleness effectively amplifies the step), while ESSP's
concentrated staleness profile keeps convergence stable across all s.

The full (model x staleness) grid runs through the sweep engine: one
compiled program per model family (SSP and ESSP), with the staleness bound
a traced value rather than a recompile.
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import essp, ssp, sweep

from .common import emit, save_json, sweep_meta, us_per_config

STALENESS_GRID = (0, 3, 7, 15)


def run(T: int = 200, seed: int = 0):
    # "step size chosen large while still converging with staleness 0"
    cfg_mf = MFConfig(lr=1.4, lr_decay=True)
    app = make_mf_app(cfg_mf)
    named = [(name, s, mk(s))
             for name, mk in (("ssp", ssp), ("essp", essp))
             for s in STALENESS_GRID]
    res = sweep(app, [c for _, _, c in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"lr": cfg_mf.lr, "ssp": {}, "essp": {}, "sweep": sweep_meta(res)}
    for i, (name, s, _) in enumerate(named):
        tr = res.trace(i)
        loss = np.asarray(tr.loss_ref)
        final = float(np.mean(loss[-20:]))
        # oscillation measure over the tail ("shaky" convergence)
        shake = float(np.std(np.diff(loss[T // 2:])))
        diverged = bool(~np.isfinite(loss).all() or final > loss[0])
        out[name][s] = {"final": final, "shake": shake,
                        "diverged": diverged}
        emit(f"robustness/{name}_s{s}", us,
             f"final={final:.4f};shake={shake:.5f};div={diverged}")
    hi = max(out["ssp"].keys())
    out["claim_C3"] = {
        "ssp_high_s_worse": bool(
            out["ssp"][hi]["final"] > 1.5 * out["ssp"][0]["final"]
            or out["ssp"][hi]["diverged"]
            or out["ssp"][hi]["shake"] > 3 * out["essp"][hi]["shake"]),
        "essp_stable_all_s": bool(all(
            (not v["diverged"]) and v["final"] < 2.5 * out["essp"][0]["final"]
            for v in out["essp"].values())),
    }
    save_json("robustness", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C3"])
