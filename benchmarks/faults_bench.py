"""Lossy wire: self-healing shipments + the detect->act recovery loop.

The measured claim (the PR's acceptance criterion): on MF and LDA over
the 16-worker / 2-pod topology, with seeded i.i.d. drop rates up to 30%
*plus* a correlated burst-loss regime, the compressed eager family with
error-feedback residual and ack/retransmit (``comm.wire``) reaches the
loss threshold within **10%** of the lossless clocks-to-loss — while the
*same* fault masks without retransmit or residual healing
(``max_retries=0, heal=False``: dropped mass is discarded) never reach
it within the T budget.  Retransmissions are charged at the shipment's
packed size into ``Trace.ship_floats``, so the faulted arms also pay
real modeled seconds over `TimeModel.bandwidth_xpod`.

On top of the convergence claim, the detect->act loop runs end to end:
every faulted run's event stream (schema v1.2, ``run_start.retry_budget``
stamped) goes through `repro.ctrl.recover.plan_recovery` with a wire SLO
set just above the lossless floats-per-clock — the controller must emit
at least one recovery action on **every** injected scenario and exactly
zero on the lossless neutral twin.

``smoke()`` is the per-push CI churn-lane variant: 20% drop + one burst
regime on MF, asserting simulator/runtime bit-identity under faults, the
healed-vs-unhealed recovery ordering, and the controller contract.

Standalone (``python -m benchmarks.faults_bench``) forces a 16-device
host platform before jax initializes and writes ``BENCH_faults.json``
for the perf-trajectory gate; under ``benchmarks/run.py`` it runs on
whatever topology the process has.
"""
from __future__ import annotations

import math
import os
import sys

# Only the standalone invocation owns the process and may pick its device
# topology; a plain import must never mutate the environment.
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.lda import LDAConfig, lda_time_model, make_lda_app  # noqa: E402
from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model  # noqa: E402
from repro.comm import wire                                  # noqa: E402
from repro.core import essp, simulate                        # noqa: E402
from repro.core.consistency import compressed, podded        # noqa: E402
from repro.ctrl.recover import plan_recovery                 # noqa: E402
from repro.obs import ObsSpec                                # noqa: E402
from repro.obs.events import collect_events                  # noqa: E402
from repro.obs.monitor import SLOParams                      # noqa: E402

from .common import (clocks_to_threshold, emit, save_bench_json,  # noqa: E402
                     save_json, wire_bound_time_model)
from .pods_bench import AGG, QUANT, S_INTRA, S_XPOD, T_NET_XPOD, TOPK  # noqa: E402

FAULT_WORKERS, FAULT_PODS = 16, 2
MAX_RETRIES = 3           # backoff ladder 1, 2, 4 clocks
HEADROOM = 1.10           # "within 10% of the lossless clocks-to-loss"
# Wire SLO: the controller must notice even the mildest scenario.  The
# measured retransmit overhead floor is ~5.5% extra floats/clock (the
# burst regime's quiet phase); the neutral twin sits at exactly 1.0 —
# 3% splits the two with margin on both sides.
WIRE_SLO_MARGIN = 1.03


def xeager_cfg():
    """The compressed eager family under test (pods_bench's ``xeager``
    knobs: equal total staleness budget, topk + int8 over the wire)."""
    return compressed(
        podded(essp(S_INTRA), FAULT_PODS, s_xpod=S_XPOD - (AGG - 1),
               t_net_xpod=T_NET_XPOD),
        agg_clocks=AGG, topk_frac=TOPK, quant=QUANT)


def fault_scenarios(T: int, P: int = FAULT_WORKERS, seed: int = 11):
    """(name, kwargs) fault regimes — i.i.d. drops up to 30% plus one
    correlated burst (90% loss for ~15% of the run)."""
    t = lambda frac: int(T * frac)
    return [
        ("drop10", dict(seed=seed, drop_rate=0.10)),
        ("drop20", dict(seed=seed + 1, drop_rate=0.20)),
        ("drop30", dict(seed=seed + 2, drop_rate=0.30)),
        ("burst", dict(seed=seed + 3, drop_rate=0.15,
                       bursts=((t(.40), t(.55), 0.9),))),
    ]


def _make(T, P, kw, healed: bool) -> wire.WireFaults:
    """Same seeded masks; only the ARQ/healing knobs differ between the
    healed arm and its no-retransmit / no-residual twin."""
    if healed:
        return wire.make_faults(T, P, max_retries=MAX_RETRIES, heal=True,
                                **kw)
    return wire.make_faults(T, P, max_retries=0, heal=False, **kw)


def run_app(name: str, app, t_comp: float, T: int, seed: int = 0) -> dict:
    P = app.n_workers
    scenarios = fault_scenarios(T, P)
    cfg = xeager_cfg()
    # one window (= one compiled family per static-knob combo) sized for
    # the largest flight budget in the matrix
    W = max(wire.required_window(cfg, _make(T, P, kw, healed))
            for _, kw in scenarios for healed in (True, False))
    cfg = cfg.replace(window=W)
    tm = wire_bound_time_model(app, t_comp, FAULT_PODS)
    obs = ObsSpec()

    fn0 = jax.jit(lambda sd: simulate(app, cfg, T, seed=sd, obs=obs))
    fnf = jax.jit(lambda sd, flt: simulate(app, cfg, T, seed=sd, obs=obs,
                                           faults=flt))
    tr0 = fn0(np.uint32(seed))
    loss0 = np.asarray(tr0.loss_ref)
    thresh = float(loss0[int(T * 0.6)])
    c0 = clocks_to_threshold(loss0, thresh)
    floats0 = float(np.asarray(tr0.ship_floats).sum()) / T
    slo = SLOParams(window=8, max_floats_per_clock=WIRE_SLO_MARGIN * floats0)

    out: dict = {"T": T, "workers": P, "loss_thresh": thresh,
                 "lossless": {"clocks_to_thresh": c0,
                              "floats_per_clock": floats0}}
    # the neutral twin: same monitors, zero faults -> zero actions
    ev0 = collect_events(tr0, cfg, tm, run=f"{name}-neutral")
    neutral_actions, _ = plan_recovery(ev0, slo=slo)
    out["neutral_actions"] = len(neutral_actions)

    rows: dict = {}
    for sname, kw in scenarios:
        row: dict = {}
        for arm, healed in (("healed", True), ("no_heal", False)):
            flt = _make(T, P, kw, healed)
            tr = fnf(np.uint32(seed), flt)
            loss = np.asarray(tr.loss_ref)
            c = clocks_to_threshold(loss, thresh)
            row[arm] = {
                "clocks_to_thresh": c,
                "loss_final": float(loss[-1]),
                "floats_per_clock":
                    float(np.asarray(tr.ship_floats).sum()) / T,
            }
            if healed:
                ev = collect_events(tr, cfg, tm, faults=flt,
                                    run=f"{name}-{sname}")
                actions, res = plan_recovery(ev, slo=slo)
                row["actions"] = len(actions)
                row["violations"] = len(res.violations)
        row["within_headroom"] = (
            c0 is not None and row["healed"]["clocks_to_thresh"] is not None
            and row["healed"]["clocks_to_thresh"]
            <= math.ceil(HEADROOM * c0))
        rows[sname] = row
        emit(f"faults/{name}/{sname}", 0.0,
             f"healed={row['healed']['clocks_to_thresh']};"
             f"no_heal={row['no_heal']['clocks_to_thresh']};"
             f"lossless={c0};actions={row['actions']}")
    out["scenarios"] = rows
    out["claim"] = {
        f"heal_within_10pct_{name}": all(r["within_headroom"]
                                         for r in rows.values()),
        f"no_heal_never_converges_{name}": all(
            r["no_heal"]["clocks_to_thresh"] is None
            for r in rows.values()),
        f"controller_fires_every_scenario_{name}": all(
            r["actions"] > 0 for r in rows.values()),
        f"controller_silent_on_neutral_{name}":
            len(neutral_actions) == 0,
    }
    return out


def run(T_mf: int = 160, T_lda: int = 80, seed: int = 0) -> dict:
    # T is sized per app so the 0.6*T threshold lands in the steep
    # descent of the lossless curve: LDA flattens onto its noise floor
    # past ~clock 60, where clock-to-clock noise makes threshold
    # crossings swing +-30% (MF keeps descending through clock 160).
    mf = run_app("mf", make_mf_app(MFConfig(n_workers=FAULT_WORKERS)),
                 mf_time_model().t_comp, T_mf, seed)
    lda = run_app("lda", make_lda_app(LDAConfig(n_workers=FAULT_WORKERS)),
                  lda_time_model().t_comp, T_lda, seed)
    out = {"mf": mf, "lda": lda, "claim": dict(mf["claim"], **lda["claim"])}
    save_json("faults", out)
    metrics: dict = {}
    for name, res in (("mf", mf), ("lda", lda)):
        metrics[f"{name}/lossless/clocks_to_thresh"] = \
            res["lossless"]["clocks_to_thresh"]
        for sname, r in res["scenarios"].items():
            metrics[f"{name}/{sname}/healed_clocks_to_thresh"] = \
                r["healed"]["clocks_to_thresh"]
            metrics[f"{name}/{sname}/healed_floats_per_clock"] = \
                r["healed"]["floats_per_clock"]
    save_bench_json("faults", metrics, claim=out["claim"])
    return out


def smoke(T: int = 60, seed: int = 0) -> dict:
    """The CI churn lane's per-push lossy-wire gate (16 devices): seeded
    20% drop + one burst regime on MF — simulator/runtime bit-identity
    under faults, recovery ordering (healed reaches the threshold the
    unhealed twin never does), controller fires / stays silent."""
    from repro.psrun.validate import cross_validate
    from .pods_bench import _runtime_for

    # full-size MF: the reduced 64x64 app sits in the batch-of-1 ulp
    # caveat (see launch.mesh) once the retry budget stretches the ring
    # window, which would void the bit-identity gate below
    app = make_mf_app(MFConfig(n_workers=FAULT_WORKERS))
    cfg = xeager_cfg()
    kw = dict(seed=11, drop_rate=0.20, bursts=((T // 3, T // 2, 0.9),))
    flt = _make(T, FAULT_WORKERS, kw, healed=True)
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    rt = _runtime_for(FAULT_WORKERS, FAULT_PODS)
    chk = cross_validate(app, cfg, 12, runtime=rt, seed=seed, faults=flt)
    out: dict = {"oracle_faulted": chk["ok"]}
    emit("faults/smoke/oracle", 0.0, f"bit_identical={chk['ok']}")
    assert chk["ok"], \
        f"faulted run diverged from the simulator oracle: {chk}"

    tm = wire_bound_time_model(app, mf_time_model().t_comp, FAULT_PODS)
    obs = ObsSpec()
    tr0 = simulate(app, cfg, T, seed=seed, obs=obs)
    loss0 = np.asarray(tr0.loss_ref)
    thresh = float(loss0[int(T * 0.6)])
    c0 = clocks_to_threshold(loss0, thresh)
    floats0 = float(np.asarray(tr0.ship_floats).sum()) / T
    slo = SLOParams(window=8, max_floats_per_clock=WIRE_SLO_MARGIN * floats0)

    tr = simulate(app, cfg, T, seed=seed, obs=obs, faults=flt)
    c = clocks_to_threshold(np.asarray(tr.loss_ref), thresh)
    twin = _make(T, FAULT_WORKERS, kw, healed=False)
    trn = simulate(app, cfg, T, seed=seed, obs=obs, faults=twin)
    cn = clocks_to_threshold(np.asarray(trn.loss_ref), thresh)
    out.update({"lossless": c0, "healed": c, "no_heal": cn})
    assert c0 is not None and c is not None, out
    assert c <= math.ceil(HEADROOM * c0), \
        f"healed recovery outside the {HEADROOM:.0%} headroom: {out}"
    assert cn is None, f"unhealed twin reached the threshold: {out}"

    actions, _ = plan_recovery(
        collect_events(tr, cfg, tm, faults=flt, run="faults-smoke"),
        slo=slo)
    silent, _ = plan_recovery(
        collect_events(tr0, cfg, tm, run="faults-smoke-neutral"), slo=slo)
    out.update({"actions": len(actions), "neutral_actions": len(silent)})
    assert actions and not silent, out
    emit("faults/smoke/recovery", 0.0,
         f"healed={c};lossless={c0};no_heal={cn};actions={len(actions)}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced per-push gate (the CI churn lane)")
    a = ap.parse_args()
    if a.smoke:
        print(smoke())
    else:
        print(run()["claim"])
