"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

On this CPU container the numbers are correctness-path timings (the Pallas
body runs in the interpreter); the derived column reports achieved
GFLOP/s of the jitted reference path, which is the deployable CPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mf_sgd import mf_sgd_block
from repro.kernels.ssd_scan import ssd

from .common import emit, save_json, timed


def run():
    out = {}
    # flash attention
    B, S, H, Hkv, D = 1, 512, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    fref = jax.jit(functools.partial(ref.attention, scale=0.125,
                                     q_pos=pos, kv_pos=pos))
    us = timed(fref, q, k, v)
    flops = 2 * 2 * B * H * S * S * D / 2   # causal
    emit("kernels/attention_ref_512", us,
         f"gflops={flops/us/1e3:.2f}")
    out["attention_ref_512_us"] = us

    fpal = jax.jit(functools.partial(
        flash_attention, scale=0.125, q_pos=pos, kv_pos=pos, interpret=True))
    us_p = timed(fpal, q, k, v, iters=1)
    emit("kernels/attention_pallas_interp_512", us_p, "interpret=True")

    # ssd
    b, s, h, p, g, n = 1, 1024, 8, 64, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    fref = jax.jit(lambda *a: ref.ssd_chunked(*a, 128))
    us = timed(fref, x, dt, A, Bm, Cm)
    emit("kernels/ssd_ref_1k", us, f"tokens_per_s={s/(us/1e6):.0f}")
    out["ssd_ref_1k_us"] = us
    fpal = jax.jit(functools.partial(ssd, chunk=128, interpret=True))
    us_p = timed(fpal, x, dt, A, Bm, Cm, iters=1)
    emit("kernels/ssd_pallas_interp_1k", us_p, "interpret=True")

    # mf sgd block
    N = M = 512; K = 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    L = jax.random.normal(ks[0], (N, K)); R = jax.random.normal(ks[1], (K, M))
    D_ = jax.random.normal(ks[2], (N, M))
    mask = jax.random.bernoulli(ks[3], 0.2, (N, M))
    fref = jax.jit(lambda *a: ref.mf_sgd_block(*a, 0.1, 1e-3))
    us = timed(fref, L, R, D_, mask)
    emit("kernels/mf_sgd_ref_512", us,
         f"ratings_per_s={0.2*N*M/(us/1e6):.2e}")
    out["mf_sgd_ref_512_us"] = us
    fpal = jax.jit(functools.partial(mf_sgd_block, gamma=0.1, lam=1e-3,
                                     interpret=True))
    us_p = timed(fpal, L, R, D_, mask, iters=1)
    emit("kernels/mf_sgd_pallas_interp_512", us_p, "interpret=True")

    save_json("kernels_bench", out)
    return out


if __name__ == "__main__":
    run()
