"""Detection-quality bench: the failure detector + SLO monitors, scored.

`repro.obs.monitor` turns telemetry into verdicts; this suite turns the
verdicts into measured claims (``BENCH_detect.json``, the harness claim
gate, and the perf-trajectory tracker).  The eager families (dense
``eager`` and compressed ``xeager`` — the families whose consistency
claims the churn matrix gates) run the full `benchmarks.robustness`
failure-scenario grid on the 16-worker / 2-pod topology; every run's
event stream is monitored blind (the detector never sees the stream's
``churn`` events) and then graded against the oracle `ChurnSchedule`
(`core.delays.score_detections`):

1. ``all_outages_detected_in_budget`` — every oracle outage is detected
   within ``s + agg_clocks`` clocks of its start (the staleness budget a
   dead worker can hide inside), with zero false alarms anywhere on the
   grid;
2. ``zero_false_alarms_neutral`` — the liveness-neutral scenarios
   (baseline / regime_shift / bw_crunch: stragglers and bandwidth
   crunches, but nobody dies) raise zero alarms at *every* timeout
   setting swept (1, 2, 4) — cadence noise must not look like death;
3. ``slo_verdicts_match_ground_truth`` — the staleness SLO verdicts
   (windowed p99 read-lag vs the declared ``s + s_xpod + agg_clocks - 1``
   contract) agree exactly, per window, with a Trace-derived ground
   truth recomputation, both under the declared bound (no violations —
   the contract holds) and under a deliberately tight ``bound=0``
   (violations fire, and fire in exactly the ground-truth windows);
   ``slo_tight_fires`` pins the tight pass non-vacuous.

Phi separation (weakest true-death phi vs noisiest healthy phi) is
reported as metrics — evidence, not a gate: the verdict trigger is the
missed-clock timeout, and the bw_crunch scenario shows why (a stretched
clock wall stretches healthy silences too).
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model
from repro.core import simulate
from repro.core.delays import score_detections
from repro.obs import ObsSpec
from repro.obs import events as obs_events
from repro.obs.monitor import DetectorParams, SLOParams, monitor_stream

from .common import emit, save_bench_json, save_json, \
    wire_bound_time_model
from .robustness import CHURN_PODS, CHURN_WORKERS, churn_families, \
    churn_scenarios

# Liveness-neutral scenarios: stress without death — any alarm is false.
NEUTRAL = ("baseline", "regime_shift", "bw_crunch")
TIMEOUT_SWEEP = (1, 2, 4)
SLO_WINDOW = 8


def _budget(cfg) -> int:
    """Clocks a dead worker can hide inside the staleness budget."""
    return int(cfg.staleness) + int(getattr(cfg, "agg_clocks", 1))


def _gt_staleness_windows(trace, bound: int, window: int) -> list:
    """Trace-derived ground truth: window-closing clocks whose worst
    per-clock p99 read lag exceeds ``bound`` — recomputed from the raw
    ``Trace.staleness`` / ``Trace.live`` arrays with the same
    `events.clock_lag_stats` reduction the stream producer uses, chunked
    exactly like `SLOMonitor` (tumbling, final partial window counts)."""
    staleness = np.asarray(trace.staleness)
    live = np.asarray(trace.live)
    T = staleness.shape[0]
    p99 = []
    for t in range(T):
        st = obs_events.clock_lag_stats(staleness[t], live[t])
        p99.append(None if st is None else st[0])
    out = []
    for w0 in range(0, T, window):
        chunk = [v for v in p99[w0:w0 + window] if v is not None]
        if chunk and max(chunk) > bound:
            out.append(min(w0 + window, T) - 1)
    return out


def run(T: int = 120, seed: int = 0) -> dict:
    families = [(n, c) for n, c in churn_families()
                if n in ("eager", "xeager")]
    scenarios = churn_scenarios(T)
    app = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                               n_workers=CHURN_WORKERS, batch=64, lr=0.5))
    tm = wire_bound_time_model(app, mf_time_model().t_comp, CHURN_PODS)

    out: dict = {"T": T, "workers": CHURN_WORKERS, "n_pods": CHURN_PODS,
                 "grid": {}}
    metrics: dict = {}
    in_budget, neutral_clean, slo_match, tight_fired = [], [], [], 0

    for fname, cfg in families:
        budget = _budget(cfg)
        bound = obs_events.declared_bound(cfg)
        for sname, sched in scenarios:
            tr = simulate(app, cfg, T, seed=seed, schedule=sched,
                          obs=ObsSpec())
            ev = obs_events.collect_events(tr, cfg, tm, schedule=sched,
                                           run=f"{fname}/{sname}")
            live = (np.asarray(sched.live) if sched is not None
                    else np.ones((T, CHURN_WORKERS), bool))

            res = monitor_stream(ev, DetectorParams(timeout_clocks=2),
                                 SLOParams(window=SLO_WINDOW))
            score = score_detections(live, res.verdicts, budget)
            in_budget.append(score["all_detected_in_budget"])

            if sname in NEUTRAL:
                clean = all(
                    monitor_stream(
                        ev, DetectorParams(timeout_clocks=to)
                    ).health["n_worker_down"] == 0
                    for to in TIMEOUT_SWEEP)
                neutral_clean.append(clean)

            # SLO agreement, declared contract + deliberately tight
            got = [v["t"] for v in res.violations
                   if v["slo"] == "staleness"]
            want = _gt_staleness_windows(tr, bound, SLO_WINDOW)
            tight = monitor_stream(
                ev, DetectorParams(timeout_clocks=2),
                SLOParams(window=SLO_WINDOW, staleness_bound=0))
            got_tight = [v["t"] for v in tight.violations
                         if v["slo"] == "staleness"]
            want_tight = _gt_staleness_windows(tr, 0, SLO_WINDOW)
            slo_match.append(got == want and got_tight == want_tight)
            tight_fired += len(got_tight)

            row = {
                "budget_clocks": budget, "declared_bound": bound,
                "n_outages": score["n_outages"],
                "n_alarms": score["n_alarms"],
                "n_false_alarms": score["n_false_alarms"],
                "max_latency": score["max_latency"],
                "all_detected_in_budget":
                    score["all_detected_in_budget"],
                "max_healthy_phi": res.health["max_healthy_phi"],
                "min_alarm_phi": res.health["min_alarm_phi"],
                "slo_declared_violations": len(got),
                "slo_tight_violations": len(got_tight),
                "slo_match": got == want and got_tight == want_tight,
            }
            out["grid"][f"{fname}/{sname}"] = row
            key = f"{fname}/{sname}"
            metrics[f"{key}/detect_latency_clocks"] = score["max_latency"]
            metrics[f"{key}/false_alarms"] = score["n_false_alarms"]
            metrics[f"{key}/max_healthy_phi"] = \
                res.health["max_healthy_phi"]
            if res.health["min_alarm_phi"] is not None:
                metrics[f"{key}/min_alarm_phi"] = \
                    res.health["min_alarm_phi"]
            emit(f"detect/{key}", 0.0,
                 f"outages={score['n_outages']};"
                 f"latency={score['max_latency']};"
                 f"false={score['n_false_alarms']};"
                 f"slo_match={row['slo_match']}")

    claim = {
        "all_outages_detected_in_budget": bool(all(in_budget)),
        "zero_false_alarms_neutral": bool(all(neutral_clean)),
        "slo_verdicts_match_ground_truth": bool(all(slo_match)),
        "slo_tight_fires": bool(tight_fired > 0),
    }
    out["claim"] = claim
    save_json("detect", out)
    save_bench_json("detect", metrics, claim=claim)
    emit("detect/claims", 0.0,
         ";".join(f"{k}={v}" for k, v in claim.items()))
    return out


if __name__ == "__main__":
    print(run()["claim"])
