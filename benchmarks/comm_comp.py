"""Paper Fig 1 (right): communication/computation breakdown per model.

C6: ESSP's background pushes shrink the synchronous-communication share
relative to lazy SSP at equal staleness (cost model; constants reported).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.apps.lda import LDAConfig, lda_time_model, make_lda_app
from repro.core import bsp, essp, simulate, ssp

from .common import emit, save_json, timed


def run(T: int = 60, seed: int = 0):
    app = make_lda_app(LDAConfig())
    tm = lda_time_model()
    out = {"time_model": tm.__dict__}
    for s in (1, 3, 5):
        for name, cfg, kind in [(f"ssp{s}", ssp(s), "ssp"),
                                (f"essp{s}", essp(s), "essp")]:
            fn = jax.jit(lambda c=cfg: simulate(app, c, T, seed=seed))
            us = timed(fn, warmup=1, iters=1)
            tr = fn()
            br = tm.breakdown(tr, kind)
            out[name] = dict(br, us=us)
            emit(f"comm_comp/{name}", us,
                 f"comm_frac={br['comm_frac']:.3f};total={br['total_s']:.1f}s")
    out["claim_C6"] = {
        s: {"ssp_comm_frac": out[f"ssp{s}"]["comm_frac"],
            "essp_comm_frac": out[f"essp{s}"]["comm_frac"],
            "pass": bool(out[f"essp{s}"]["comm_frac"]
                         < out[f"ssp{s}"]["comm_frac"])}
        for s in (1, 3, 5)
    }
    save_json("comm_comp", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C6"])
