"""Theorem-level validation (C4/C5): regret decay, decreasing variance,
VAP bound enforcement + sync cost, Theorem 5 moment sensitivity.

Every multi-config measurement (regret models, Theorem 5 staleness moments,
the VAP v0 grid) runs through the batched sweep engine — the VAP grid in
particular is one compiled program for all three value bounds, where the
seed implementation recompiled per v0.
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.core import essp, ssp, sweep, vap
from repro.core import staleness as stal
from repro.core import theory

from .common import emit, save_json, sweep_meta, us_per_config


def _quadratic_app(n_workers=8, dim=32, eta=0.4, noise=0.3):
    """Convex PS app: minimize ||x||^2 with noisy worker gradients."""
    import jax
    import jax.numpy as jnp
    from repro.core.ps import PSApp

    def worker_update(view, local, _wid, clock, rng):
        g = view + noise * jax.random.normal(rng, view.shape)
        step = eta / jnp.sqrt(1.0 + clock)
        return -step * g / n_workers, local

    return PSApp(name="quad", dim=dim, n_workers=n_workers,
                 x0=jnp.ones((dim,)) * 2.0,
                 local0={"_": jnp.zeros((n_workers, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


def run(seed: int = 0):
    out = {}
    app = make_mf_app(MFConfig())

    # Theorem 1/3: regret decays ~ 1/sqrt(T)
    regret_named = [("essp3", essp(3)), ("vap", vap(0.5, staleness=6))]
    res_r = sweep(app, [c for _, c in regret_named], 300, seeds=[seed],
                  timeit=True)
    us_r = us_per_config(res_r)
    for i, (name, _) in enumerate(regret_named):
        lv = np.asarray(res_r.trace(i).loss_view)
        curve = theory.regret_curve(lv, loss_star=float(lv.min()))
        expo = theory.sqrt_decay_fit(curve, skip=20)
        out[f"regret_{name}"] = {"exponent": expo,
                                 "final_regret": float(curve[-1])}
        emit(f"theory/regret_{name}", us_r, f"fit_exponent={expo:.2f}")

    # Theorem 2/6: variance decreasing; ESSP <= SSP.
    # Measured on a CONVEX objective (noisy quadratic) — the theorem's
    # setting.  (First attempt used MF and was *refuted*: MF's rotational
    # symmetry lets different seeds converge to different factorizations,
    # so iterate variance grows even as the loss converges.  Recorded in
    # EXPERIMENTS.md §Paper-fidelity C4.)
    app_s = _quadratic_app(n_workers=8, dim=32)
    v_ssp = theory.variance_trace(app_s, ssp(5), n_clocks=80, n_seeds=8)
    v_essp = theory.variance_trace(app_s, essp(5), n_clocks=80, n_seeds=8)
    out["variance"] = {
        "ssp_early": float(v_ssp[5:15].mean()),
        "ssp_late": float(v_ssp[-20:].mean()),
        "essp_early": float(v_essp[5:15].mean()),
        "essp_late": float(v_essp[-20:].mean()),
        "decreasing": bool(v_essp[-20:].mean() < v_essp[5:15].mean()),
        "essp_leq_ssp_late": bool(v_essp[-20:].mean()
                                  <= v_ssp[-20:].mean() * 1.1),
    }
    emit("theory/variance", 0.0,
         f"essp_late={out['variance']['essp_late']:.3e};"
         f"ssp_late={out['variance']['ssp_late']:.3e}")

    # Theorem 5: measured staleness moments -> bound ingredients
    res_t = sweep(app, [ssp(5), essp(5)], 200, seeds=[seed])
    for i, name in enumerate(("ssp5", "essp5")):
        s = stal.summary(res_t.trace(i))
        mu_g, sd_g = abs(s["mean"]) - 1, s["std"]   # staleness beyond -1
        b = theory.theorem5_bound(T=200, s=5, P=8, eta=0.5, L=1.0, F=1.0,
                                  mu_gamma=max(mu_g, 0), sigma_gamma=sd_g,
                                  tau=0.05)
        out[f"thm5_{name}"] = dict(b, mu_gamma=mu_g, sigma_gamma=sd_g)
        emit(f"theory/thm5_{name}", 0.0,
             f"threshold={b['threshold']:.3f};tail={b['tail_prob']:.3f}")
    out["thm5_essp_tighter"] = bool(
        out["thm5_essp5"]["threshold"] < out["thm5_ssp5"]["threshold"])

    # VAP (C5): bound holds; sync cost explodes as v0 -> 0.  One compiled
    # program for the whole v0 grid (v0 is a traced knob).
    v0_grid = (1.0, 0.1, 0.01)
    res_v = sweep(app, [vap(v, staleness=6) for v in v0_grid], 100,
                  seeds=[seed])
    out["vap_sweep"] = sweep_meta(res_v)
    forced = {}
    for i, v0 in enumerate(v0_grid):
        tr = res_v.trace(i)
        it = np.asarray(tr.intransit_inf)
        vt = v0 / np.sqrt(np.arange(1, 101))
        forced[v0] = {"forced_per_clock": float(np.asarray(tr.forced).sum()
                                                / 100),
                      "violations": float((it[1:] > vt[:-1] + 1e-6).mean())}
        emit(f"theory/vap_v0_{v0}", 0.0,
             f"forced_per_clock={forced[v0]['forced_per_clock']:.1f};"
             f"viol={forced[v0]['violations']:.3f}")
    out["vap"] = forced
    save_json("theory_validation", out)
    return out


if __name__ == "__main__":
    r = run()
    print({k: v for k, v in r.items() if k.startswith(("variance",
                                                       "thm5_essp_t"))})
