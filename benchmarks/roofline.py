"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all per-chip:

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / ICI_bw       (3 links x 50 GB/s)

HLO_FLOPs/bytes come from the multiplicity-aware HLO analyzer
(utils/hlo.py) — XLA's cost_analysis counts scan bodies once and is kept in
the artifacts as ``flops_xla_raw`` for reference.

MODEL_FLOPS: 6·N·D for training (N = params, D = tokens; MoE: N_active),
2·N·D for prefill/decode.  The ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/recompute waste (e.g. 0.75 = the extra remat forward).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, N_ICI_LINKS,  # noqa: E402
                               PEAK_FLOPS_BF16)

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def active_params(arch: str) -> float:
    """Active (per-token) parameter count — MoE uses top_k experts only."""
    from repro.models.registry import build_model
    cfg = get_config(arch)
    n = build_model(cfg).n_params
    if cfg.moe is None:
        return float(n)
    e, k, ffe, d = (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_ff_expert,
                    cfg.d_model)
    per_layer_routed = e * 3 * d * ffe
    per_layer_active = k * 3 * d * ffe
    if cfg.family == "moe":
        n_moe_layers = cfg.n_layers
    elif cfg.family == "hybrid":
        n_moe_layers = cfg.n_layers // 2
    else:
        n_moe_layers = 0
    return float(n - n_moe_layers * (per_layer_routed - per_layer_active))


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS per step (global, matmul-only, no attention)."""
    shape = INPUT_SHAPES[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    tokens = shape.global_batch          # decode: one token per sequence
    return 2.0 * n_act * tokens


def load_artifacts(pattern: str = "*", include_tagged: bool = False):
    """Baseline artifacts are named <arch>_<shape>_<mesh>.json; §Perf
    variants carry a trailing _<tag> and are excluded by default."""
    arts = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{pattern}.json"))):
        stem = os.path.basename(fn)[:-len(".json")]
        if not include_tagged and not (stem.endswith("_16x16")
                                       or stem.endswith("_2x16x16")):
            continue
        with open(fn) as f:
            arts.append(json.load(f))
    return arts


def roofline_row(art: dict) -> dict:
    chips = art["chips"]
    compute = art["flops_per_device"] / PEAK_FLOPS_BF16
    memory = art["bytes_accessed_per_device"] / HBM_BW
    coll = (art["collectives"]["total_bytes"]
            / (ICI_BW_PER_LINK * N_ICI_LINKS))
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(art["arch"], art["shape"])
    hlo_global = art["flops_per_device"] * chips
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    # fraction of roofline: useful-model-compute time / dominant term
    mf_time = mf / chips / PEAK_FLOPS_BF16
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "kind": art["kind"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_compute_ratio": useful,
        "roofline_fraction": (mf_time / bound) if bound else 0.0,
        "mem_gib": art["memory"]["total_bytes"] / 2**30,
        "fits_hbm": art["memory"]["total_bytes"] <= 16 * 2**30,
        "coll_counts": art["collectives"]["count_by_op"],
    }


def table(rows, f=sys.stdout):
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} "
           f"{'compute':>9s} {'memory':>9s} {'collect':>9s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'mem GiB':>8s} fits")
    print(hdr, file=f)
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_compute_ratio']:7.3f} "
              f"{100*r['roofline_fraction']:6.1f}% "
              f"{r['mem_gib']:8.2f} {'Y' if r['fits_hbm'] else 'N'}",
              file=f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--pattern", default="*")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = [roofline_row(a) for a in load_artifacts(args.pattern)
            if a["mesh"] == args.mesh or args.mesh == "all"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    table(rows)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(rows, fh, indent=1)


if __name__ == "__main__":
    main()
