"""Straggler ablation (beyond the paper's figures, squarely its motivation):
persistently slow workers vs consistency model.

With n slow producers (pushing at 25% of the nominal rate):
- BSP pays the straggler every clock (barrier; time model);
- lazy SSP's forced refreshes spike (its reads hit the bound constantly);
- ESSP degrades gracefully: staleness of the slow channels grows toward the
  bound but everyone else stays fresh, and convergence barely moves.

The (model x n_slow) grid runs through the sweep engine — straggler count
and rate are traced knobs, so each model family compiles once.
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model
from repro.core import essp, ssp, staleness, sweep

from .common import emit, save_json, sweep_meta, us_per_config


def run(T: int = 150, s: int = 5, seed: int = 0):
    app = make_mf_app(MFConfig())
    tm = mf_time_model()
    named = [(name, kind, n_slow,
              mk(s).replace(straggler_workers=n_slow, straggler_rate=0.25))
             for name, mk, kind in (("ssp", ssp, "ssp"),
                                    ("essp", essp, "essp"))
             for n_slow in (0, 1, 2)]
    res = sweep(app, [c for *_, c in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"sweep": sweep_meta(res)}
    for i, (name, kind, n_slow, _) in enumerate(named):
        tr = res.trace(i)
        loss = float(np.asarray(tr.loss_ref)[-10:].mean())
        forced = float(np.asarray(tr.forced).sum() / T)
        summ = staleness.summary(tr)
        br = tm.breakdown(tr, kind)
        key = f"{name}_slow{n_slow}"
        out[key] = {"final_loss": loss, "forced_per_clock": forced,
                    "stale_mean": summ["mean"], "stale_min": summ["min"],
                    "comm_frac": br["comm_frac"]}
        emit(f"stragglers/{key}", us,
             f"loss={loss:.4f};forced={forced:.1f};"
             f"stale_mean={summ['mean']:.2f}")
    out["claim"] = {
        # ESSP's convergence is robust to stragglers
        "essp_loss_stable": bool(out["essp_slow2"]["final_loss"]
                                 < 2.0 * out["essp_slow0"]["final_loss"]
                                 + 1e-3),
        # SSP forced synchronous refreshes grow with stragglers
        "ssp_forced_grows": bool(out["ssp_slow2"]["forced_per_clock"]
                                 >= out["ssp_slow0"]["forced_per_clock"]),
        # the slow channels are bounded by s even under ESSP
        "bound_respected": bool(out["essp_slow2"]["stale_min"] >= -(s + 2)),
    }
    save_json("stragglers", out)
    return out


if __name__ == "__main__":
    print(run()["claim"])
