"""Paper Fig 2 (LDA): predictive NLL vs iteration and vs modeled time.

The three consistency models run through the batched sweep engine (one
compile per model family).
"""
from __future__ import annotations

import numpy as np

from repro.apps.lda import LDAConfig, lda_time_model, make_lda_app
from repro.core import bsp, essp, ssp, sweep

from .common import emit, save_json, sweep_meta, us_per_config


def run(T: int = 80, s: int = 5, seed: int = 0):
    app = make_lda_app(LDAConfig())
    tm = lda_time_model()                      # Gibbs clocks cost more
    named = [("bsp", bsp(), "bsp"), (f"ssp{s}", ssp(s), "ssp"),
             (f"essp{s}", essp(s), "essp")]
    res = sweep(app, [c for _, c, _ in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"time_model": tm.__dict__, "sweep": sweep_meta(res)}
    for i, (name, _, kind) in enumerate(named):
        tr = res.trace(i)
        nll = np.asarray(tr.loss_ref)
        wall = tm.wall_time(tr, kind)
        out[name] = {"nll": nll.tolist(), "wall_s": wall.tolist(), "us": us}
        emit(f"lda_convergence/{name}", us, f"nll_T={nll[-1]:.4f}")

    m = {n: float(np.mean(out[n]["nll"][T // 2:]))
         for n in ("bsp", f"ssp{s}", f"essp{s}")}
    out["claim_C2_lda"] = {
        "tail_mean_nll": m,
        "pass": bool(m[f"essp{s}"] <= m[f"ssp{s}"] + 0.02),
    }
    save_json("lda_convergence", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C2_lda"])
