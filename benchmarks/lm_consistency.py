"""Pod-side ablation: the paper's consistency models on a *real* language
model (tiny transformer, synthetic data, actual AdamW gradients) — the
bridge between the PS simulator and the pod gradient-sync mapping.

BSP vs SSP(s) (delayed gradient application) vs ESSP (bucketed, s=0 —
bit-identical math to BSP by construction).  The interesting measurement is
SSP's convergence cost as a function of the FIFO depth: this is what the
staleness window costs *in exchange for* collective/compute overlap on a
pod (the overlap itself is a scheduling property, quantified in §Perf).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import TokenGenConfig, token_batches
from repro.models.registry import build_model
from repro.optim.optimizers import adamw, cosine_schedule
from repro.psdist.grad_sync import GradSync
from repro.train.state import init_state, make_train_step

from .common import emit, save_json, timed


def run(steps: int = 60, seed: int = 0):
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    opt = adamw(cosine_schedule(3e-3, steps // 10, steps))
    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=48, batch=8,
                          seed=seed)
    out = {}
    for name, sync in [("bsp", GradSync("bsp")),
                       ("ssp1", GradSync("ssp", 1)),
                       ("ssp2", GradSync("ssp", 2)),
                       ("ssp4", GradSync("ssp", 4)),
                       ("essp", GradSync("essp", 0, n_buckets=8))]:
        state = init_state(model, opt, sync, jax.random.PRNGKey(seed))
        step = jax.jit(make_train_step(model, opt, sync))
        losses = []
        import time
        t0 = time.time()
        for b in token_batches(dcfg, steps):
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        us = (time.time() - t0) * 1e6 / steps
        out[name] = {"losses": losses, "final": float(np.mean(losses[-5:])),
                     "us_per_step": us}
        emit(f"lm_consistency/{name}", us, f"final_loss={out[name]['final']:.3f}")
    out["claim"] = {
        # ESSP (s=0) must match BSP exactly; SSP cost grows with depth
        "essp_equals_bsp": bool(abs(out["essp"]["final"]
                                    - out["bsp"]["final"]) < 1e-3),
        "ssp_monotone_cost": bool(out["bsp"]["final"]
                                  <= out["ssp1"]["final"] + 0.05
                                  and out["ssp1"]["final"]
                                  <= out["ssp4"]["final"] + 0.6),
    }
    save_json("lm_consistency", out)
    return out


if __name__ == "__main__":
    print(run()["claim"])
