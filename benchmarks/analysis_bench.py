"""Static-analysis gate as a benchmark claim.

``repro.analysis`` (pure AST + the staleness model checker) runs over
``src/repro`` in strict mode; the claim leaf ``analysis_clean`` is True
iff zero findings.  Putting the analyzer verdict in ``BENCH_analysis.json``
means ``benchmarks.compare`` trips on a clean -> dirty transition the same
way it trips on a perf regression — an analysis regression is a trajectory
regression.
"""
from __future__ import annotations

import os
import time

from repro.analysis import analyze_paths

from . import common

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src", "repro")


def run() -> dict:
    t0 = time.time()
    findings = analyze_paths([_SRC], strict=True, model_check=True)
    wall = time.time() - t0
    claim = {"analysis_clean": not findings,
             "n_findings": len(findings)}
    common.emit("analysis_strict_scan", wall * 1e6,
                f"findings={len(findings)}")
    out = {"claim": claim, "wall_s": wall,
           "findings": [str(f) for f in findings]}
    common.save_json("analysis", out)
    common.save_bench_json("analysis",
                           {"scan_wall_s": wall,
                            "n_findings": len(findings)},
                           claim=claim)
    return out


if __name__ == "__main__":
    r = run()
    print(r["claim"])
