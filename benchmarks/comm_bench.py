"""The comm substrate (`repro.comm`) — compression knobs vs time-to-loss.

Sweeps the bandwidth-faithful communication knobs — ``agg_clocks`` (k-clock
delta aggregation), ``topk_frac`` (significance-filtered sparse shipment
with error feedback), ``quant`` (f32/int8 wire values) — over a small MF
app on 2 pods at **equal total staleness** (``s_xpod`` gives back
``agg_clocks - 1``), through the batched sweep engine: the comm knobs are
ordinary traced data leaves, so the whole (grid x seed) batch compiles
once per wire format.  Each point reports:

- clocks to a common loss threshold (does compression hurt convergence?);
- measured cross-pod floats-on-wire (``Trace.ship_floats`` through
  `pods.reconcile.reconcile_stats`) and the reduction vs dense-eager;
- modeled wall seconds to threshold under the per-tier `TimeModel`
  (dense-eager provisioned ~3x wire-bound, constants in the JSON);
- per-point execution time of the compiled substrate (the wired scan step
  vs the dense one — the sort/pack overhead, measured).

Claim: some aggregated+sparse+quantized point reaches the threshold with
>= 4x fewer floats-on-wire and a lower modeled wall clock than
dense-eager, within 10% of its clocks-to-loss — the Petuum/Bösen
update-batching result reproduced against measured bytes.

``smoke()`` is the per-push CI entry: tiny sizes, asserts the
deterministic claim layer only.
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app
from repro.comm import substrate as comm
from repro.core import essp
from repro.core.consistency import compressed, podded
from repro.core.sweep import sweep
from repro.core.tune import metrics_post
from repro.kernels import ops
from repro.pods.reconcile import reconcile_stats

from .common import (clocks_to_threshold, emit, save_bench_json, save_json,
                     timed, us_per_config, wire_bound_time_model)

S_INTRA, S_X_TOTAL, T_NET_XPOD = 2, 4, 8.0   # equal-total-staleness budget


def _grid(n_pods=2):
    """Dense baseline + the compressed grid, all at total cross-pod
    staleness ``S_INTRA + S_X_TOTAL``."""
    points = [("dense", podded(essp(S_INTRA), n_pods, s_xpod=S_X_TOTAL,
                               t_net_xpod=T_NET_XPOD))]
    for agg in (1, 2, 4):
        for topk in (1.0, 0.25, 0.0625):
            for quant in ("f32", "int8"):
                cfg = compressed(
                    podded(essp(S_INTRA), n_pods,
                           s_xpod=S_X_TOTAL - (agg - 1),
                           t_net_xpod=T_NET_XPOD),
                    agg_clocks=agg, topk_frac=topk, quant=quant)
                points.append((f"agg{agg}/top{topk:g}/{quant}", cfg))
    return points


def _kernel_rows(out):
    """Micro-bench the hot pack path (jnp reference backend — what the CPU
    sim runs; the Pallas body is parity-tested under interpret)."""
    import jax
    for P, d in ((16, 1024), (16, 8192)):
        delta = jax.random.normal(jax.random.PRNGKey(0), (P, d))
        fn = jax.jit(lambda x: comm.pack(x, 0.25, "int8"))
        us = timed(fn, delta)
        emit(f"comm_bench/pack/int8/{P}x{d}", us)
        out.setdefault("kernels", {})[f"pack_int8_{P}x{d}_us"] = us
        fn32 = jax.jit(lambda x: ops.delta_pack(
            x, comm.row_threshold(x, 0.25), comm.quant_scale(x, "f32"),
            "f32"))
        out["kernels"][f"pack_f32_{P}x{d}_us"] = timed(fn32, delta)


def run(T: int = 120, workers: int = 8, seeds: int = 2):
    app = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                               n_workers=workers, batch=64, lr=0.5))
    G = 2
    tm = wire_bound_time_model(app, t_comp=0.05, n_pods=G)
    out: dict = {"dim": app.dim, "workers": workers, "n_clocks": T,
                 "time_model": {"t_comp": tm.t_comp,
                                "bandwidth_xpod": tm.bandwidth_xpod}}
    _kernel_rows(out)

    names, configs = zip(*_grid(G))
    res = sweep(app, list(configs), T, seeds=seeds, timeit=True,
                post=metrics_post(tm))
    out["n_compiles"] = res.n_compiles            # one per wire format
    out["us_per_config"] = us_per_config(res)

    # threshold: where the dense baseline lands at 60% of the run
    dense_loss = np.stack(
        [np.asarray(res.post(0, s)["loss"]) for s in range(seeds)])
    thresh = float(dense_loss[:, int(T * 0.6)].mean())
    out["loss_thresh"] = thresh

    rows = {}
    for i, name in enumerate(names):
        cfg = configs[i]
        clocks, walls, wires = [], [], []
        for s in range(seeds):
            p = res.post(i, s)
            loss = np.asarray(p["loss"])
            wall = np.asarray(p["cum_wall"])
            c = clocks_to_threshold(loss, thresh)
            rec = reconcile_stats(res.trace(i, s), res.harmonized[i],
                                  dim=app.dim)
            clocks.append(c)
            walls.append(None if c is None else float(wall[c - 1]))
            wires.append(rec["wire_floats"])
        ok = [c for c in clocks if c is not None]
        rows[name] = {
            "clocks_to_thresh": float(np.mean(ok)) if ok else None,
            "modeled_wall_s": (float(np.mean([w for w in walls
                                              if w is not None]))
                               if ok else None),
            "wire_floats": float(np.mean(wires)),
        }
        emit(f"comm_bench/{name}", out["us_per_config"],
             f"clocks={rows[name]['clocks_to_thresh']};"
             f"wire={rows[name]['wire_floats']:.0f}")
    out["grid"] = rows

    # --- claim: a compressed point beats dense-eager on modeled wall with
    # >= 4x fewer floats-on-wire at matched (<=10%) clocks-to-loss.
    dense_row = rows["dense"]
    best = None
    for name, r in rows.items():
        if name == "dense" or r["clocks_to_thresh"] is None:
            continue
        if (dense_row["clocks_to_thresh"] is not None
                and r["clocks_to_thresh"] <= 1.1
                * dense_row["clocks_to_thresh"]
                and dense_row["wire_floats"] >= 4.0 * r["wire_floats"]
                and r["modeled_wall_s"] < dense_row["modeled_wall_s"]):
            if best is None or r["modeled_wall_s"] \
                    < rows[best]["modeled_wall_s"]:
                best = name
    claim = {
        "dense_clocks": dense_row["clocks_to_thresh"],
        "dense_wall_s": dense_row["modeled_wall_s"],
        "dense_wire": dense_row["wire_floats"],
        "best": best,
        "best_point": rows.get(best),
        "pass": best is not None,
    }
    out["claim"] = claim
    emit("comm_bench/compressed_beats_dense", 0.0,
         f"best={best};pass={claim['pass']}")
    save_json("comm_bench", out)
    metrics = {f"{n}/{k}": v for n, r in rows.items() for k, v in r.items()}
    metrics["us_per_config"] = out["us_per_config"]
    metrics["n_compiles"] = out["n_compiles"]
    save_bench_json("comm", metrics, claim=claim)
    return out


def smoke(T: int = 60, workers: int = 8):
    """Per-push CI smoke: tiny sizes, deterministic claim layer only."""
    r = run(T=T, workers=workers, seeds=1)
    assert r["claim"]["pass"], r["claim"]
    assert r["n_compiles"] <= 3, r["n_compiles"]   # dense + one per quant
    return r


if __name__ == "__main__":
    r = run()
    print(r["claim"])
