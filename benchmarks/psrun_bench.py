"""Executable PS runtime (`repro.psrun`) — throughput scaling and the
paper's eager-beats-lazy wall-clock claim, measured for real on a mesh.

Where every other benchmark *models* wall-clock through `TimeModel`, this
one executes the sharded runtime and times it: clocks/sec vs worker count
for MF and LDA under bsp/ssp/essp, and wall-clock time-to-loss at equal
staleness — the paper's Fig 2 claim (ESSP reaches the loss threshold
before SSP) reproduced with measured seconds instead of modeled ones.
Before timing anything it re-checks the oracle contract (seeded BSP run
bit-identical to ``core.ps.simulate``).

Standalone (``python -m benchmarks.psrun_bench``) this forces an 8-device
host platform before jax initializes — that invocation (or the CI sharded
lane) is where the sharded clocks/sec numbers come from.  Under
``benchmarks/run.py`` jax is already initialized, so it runs on whatever
topology the process has (typically one device); the *traces* are
mesh-independent either way (oracle contract), but the measured
seconds/clock are not.
"""
from __future__ import annotations

import os
import sys

# Only the standalone `python -m benchmarks.psrun_bench` invocation owns
# the process and may pick its device topology; a plain import must never
# mutate the environment (callers set XLA_FLAGS themselves, as the CI
# sharded lane does).
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.lda import LDAConfig, make_lda_app          # noqa: E402
from repro.apps.matfact import MFConfig, make_mf_app        # noqa: E402
from repro.core import bsp, essp, ssp                       # noqa: E402
from repro.psrun import PSRuntime, cross_validate, default_mesh  # noqa: E402

from .common import (clocks_to_threshold, emit, save_json,  # noqa: E402
                     timed_runtime_run)

MODELS = lambda s: [("bsp", bsp()), (f"ssp{s}", ssp(s)), (f"essp{s}", essp(s))]


def _mf(P):
    return make_mf_app(MFConfig(n_workers=P))


def _lda(P):
    return make_lda_app(LDAConfig(n_workers=P))


def run(T_mf: int = 240, T_lda: int = 50, s: int = 5,
        workers=(2, 4, 8), seed: int = 0):
    n_dev = len(jax.devices())
    out: dict = {"n_devices": n_dev, "staleness": s}

    # --- oracle contract first: measured numbers only count if the runtime
    # is running the same algorithm the simulator proves things about.
    app_small = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8,
                                     true_rank=8, n_workers=4, batch=64,
                                     lr=0.5))
    chk = cross_validate(app_small, bsp(), 10,
                         runtime=PSRuntime(default_mesh(4)), seed=seed)
    out["oracle_bsp_exact"] = chk["ok"]
    emit("psrun_bench/oracle_bsp", 0.0, f"bit_identical={chk['ok']}")
    assert chk["ok"], f"psrun diverged from the simulator oracle: {chk}"

    # --- clocks/sec + measured time-to-loss vs workers, per app x model ---
    for app_name, make_app, T in (("mf", _mf, T_mf), ("lda", _lda, T_lda)):
        scaling: dict = {}
        for P in workers:
            mesh = default_mesh(P)
            rt = PSRuntime(mesh)
            app = make_app(P)
            row: dict = {"mesh": dict(mesh.shape)}
            losses = {}
            for name, cfg in MODELS(s):
                t_first, t_exec, tr = timed_runtime_run(rt, app, cfg, T,
                                                        seed)
                loss = np.asarray(tr.loss_ref)
                losses[name] = loss
                row[name] = {
                    "clocks_per_sec": T / t_exec,
                    "t_compile_s": t_first - t_exec,
                    "sec_per_clock": t_exec / T,
                    "loss_final": float(loss[-1]),
                }
                emit(f"psrun_bench/{app_name}/{name}/P{P}",
                     t_exec / T * 1e6,
                     f"clocks_per_sec={T / t_exec:.1f}")
            # measured wall-clock to a common loss threshold: the level BSP
            # reaches at 60% of the run (all models get there, at different
            # clocks -- freshness differences become measured seconds).
            thresh = float(losses["bsp"][int(T * 0.6)])
            row["loss_thresh"] = thresh
            for name, _ in MODELS(s):
                c = clocks_to_threshold(losses[name], thresh)
                row[name]["clocks_to_thresh"] = c
                row[name]["wall_to_thresh_s"] = (
                    None if c is None else c * row[name]["sec_per_clock"])
            scaling[f"P{P}"] = row
        out[app_name] = scaling

    # --- the claim: eager beats lazy at equal staleness on the largest
    # mesh.  Two layers: `pass_clocks` (fewer clocks to the threshold) is
    # deterministic given the seed — trace values are mesh-independent by
    # the oracle contract — and is what CI asserts; `pass` additionally
    # multiplies by measured sec/clock (wall-clock sensitive, reported but
    # only asserted where the host is quiet).
    Pmax = f"P{max(workers)}"
    claim = {}
    for app_name in ("mf", "lda"):
        row = out[app_name][Pmax]
        ce, cl = row[f"essp{s}"]["clocks_to_thresh"], \
            row[f"ssp{s}"]["clocks_to_thresh"]
        e, l = row[f"essp{s}"]["wall_to_thresh_s"], \
            row[f"ssp{s}"]["wall_to_thresh_s"]
        claim[app_name] = {
            "essp_clocks": ce, "ssp_clocks": cl,
            "essp_wall_s": e, "ssp_wall_s": l,
            "pass_clocks": (ce is not None) and (cl is None or ce <= cl),
            "pass": (e is not None) and (l is None or e <= l),
        }
    claim["pass_clocks"] = all(claim[a]["pass_clocks"] for a in ("mf", "lda"))
    claim["pass"] = all(claim[a]["pass"] for a in ("mf", "lda"))
    out["claim"] = claim
    emit("psrun_bench/eager_beats_lazy", 0.0,
         f"mf={claim['mf']['pass']};lda={claim['lda']['pass']};"
         f"clocks={claim['pass_clocks']}")
    save_json("psrun_bench", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["claim"])
