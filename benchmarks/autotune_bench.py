"""Sweep-driven consistency auto-tuning vs the paper's hand-picked settings.

The paper hand-picks the consistency knobs per app (staleness 5, eager
pushes) and shows they win in wall-clock terms (Fig 2, C6).  `core.tune`
recovers that choice automatically: a dense (staleness × push_prob) grid per
consistency family runs as **one compiled program per family** (config and
seed batched via `core.sweep`, the traced `TimeModel` riding inside the
compile as a ``post`` consumer), and the Pareto frontier of (final loss,
modeled wall seconds to threshold) is read off the grid.

Reported per app (MF and LDA):
- the recovered frontier and the grid it came from (≥ 24 (config × seed)
  points per family, single compile per family — verified via the sweep
  trace counter);
- where the paper's hand-picked setting (ESSP, s=5, push 0.9) lands
  relative to the frontier's best point;
- one coarse→fine refinement round around the frontier (extra compiles are
  reported separately — the batch shape changes, so each round is a fresh
  program).
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.lda import LDAConfig, lda_time_model, make_lda_app
from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model
from repro.core import essp, ssp, tune
from repro.core.sweep import trace_count
from repro.core.timemodel import TimeModel

from .common import emit, save_json, sweep_meta


def _numpy_reference_per_clock(tm: TimeModel, comp, forced, model):
    """Independent numpy reimplementation of the TimeModel accounting
    (given the compute draws), used to cross-check the traced path."""
    comp = np.asarray(comp)                       # [T, P]
    forced = np.asarray(forced).astype(np.float64)
    T, P, _ = forced.shape
    xfer = tm.bytes_per_channel / tm.bandwidth
    sync = forced.sum(axis=2) * (tm.rtt + xfer)
    if model == "bsp":
        comp_clock = comp.max(axis=1)
        comm_clock = np.full(T, tm.barrier_overhead + (P - 1) * xfer + tm.rtt)
    else:
        worst = (comp + sync).argmax(axis=1)
        comp_clock = comp[np.arange(T), worst]
        comm_clock = sync[np.arange(T), worst]
    return np.cumsum(comp_clock + comm_clock)


def _verify_timemodel(app, tm: TimeModel) -> dict:
    """Acceptance checks: the traced model matches an independent numpy
    reimplementation to float tolerance (same straggler draws), and the
    corrected draws average to t_comp within 1%."""
    import jax

    from repro.core import essp, simulate

    tr = jax.jit(lambda: simulate(app, essp(3), 12))()
    got = np.asarray(jax.jit(
        lambda t: tm.wall_time(t, "essp", fold=(0, 0)))(tr))
    comp = tm.comp_draws((12, app.n_workers), fold=(0, 0))
    want = _numpy_reference_per_clock(tm, comp, tr.forced, "essp")
    max_rel = float(np.abs(got - want).max() / np.abs(want).max())
    draws = np.asarray(tm.comp_draws((400_000,)))
    mean_rel_err = float(abs(draws.mean() / tm.t_comp - 1.0))
    return {"traced_vs_numpy_max_rel": max_rel,
            "traced_matches_numpy": bool(max_rel < 1e-5),
            "draw_mean_rel_err": mean_rel_err,
            "draw_mean_within_1pct": bool(mean_rel_err < 0.01)}


HAND_PICKED = {"model": "essp", "staleness": 5, "push_prob": 0.9}

STALENESS_GRID = (1, 3, 5, 7)
PUSH_GRID = (0.5, 0.7, 0.9)


def _match(points, spec):
    for p in points:
        c = p["config"]
        if (c.model == spec["model"]
                and int(c.staleness) == spec["staleness"]
                and abs(float(c.push_prob) - spec["push_prob"]) < 1e-9):
            return p
    return None


def _tune_family(name: str, app, tm: TimeModel, T: int, seeds: int,
                 refine_rounds: int = 1) -> dict:
    bases = [ssp(STALENESS_GRID[0]), essp(STALENESS_GRID[0])]
    grids = {"staleness": list(STALENESS_GRID), "push_prob": list(PUSH_GRID)}
    n_families = len({b.family for b in bases})
    n0 = trace_count()
    t0 = time.perf_counter()
    fr = tune.frontier(app, bases, grids, time_model=tm, n_clocks=T,
                       seeds=seeds, refine_rounds=refine_rounds,
                       refine_knobs=("push_prob",))
    wall_s = time.perf_counter() - t0
    total_compiles = trace_count() - n0
    coarse = fr.history[0]
    n_grid = len(STALENESS_GRID) * len(PUSH_GRID) * len(bases)
    points_per_family = len(STALENESS_GRID) * len(PUSH_GRID) * seeds

    best = fr.best()
    hand = _match(fr.points, HAND_PICKED)

    def tts(p):
        return float(p["wall_to_threshold"]) if p else float("inf")

    by_model = {m: min((tts(p) for p in fr.points
                        if p["config"].model == m), default=float("inf"))
                for m in ("ssp", "essp")}

    out = {
        "time_model": tm.__dict__,
        "grid": {"staleness": list(STALENESS_GRID),
                 "push_prob": list(PUSH_GRID), "n_configs": n_grid,
                 "seeds": seeds, "T": T},
        "threshold": fr.threshold,
        "coarse_compiles": coarse["n_compiles"],
        "total_compiles": total_compiles,
        "points_per_family": points_per_family,
        "refinement": fr.history[1:],
        "frontier": fr.summary()["frontier"],
        "best": fr.summary()["best"],
        "hand_picked": {**HAND_PICKED, "wall_to_threshold": tts(hand),
                        "final_loss": hand["final_loss"] if hand else None},
        "best_tts_by_model": by_model,
        "wall_s": wall_s,
        "sweep": sweep_meta(fr.sweep_result),
        "claim": {
            # the whole coarse grid compiled once per consistency family
            "single_compile_per_family":
                bool(coarse["n_compiles"] == n_families),
            "points_per_family_ge_24": bool(points_per_family >= 24),
            # auto-tuning at least matches the paper's hand-picked setting
            "auto_beats_or_matches_hand":
                bool(tts(best) <= tts(hand) * 1.001 + 1e-9),
            # eager propagation wins the wall-clock race (C2/C6)
            "essp_best_faster_than_ssp_best":
                bool(by_model["essp"] <= by_model["ssp"]),
        },
    }
    us = fr.sweep_result.t_first_s * 1e6 / max(1, n_grid * seeds)
    emit(f"autotune/{name}", us,
         f"best={out['best']['model']}(s={out['best']['staleness']},"
         f"p={out['best']['push_prob']:.2f});"
         f"tts={out['best']['wall_to_threshold']:.2f}s;"
         f"hand_tts={tts(hand):.2f}s;"
         f"compiles={coarse['n_compiles']}/{n_families}fam")
    return out


def run(T_mf: int = 150, T_lda: int = 50, seeds: int = 2) -> dict:
    out = {}
    mf_app = make_mf_app(MFConfig())
    out["timemodel_checks"] = _verify_timemodel(mf_app, mf_time_model())
    out["mf"] = _tune_family("mf", mf_app, mf_time_model(), T_mf, seeds)
    out["lda"] = _tune_family(
        "lda", make_lda_app(LDAConfig()), lda_time_model(), T_lda, seeds)
    out["claim"] = {
        f"{app}_{k}": v
        for app in ("mf", "lda") for k, v in out[app]["claim"].items()
    }
    out["claim"]["traced_matches_numpy"] = \
        out["timemodel_checks"]["traced_matches_numpy"]
    out["claim"]["draw_mean_within_1pct"] = \
        out["timemodel_checks"]["draw_mean_within_1pct"]
    save_json("autotune_bench", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["claim"])
    for app in ("mf", "lda"):
        print(app, "best:", r[app]["best"], "| hand:", r[app]["hand_picked"])
