"""Paper Fig 2 (matrix factorization): objective vs iteration and vs time.

The time axis uses the parametric TimeModel (1 GbE-class constants, stated
in the output) — C2: ESSP >= SSP convergence per clock *and* per second.
The three consistency models run through the batched sweep engine (one
compile per model family).
"""
from __future__ import annotations

import numpy as np

from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model
from repro.core import bsp, essp, ssp, sweep

from .common import emit, save_json, sweep_meta, us_per_config


def run(T: int = 300, s: int = 5, seed: int = 0):
    app = make_mf_app(MFConfig())
    tm = mf_time_model()
    named = [("bsp", bsp(), "bsp"), (f"ssp{s}", ssp(s), "ssp"),
             (f"essp{s}", essp(s), "essp")]
    res = sweep(app, [c for _, c, _ in named], T, seeds=[seed], timeit=True)
    us = us_per_config(res)
    out = {"time_model": tm.__dict__, "sweep": sweep_meta(res)}
    for i, (name, _, tm_kind) in enumerate(named):
        tr = res.trace(i)
        loss = np.asarray(tr.loss_ref)
        wall = tm.wall_time(tr, tm_kind)
        out[name] = {"loss": loss.tolist(), "wall_s": wall.tolist(),
                     "us": us}
        emit(f"mf_convergence/{name}", us,
             f"loss_T={loss[-1]:.4f};modeled_wall={wall[-1]:.1f}s")

    def auc(name):   # lower = faster convergence (mean loss over clocks)
        return float(np.mean(out[name]["loss"]))

    def loss_at_time(name, t):
        w = np.asarray(out[name]["wall_s"])
        l = np.asarray(out[name]["loss"])
        i = np.searchsorted(w, t)
        return float(l[min(i, len(l) - 1)])

    t_ref = min(out[n]["wall_s"][-1] for n in ("bsp", f"ssp{s}", f"essp{s}"))
    out["claim_C2"] = {
        "per_clock_auc": {n: auc(n) for n in ("bsp", f"ssp{s}", f"essp{s}")},
        "loss_at_common_time": {n: loss_at_time(n, t_ref)
                                for n in ("bsp", f"ssp{s}", f"essp{s}")},
        "pass": bool(auc(f"essp{s}") <= auc(f"ssp{s}") * 1.05
                     and loss_at_time(f"essp{s}", t_ref)
                     <= loss_at_time(f"ssp{s}", t_ref) * 1.05),
    }
    save_json("mf_convergence", out)
    return out


if __name__ == "__main__":
    print(run()["claim_C2"])
