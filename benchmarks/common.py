"""Shared benchmark plumbing: timing + CSV rows."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.environ.get("BENCH_DIR", "experiments/bench")


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    """Print one ``name,us_per_call,derived`` CSV row (the run.py contract)."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timed_runtime_run(rt, app, cfg, n_clocks, seed=0):
    """Shared PS-runtime timing loop (psrun_bench / pods_bench):
    ``(first-call seconds incl. compile, steady-state seconds, trace)``."""
    import time
    fn = rt.run_fn(app, cfg, n_clocks)
    t0 = time.perf_counter()
    tr = jax.block_until_ready(fn(seed, cfg))
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    tr = jax.block_until_ready(fn(seed, cfg))
    t_exec = time.perf_counter() - t0
    return t_first, t_exec, tr


def clocks_to_threshold(loss, thresh):
    """First clock (1-based) at which ``loss`` reaches ``thresh``, else
    None — the time-to-loss metric of the runtime benchmarks."""
    hit = np.flatnonzero(np.asarray(loss) <= thresh)
    return int(hit[0]) + 1 if hit.size else None


def us_per_config(res) -> float:
    """Steady-state execution us attributed to one (config, seed) point of a
    `core.sweep.SweepResult` (compile time is reported separately)."""
    t = res.t_exec_s if res.t_exec_s is not None else res.t_first_s
    return float(t * 1e6 / max(1, len(res.configs) * len(res.seeds)))


def sweep_meta(res) -> dict:
    """Compile-count / timing evidence of a sweep, for the JSON artifacts."""
    return {"n_configs": len(res.configs), "n_seeds": len(res.seeds),
            "n_compiles": res.n_compiles, "t_first_s": res.t_first_s,
            "t_exec_s": res.t_exec_s,
            "families": {"/".join(map(str, k)): v
                         for k, v in res.families.items()}}
