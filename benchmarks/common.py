"""Shared benchmark plumbing: timing + CSV rows + JSON artifacts.

All JSON lands in one directory (``--json-dir`` on ``benchmarks.run`` /
``set_results_dir``, or the ``BENCH_DIR`` env var; default
``experiments/bench``) — no suite hand-rolls output paths.  Two artifact
kinds:

- ``save_json(name, payload)``: the suite's full result dict (free-form);
- ``save_bench_json(name, metrics, claim=...)``: a machine-readable
  ``BENCH_<name>.json`` with a fixed envelope (bench name, schema version,
  flat metrics such as clocks-to-loss / floats shipped / wall seconds, and
  the pass/fail claim) — the per-run perf record CI uploads as an artifact
  so the trajectory is tracked across scheduled runs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.environ.get("BENCH_DIR", "experiments/bench")

# BENCH_*.json names written since the last pop — the run.py harness uses
# this to annotate each suite's records with its wall-time/peak-RSS
# (meta.timing) without the suites knowing about the harness.
_WRITTEN: list = []


def pop_written() -> list:
    """Drain the list of BENCH names written since the last call."""
    out, _WRITTEN[:] = list(_WRITTEN), []
    return out


def annotate_bench_meta(names: list, timing: dict) -> None:
    """Fold ``meta.timing`` into the named ``BENCH_*.json`` records.

    ``meta.*`` is observability about the harness itself (suite wall
    seconds, process peak RSS) — `benchmarks.compare` ignores it when
    diffing metrics and claims."""
    for name in names:
        path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        payload.setdefault("meta", {})["timing"] = timing
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=float)


def set_results_dir(path: str) -> None:
    """Point every suite's JSON output at ``path`` (the ``--json-dir``
    flag of ``benchmarks.run``)."""
    global RESULTS_DIR
    RESULTS_DIR = path


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    """Print one ``name,us_per_call,derived`` CSV row (the run.py contract)."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def save_bench_json(name: str, metrics: dict, claim: dict | None = None):
    """Write the machine-readable ``BENCH_<name>.json`` perf record."""
    payload = {"bench": name, "schema": 1,
               "n_devices": len(jax.devices()),
               "metrics": metrics}
    if claim is not None:
        payload["claim"] = claim
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"BENCH_{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    _WRITTEN.append(name)
    return payload


def wire_bound_time_model(app, t_comp: float, n_pods: int,
                          wire_factor: float = 3.0):
    """Bandwidth-faithful `TimeModel` constants shared by the comm-layer
    benches (pods_bench / comm_bench): toy-scale per-delta bytes (``4d``)
    and a cross-pod tier provisioned so one dense-eager clock's shipments
    take ``wire_factor`` x the mean compute — with the default 3x clearly
    above the straggler tail (worst-of-P lognormal draws reach ~2x), so
    dense-eager clocks are genuinely wire-bound: the regime the second
    datacenter tier lives in and update batching targets.  Constants
    belong in every JSON artifact they condition."""
    from repro.core.timemodel import TimeModel
    dense_bytes = 4.0 * max(n_pods - 1, 1) * app.n_workers * app.dim
    return TimeModel(t_comp=t_comp, bytes_per_channel=4.0 * app.dim,
                     bandwidth_xpod=dense_bytes / (wire_factor * t_comp))


def timed_runtime_run(rt, app, cfg, n_clocks, seed=0):
    """Shared PS-runtime timing loop (psrun_bench / pods_bench):
    ``(first-call seconds incl. compile, steady-state seconds, trace)``."""
    fn = rt.run_fn(app, cfg, n_clocks)
    t0 = time.perf_counter()
    tr = jax.block_until_ready(fn(seed, cfg))
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    tr = jax.block_until_ready(fn(seed, cfg))
    t_exec = time.perf_counter() - t0
    return t_first, t_exec, tr


def clocks_to_threshold(loss, thresh):
    """First clock (1-based) at which ``loss`` reaches ``thresh``, else
    None — the time-to-loss metric of the runtime benchmarks."""
    hit = np.flatnonzero(np.asarray(loss) <= thresh)
    return int(hit[0]) + 1 if hit.size else None


def us_per_config(res) -> float:
    """Steady-state execution us attributed to one (config, seed) point of a
    `core.sweep.SweepResult` (compile time is reported separately)."""
    t = res.t_exec_s if res.t_exec_s is not None else res.t_first_s
    return float(t * 1e6 / max(1, len(res.configs) * len(res.seeds)))


def sweep_meta(res) -> dict:
    """Compile-count / timing evidence of a sweep, for the JSON artifacts."""
    return {"n_configs": len(res.configs), "n_seeds": len(res.seeds),
            "n_compiles": res.n_compiles, "t_first_s": res.t_first_s,
            "t_exec_s": res.t_exec_s,
            "families": {"/".join(map(str, k)): v
                         for k, v in res.families.items()}}
