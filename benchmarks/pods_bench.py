"""Hierarchical multi-pod PS (`repro.pods`) — throughput vs pod count and
the paper's eager-beats-lazy claim lifted one hierarchy level, now with
the bytes actually on the wire.

Where `benchmarks.psrun_bench` measures the flat executable runtime, this
one measures the hierarchical one: MF and LDA on a 3-D
``("pod","data","model")`` mesh with a full parameter-shard replica per
pod, comparing at **equal total staleness**:

- **eager** (dense ESSP-style: a full ``d``-float delta crosses the slow
  tier every clock),
- **xeager** (compressed eager through the comm substrate, `repro.comm`:
  k-clock aggregated, top-k sparse, int8-quantized shipments with error
  feedback — ``s_xpod`` tightened by ``agg_clocks - 1`` so the total
  staleness budget matches), and
- **gated** (clock-gated SSP-style sync: a cross-pod channel is pulled
  only when its bound trips).

Reported per (app × pod count): clocks/sec of the compiled step, clocks /
measured wall / **modeled wall** seconds to a common loss threshold (the
`TimeModel` with the bandwidth-faithful cross-pod tier — constants in the
JSON; the tier is provisioned so a dense-eager clock is ~3x wire-bound,
the regime the second datacenter tier lives in and the one Petuum-style
update batching targets), and measured cross-pod floats-on-wire
(`pods.reconcile.reconcile_stats` on ``Trace.ship_floats``).

The headline claim: **compressed-eager reaches the loss threshold in
fewer modeled wall seconds than dense-eager and clock-gated sync**, at
matched clocks-to-loss (within 10% of dense-eager) and >= 4x fewer
cross-pod floats-on-wire.

Before timing anything it re-checks the hierarchical oracle contract
(seeded BSP and compressed-ESSP runs on 2 pods bit-identical to
``core.ps.simulate`` with ``n_pods=2``).  The claim layer mirrors
psrun_bench: ``pass_clocks`` (deterministic given the seed, what CI
asserts; the wire/modeled-wall layers are deterministic too) and ``pass``
(adds measured sec/clock — wall-clock sensitive on shared runners).

Standalone (``python -m benchmarks.pods_bench``) this forces a 16-device
host platform before jax initializes (the CI pods lane's topology: 2x4x2);
under ``benchmarks/run.py`` it runs on whatever topology the process has.
"""
from __future__ import annotations

import os
import sys

# Only the standalone invocation owns the process and may pick its device
# topology; a plain import must never mutate the environment.
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.lda import LDAConfig, lda_time_model, make_lda_app  # noqa: E402
from repro.apps.matfact import MFConfig, make_mf_app, mf_time_model  # noqa: E402
from repro.core import bsp, essp, ssp                       # noqa: E402
from repro.core.consistency import compressed, podded       # noqa: E402
from repro.pods import (PodsRuntime, cross_validate_pods,   # noqa: E402
                        default_pods_mesh, reconcile_stats)
from repro.psrun import PSRuntime                           # noqa: E402
from repro.psrun.runtime import default_mesh as flat_mesh_for  # noqa: E402

from .common import (clocks_to_threshold, emit,             # noqa: E402
                     save_bench_json, save_json, timed_runtime_run,
                     wire_bound_time_model)

# Equal-total-staleness pairing: s_intra + s_xpod (+ agg_clocks - 1 for
# the compressed arm) is the same for every reconciliation style; the
# cross-pod tier is ~an order slower.
S_INTRA, S_XPOD, T_NET_XPOD = 2, 4, 8.0
# Compressed-eager arm: 2-clock aggregation, top-25% significance-filtered
# shipment, int8 wire — s_xpod gives back agg_clocks - 1 so the total
# staleness budget matches the dense arms exactly.
AGG, TOPK, QUANT = 2, 0.25, "int8"


def _runtime_for(workers, n_pods):
    """`PodsRuntime` on a physical pod mesh when the host has the devices;
    otherwise the flat runtime carrying the hierarchical config.  The
    traces (and therefore the clocks-to-threshold claim) are
    placement-independent by the oracle contract; only the measured
    sec/clock reflects the fallback placement — which is also what keeps
    ``benchmarks.run`` viable on a single-device host."""
    n = len(jax.devices())
    if n_pods == 1 or (n >= 2 * n_pods and n % n_pods == 0):
        try:
            return PodsRuntime(default_pods_mesh(workers, n_pods=n_pods))
        except ValueError:
            pass
    return PSRuntime(flat_mesh_for(workers))


def _configs(n_pods):
    mk = lambda cfg: podded(cfg, n_pods, s_xpod=S_XPOD,
                            t_net_xpod=T_NET_XPOD)
    out = [("bsp", mk(bsp())),
           ("gated", mk(ssp(S_INTRA))),       # clock-gated cross-pod pull
           ("eager", mk(essp(S_INTRA)))]      # dense eager cross-pod push
    if n_pods > 1:
        # compressed eager through the comm substrate, at the same total
        # staleness budget: s_xpod gives back the agg_clocks - 1 widening
        out.append(("xeager", compressed(
            podded(essp(S_INTRA), n_pods, s_xpod=S_XPOD - (AGG - 1),
                   t_net_xpod=T_NET_XPOD),
            agg_clocks=AGG, topk_frac=TOPK, quant=QUANT)))
    return out


def _mf(P):
    return make_mf_app(MFConfig(n_workers=P))


def _lda(P):
    return make_lda_app(LDAConfig(n_workers=P))


def run(T_mf: int = 160, T_lda: int = 40, workers: int = 16,
        pod_counts=(1, 2), seed: int = 0):
    n_dev = len(jax.devices())
    out: dict = {"n_devices": n_dev, "workers": workers,
                 "s_intra": S_INTRA, "s_xpod": S_XPOD,
                 "t_net_xpod": T_NET_XPOD}

    # --- hierarchical oracle contract first: measured numbers only count
    # if the runtime runs the same algorithm the simulator proves things
    # about (BSP bit-identity is checked inside cross_validate_pods).
    app_small = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8,
                                     true_rank=8, n_workers=workers,
                                     batch=64, lr=0.5))
    rt2 = _runtime_for(workers, 2)
    chk = cross_validate_pods(
        app_small, podded(bsp(), 2, s_xpod=S_XPOD), 10, runtime=rt2,
        seed=seed)
    out["oracle_bsp_exact"] = chk["ok"]
    out["oracle_mesh"] = dict(rt2.mesh.shape)
    emit("pods_bench/oracle_bsp", 0.0,
         f"bit_identical={chk['ok']};"
         f"mesh={'x'.join(map(str, rt2.mesh.shape.values()))}")
    assert chk["ok"], f"pods diverged from the hierarchical oracle: {chk}"
    # ... and the compressed path: aggregated/sparse/quantized shipment
    # must also be bit-identical between the runtime and the simulator.
    chk_x = cross_validate_pods(
        app_small, compressed(podded(essp(S_INTRA), 2,
                                     s_xpod=S_XPOD - (AGG - 1)),
                              agg_clocks=AGG, topk_frac=TOPK, quant=QUANT),
        10, runtime=rt2, seed=seed)
    out["oracle_comm_exact"] = chk_x["ok"]
    emit("pods_bench/oracle_comm", 0.0, f"bit_identical={chk_x['ok']}")
    assert chk_x["ok"], f"compressed path diverged from the oracle: {chk_x}"

    # --- clocks/sec + clocks/wall-to-threshold per app x pod count -------
    for app_name, make_app, T, t_comp in (
            ("mf", _mf, T_mf, mf_time_model().t_comp),
            ("lda", _lda, T_lda, lda_time_model().t_comp)):
        app = make_app(workers)
        per_pods: dict = {}
        for n_pods in pod_counts:
            rt = _runtime_for(workers, n_pods)
            tm = wire_bound_time_model(app, t_comp, n_pods)
            row: dict = {"mesh": dict(rt.mesh.shape),
                         "time_model": {"t_comp": tm.t_comp,
                                        "bandwidth_xpod": tm.bandwidth_xpod,
                                        "bytes_per_channel":
                                            tm.bytes_per_channel}}
            losses, walls = {}, {}
            for name, cfg in _configs(n_pods):
                t_first, t_exec, tr = timed_runtime_run(rt, app, cfg, T,
                                                        seed)
                loss = np.asarray(tr.loss_ref)
                losses[name] = loss
                # modeled wall clock over the bandwidth-faithful tier
                # (deterministic: folds the straggler RNG on (0, seed))
                walls[name] = tm.wall_time_np(tr, cfg.model,
                                              fold=(0, seed), cfg=cfg)
                row[name] = {
                    "clocks_per_sec": T / t_exec,
                    "t_compile_s": t_first - t_exec,
                    "sec_per_clock": t_exec / T,
                    "loss_final": float(loss[-1]),
                }
                if n_pods > 1 and name in ("gated", "eager", "xeager"):
                    rec = reconcile_stats(tr, cfg, dim=app.dim)
                    row[name]["xpod_eager_per_clock"] = rec["eager_per_clock"]
                    row[name]["xpod_gated_per_clock"] = rec["gated_per_clock"]
                    row[name]["dense_equiv_compression"] = \
                        rec["dense_equiv_compression"]
                    row[name]["wire_floats"] = rec["wire_floats"]
                    row[name]["wire_compression"] = rec["wire_compression"]
                emit(f"pods_bench/{app_name}/{name}/pods{n_pods}",
                     t_exec / T * 1e6,
                     f"clocks_per_sec={T / t_exec:.1f}")
            # wall-clock to a common loss threshold: the level the
            # hierarchical BSP reference reaches at 60% of the run.
            thresh = float(losses["bsp"][int(T * 0.6)])
            row["loss_thresh"] = thresh
            for name, _ in _configs(n_pods):
                c = clocks_to_threshold(losses[name], thresh)
                row[name]["clocks_to_thresh"] = c
                row[name]["wall_to_thresh_s"] = (
                    None if c is None else c * row[name]["sec_per_clock"])
                row[name]["modeled_wall_to_thresh_s"] = (
                    None if c is None else float(walls[name][c - 1]))
            per_pods[f"pods{n_pods}"] = row
        out[app_name] = per_pods

    # --- the claims, at equal total staleness on the multi-pod mesh:
    # (1) eager cross-pod reconciliation reaches the loss threshold before
    # clock-gated sync (PR 4's claim, kept); (2) *compressed* eager beats
    # both dense eager and gated in MODELED wall seconds, at matched
    # clocks-to-loss (within 10% of dense eager) and >= 4x fewer measured
    # cross-pod floats-on-wire.  `pass_clocks`, the wire ratios, and the
    # modeled walls are all deterministic (trace values are
    # mesh-independent by the oracle contract); `pass` adds measured
    # seconds (wall-clock sensitive — asserted only where the host is
    # quiet).
    pmax = f"pods{max(pod_counts)}"
    multi_pod = max(pod_counts) > 1    # the xeager arm (and any cross-pod
    #                                    wire at all) needs >= 2 pods
    claim = {}
    for app_name in ("mf", "lda"):
        row = out[app_name][pmax]
        ce, cl = row["eager"]["clocks_to_thresh"], \
            row["gated"]["clocks_to_thresh"]
        e, l = row["eager"]["wall_to_thresh_s"], \
            row["gated"]["wall_to_thresh_s"]
        claim[app_name] = {
            "eager_clocks": ce, "gated_clocks": cl,
            "eager_wall_s": e, "gated_wall_s": l,
            "pass_clocks": (ce is not None) and (cl is None or ce <= cl),
            "pass": (e is not None) and (l is None or e <= l),
        }
        if multi_pod:
            cx = row["xeager"]["clocks_to_thresh"]
            me, ml, mx = (row[n]["modeled_wall_to_thresh_s"]
                          for n in ("eager", "gated", "xeager"))
            wire_ratio = (row["eager"]["wire_floats"]
                          / max(row["xeager"]["wire_floats"], 1.0))
            claim[app_name].update({
                "xeager_clocks": cx,
                "eager_modeled_s": me, "gated_modeled_s": ml,
                "xeager_modeled_s": mx,
                "wire_reduction": wire_ratio,
                "pass_clocks_matched": (
                    ce is not None and cx is not None
                    and abs(cx - ce) <= max(1, 0.1 * ce)),
                "pass_wire_4x": wire_ratio >= 4.0,
                "pass_modeled": (
                    mx is not None
                    and (me is None or mx < me) and (ml is None or mx < ml)),
            })
    keys = ["pass_clocks", "pass"]
    if multi_pod:
        keys += ["pass_clocks_matched", "pass_wire_4x", "pass_modeled"]
    for key in keys:
        claim[key] = all(claim[a][key] for a in ("mf", "lda"))
    if multi_pod:
        claim["pass_comm"] = (claim["pass_clocks_matched"]
                              and claim["pass_wire_4x"]
                              and claim["pass_modeled"])
    out["claim"] = claim
    emit("pods_bench/eager_beats_gated_xpod", 0.0,
         f"mf={claim['mf']['pass']};lda={claim['lda']['pass']};"
         f"clocks={claim['pass_clocks']}")
    if multi_pod:
        emit("pods_bench/compressed_eager_wins", 0.0,
             f"matched={claim['pass_clocks_matched']};"
             f"wire4x={claim['pass_wire_4x']};"
             f"modeled={claim['pass_modeled']}")
    save_json("pods_bench", out)
    # machine-readable perf record (CI artifact): the trajectory tracker
    metrics = {}
    for app_name in ("mf", "lda"):
        row = out[app_name][pmax]
        for name, _ in _configs(max(pod_counts)):
            r = row[name]
            metrics[f"{app_name}/{name}/clocks_to_thresh"] = \
                r["clocks_to_thresh"]
            metrics[f"{app_name}/{name}/sec_per_clock"] = r["sec_per_clock"]
            metrics[f"{app_name}/{name}/modeled_wall_to_thresh_s"] = \
                r["modeled_wall_to_thresh_s"]
            if "wire_floats" in r:
                metrics[f"{app_name}/{name}/wire_floats"] = r["wire_floats"]
    save_bench_json("pods", metrics, claim=claim)
    return out


if __name__ == "__main__":
    r = run()
    print(r["claim"])
