"""Hierarchical multi-pod PS (`repro.pods`) — throughput vs pod count and
the paper's eager-beats-lazy claim lifted one hierarchy level.

Where `benchmarks.psrun_bench` measures the flat executable runtime, this
one measures the hierarchical one: MF and LDA on a 3-D
``("pod","data","model")`` mesh with a full parameter-shard replica per
pod, comparing *eager* cross-pod reconciliation (ESSP-style: update deltas
cross the slow tier every clock) against *clock-gated* sync (SSP-style:
a cross-pod channel is pulled only when its bound trips) at **equal total
staleness** ``s_intra + s_xpod`` — the paper's headline claim applied to
the second network tier.  Reported per (app × pod count):

- clocks/sec of the compiled hierarchical step (and its compile time);
- clocks and measured wall seconds to a common loss threshold (set by a
  hierarchical BSP reference run at 60% of the clock budget);
- cross-pod reconciliation traffic (`pods.reconcile.reconcile_stats`):
  eager delta deliveries vs gated pulls, and the delta-compression ratio.

Before timing anything it re-checks the hierarchical oracle contract
(seeded BSP run on 2 pods bit-identical to ``core.ps.simulate`` with
``n_pods=2``).  The claim layer mirrors psrun_bench: ``pass_clocks``
(fewer clocks to threshold — deterministic given the seed, what CI
asserts) and ``pass`` (adds measured sec/clock — wall-clock sensitive on
shared runners).

Standalone (``python -m benchmarks.pods_bench``) this forces a 16-device
host platform before jax initializes (the CI pods lane's topology: 2x4x2);
under ``benchmarks/run.py`` it runs on whatever topology the process has.
"""
from __future__ import annotations

import os
import sys

# Only the standalone invocation owns the process and may pick its device
# topology; a plain import must never mutate the environment.
if __name__ == "__main__" and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16"
                               ).strip()

import jax                  # noqa: E402
import numpy as np          # noqa: E402

from repro.apps.lda import LDAConfig, make_lda_app          # noqa: E402
from repro.apps.matfact import MFConfig, make_mf_app        # noqa: E402
from repro.core import bsp, essp, ssp                       # noqa: E402
from repro.core.consistency import podded                   # noqa: E402
from repro.pods import (PodsRuntime, cross_validate_pods,   # noqa: E402
                        default_pods_mesh, reconcile_stats)
from repro.psrun import PSRuntime                           # noqa: E402
from repro.psrun.runtime import default_mesh as flat_mesh_for  # noqa: E402

from .common import (clocks_to_threshold, emit, save_json,  # noqa: E402
                     timed_runtime_run)

# Equal-total-staleness pairing: s_intra + s_xpod is the same for both
# reconciliation styles; the cross-pod tier is ~an order slower.
S_INTRA, S_XPOD, T_NET_XPOD = 2, 4, 8.0


def _runtime_for(workers, n_pods):
    """`PodsRuntime` on a physical pod mesh when the host has the devices;
    otherwise the flat runtime carrying the hierarchical config.  The
    traces (and therefore the clocks-to-threshold claim) are
    placement-independent by the oracle contract; only the measured
    sec/clock reflects the fallback placement — which is also what keeps
    ``benchmarks.run`` viable on a single-device host."""
    n = len(jax.devices())
    if n_pods == 1 or (n >= 2 * n_pods and n % n_pods == 0):
        try:
            return PodsRuntime(default_pods_mesh(workers, n_pods=n_pods))
        except ValueError:
            pass
    return PSRuntime(flat_mesh_for(workers))


def _configs(n_pods):
    mk = lambda cfg: podded(cfg, n_pods, s_xpod=S_XPOD,
                            t_net_xpod=T_NET_XPOD)
    return [("bsp", mk(bsp())),
            ("gated", mk(ssp(S_INTRA))),      # clock-gated cross-pod pull
            ("eager", mk(essp(S_INTRA)))]     # eager cross-pod push


def _mf(P):
    return make_mf_app(MFConfig(n_workers=P))


def _lda(P):
    return make_lda_app(LDAConfig(n_workers=P))


def run(T_mf: int = 160, T_lda: int = 40, workers: int = 16,
        pod_counts=(1, 2), seed: int = 0):
    n_dev = len(jax.devices())
    out: dict = {"n_devices": n_dev, "workers": workers,
                 "s_intra": S_INTRA, "s_xpod": S_XPOD,
                 "t_net_xpod": T_NET_XPOD}

    # --- hierarchical oracle contract first: measured numbers only count
    # if the runtime runs the same algorithm the simulator proves things
    # about (BSP bit-identity is checked inside cross_validate_pods).
    app_small = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8,
                                     true_rank=8, n_workers=workers,
                                     batch=64, lr=0.5))
    rt2 = _runtime_for(workers, 2)
    chk = cross_validate_pods(
        app_small, podded(bsp(), 2, s_xpod=S_XPOD), 10, runtime=rt2,
        seed=seed)
    out["oracle_bsp_exact"] = chk["ok"]
    out["oracle_mesh"] = dict(rt2.mesh.shape)
    emit("pods_bench/oracle_bsp", 0.0,
         f"bit_identical={chk['ok']};"
         f"mesh={'x'.join(map(str, rt2.mesh.shape.values()))}")
    assert chk["ok"], f"pods diverged from the hierarchical oracle: {chk}"

    # --- clocks/sec + clocks/wall-to-threshold per app x pod count -------
    for app_name, make_app, T in (("mf", _mf, T_mf), ("lda", _lda, T_lda)):
        app = make_app(workers)
        per_pods: dict = {}
        for n_pods in pod_counts:
            rt = _runtime_for(workers, n_pods)
            row: dict = {"mesh": dict(rt.mesh.shape)}
            losses = {}
            for name, cfg in _configs(n_pods):
                t_first, t_exec, tr = timed_runtime_run(rt, app, cfg, T,
                                                        seed)
                loss = np.asarray(tr.loss_ref)
                losses[name] = loss
                row[name] = {
                    "clocks_per_sec": T / t_exec,
                    "t_compile_s": t_first - t_exec,
                    "sec_per_clock": t_exec / T,
                    "loss_final": float(loss[-1]),
                }
                if n_pods > 1 and name in ("gated", "eager"):
                    rec = reconcile_stats(tr, cfg, dim=app.dim)
                    row[name]["xpod_eager_per_clock"] = rec["eager_per_clock"]
                    row[name]["xpod_gated_per_clock"] = rec["gated_per_clock"]
                    row[name]["delta_compression"] = rec["delta_compression"]
                emit(f"pods_bench/{app_name}/{name}/pods{n_pods}",
                     t_exec / T * 1e6,
                     f"clocks_per_sec={T / t_exec:.1f}")
            # measured wall-clock to a common loss threshold: the level the
            # hierarchical BSP reference reaches at 60% of the run.
            thresh = float(losses["bsp"][int(T * 0.6)])
            row["loss_thresh"] = thresh
            for name, _ in _configs(n_pods):
                c = clocks_to_threshold(losses[name], thresh)
                row[name]["clocks_to_thresh"] = c
                row[name]["wall_to_thresh_s"] = (
                    None if c is None else c * row[name]["sec_per_clock"])
            per_pods[f"pods{n_pods}"] = row
        out[app_name] = per_pods

    # --- the claim: at equal total staleness on the multi-pod mesh, eager
    # cross-pod reconciliation reaches the loss threshold before
    # clock-gated sync.  `pass_clocks` is deterministic (trace values are
    # mesh-independent by the oracle contract); `pass` adds measured
    # seconds (wall-clock sensitive — asserted only where the host is
    # quiet).
    pmax = f"pods{max(pod_counts)}"
    claim = {}
    for app_name in ("mf", "lda"):
        row = out[app_name][pmax]
        ce, cl = row["eager"]["clocks_to_thresh"], \
            row["gated"]["clocks_to_thresh"]
        e, l = row["eager"]["wall_to_thresh_s"], \
            row["gated"]["wall_to_thresh_s"]
        claim[app_name] = {
            "eager_clocks": ce, "gated_clocks": cl,
            "eager_wall_s": e, "gated_wall_s": l,
            "pass_clocks": (ce is not None) and (cl is None or ce <= cl),
            "pass": (e is not None) and (l is None or e <= l),
        }
    claim["pass_clocks"] = all(claim[a]["pass_clocks"] for a in ("mf", "lda"))
    claim["pass"] = all(claim[a]["pass"] for a in ("mf", "lda"))
    out["claim"] = claim
    emit("pods_bench/eager_beats_gated_xpod", 0.0,
         f"mf={claim['mf']['pass']};lda={claim['lda']['pass']};"
         f"clocks={claim['pass_clocks']}")
    save_json("pods_bench", out)
    return out


if __name__ == "__main__":
    r = run()
    print(r["claim"])
