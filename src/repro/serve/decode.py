"""Batched serving: prefill + autoregressive decode against the KV cache."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.registry import Model


def greedy_sample(logits, rng=None, temperature: float = 0.0):
    if temperature and rng is not None:
        return jax.random.categorical(rng, logits[:, -1] / temperature)
    return jnp.argmax(logits[:, -1], axis=-1)


def make_serve_step(model: Model):
    """One decode step: (params, tokens [B,1], cache) -> (logits, cache).
    This is what the decode-shape dry-runs lower."""
    def serve_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return serve_step


def generate(model: Model, params, prompt_tokens, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             rng=None, extra_inputs: dict | None = None):
    """Prefill on the prompt then greedily decode ``max_new`` tokens.

    Returns [B, max_new] generated token ids.  ``extra_inputs`` carries
    modality stubs (frames / image_embeds) for audio/vlm models.
    """
    B, S = prompt_tokens.shape
    max_len = max_len or (S + max_new)
    cache = model.init_cache(B, max_len)
    batch = {"tokens": prompt_tokens, **(extra_inputs or {})}
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    rng, k0 = jax.random.split(rng)
    tok = greedy_sample(logits, k0, temperature)

    decode = jax.jit(model.decode_step)

    def body(carry, key):
        tok, cache = carry
        logits, cache = decode(params, {"tokens": tok[:, None]}, cache)
        nxt = greedy_sample(logits, key, temperature)
        return (nxt, cache), nxt

    keys = jax.random.split(rng, max_new)
    out = [tok]
    carry = (tok, cache)
    for k in keys[:-1]:
        carry, nxt = body(carry, k)
        out.append(nxt)
    return jnp.stack(out, axis=1)


def generate_scan(model: Model, params, prompt_tokens, max_new: int,
                  max_len: int | None = None, extra_inputs: dict | None = None):
    """Fully-jitted greedy generation (decode loop inside lax.scan)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + max_new)
    cache = model.init_cache(B, max_len)
    batch = {"tokens": prompt_tokens, **(extra_inputs or {})}

    @jax.jit
    def run(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)

        def body(carry, _):
            tok, cache = carry
            logits, cache = model.decode_step(
                params, {"tokens": tok[:, None]}, cache)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            return (nxt, cache), nxt

        (last, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                           length=max_new - 1)
        return jnp.concatenate([tok[:, None], toks.T], axis=1)

    return run(params, batch, cache)
