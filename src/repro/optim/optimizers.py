"""Optimizers (no optax in this environment): SGD, momentum, AdamW.

API mirrors optax minimally: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)`` where updates are
*additive* deltas (the PS "INC" convention — apply with tree_add).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd(lr: float | Callable = 1e-2) -> Optimizer:
    sched = lr if callable(lr) else (lambda step: lr)

    def init(_params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, _params=None):
        step = state["step"]
        g = sched(step)
        upd = jax.tree.map(lambda gr: (-g * gr.astype(jnp.float32)), grads)
        return upd, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr: float | Callable = 1e-2, beta: float = 0.9) -> Optimizer:
    sched = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)}

    def update(grads, state, _params=None):
        step = state["step"]
        mu = jax.tree.map(lambda m, gr: beta * m + gr.astype(jnp.float32),
                          state["mu"], grads)
        g = sched(step)
        upd = jax.tree.map(lambda m: -g * m, mu)
        return upd, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr: float | Callable = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          state_dtype=jnp.float32) -> Optimizer:
    """AdamW.  ``state_dtype=bfloat16`` halves optimizer memory — used for
    the 398B config to fit one v5e pod (documented in DESIGN.md)."""
    sched = lr if callable(lr) else (lambda step: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        sf = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** sf
        c2 = 1.0 - b2 ** sf

        def upd_m(m, gr):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * gr.astype(jnp.float32)).astype(state_dtype)

        def upd_v(v, gr):
            g32 = gr.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(state_dtype)

        m = jax.tree.map(upd_m, state["m"], grads)
        v = jax.tree.map(upd_v, state["v"], grads)
        g = sched(state["step"])

        def delta(mm, vv, pp):
            mhat = mm.astype(jnp.float32) / c1
            vhat = vv.astype(jnp.float32) / c2
            d = -g * (mhat / (jnp.sqrt(vhat) + eps)
                      + weight_decay * pp.astype(jnp.float32))
            return d

        upd = jax.tree.map(delta, m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    """params <- params + updates (PS INC semantics; dtype-preserving)."""
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1.0) / max(1, warmup))
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return sched


def inv_sqrt_schedule(base_lr: float, t0: float = 1.0):
    """The paper's η_t = η/sqrt(t) schedule (SGD theory sections)."""
    def sched(step):
        return base_lr / jnp.sqrt(t0 + step.astype(jnp.float32))
    return sched
