"""HLO-text analysis for the roofline model.

``compiled.cost_analysis()`` visits every computation **once** — ``while``
bodies (lax.scan layers, microbatch accumulation, blocked-attention chunk
loops) are not multiplied by their trip counts, so its flops/bytes are large
undercounts for scanned models, and it reports no collective bytes at all.

This module parses the optimized HLO text instead:

1. split the module into computations,
2. recover each ``while`` op's trip count from its condition computation
   (XLA emits ``compare(iv, constant(N)), direction=LT`` for lax.scan),
3. propagate execution multiplicity through the call graph
   (while bodies × trip count; call/conditional × 1),
4. accumulate per-computation:
   - matmul flops from ``dot`` instructions (2 · prod(result) · K, K from
     the printed contracting dims),
   - bytes accessed (operand + result sizes of real instructions),
   - collective bytes/counts by op kind.

Shapes in SPMD modules are per-device shard shapes, so every number below is
*per device* — exactly what the per-chip roofline needs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

# ops whose operand/result bytes we do NOT count as memory traffic
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "custom-call", "partition-id", "replica-id", "iota",
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^()]*\)|[\w\[\],{}/: ]+?))\s+"
    r"([\w\-]+)\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPERANDS_RE = re.compile(r"[\w\-]+\(([^()]*)\)")


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _all_shapes_bytes(text: str) -> int:
    return sum(shape_bytes(f"{dt}[{dims}]")
               for dt, dims in _SHAPE_RE.findall(text))


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    whiles: list = field(default_factory=list)   # (cond_name, body_name)
    calls: list = field(default_factory=list)    # called computation names


def _split_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_START_RE.match(stripped)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line)
    return comps


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)\s*$")


def _operand_names(line: str):
    """Operand %names of an instruction (from the first paren group).

    Depending on the XLA printer the operands appear bare (``%name``) or
    typed (``f32[64,64]{1,0} %name`` — scheduled modules); take the
    trailing %name either way."""
    m = _OPERANDS_RE.search(line)
    if not m:
        return []
    names = []
    for tok in m.group(1).split(","):
        nm = _OPERAND_NAME_RE.search(tok.strip())
        if nm:
            names.append(nm.group(1))
    return names


def _dot_flops(line: str, result_str: str, table: dict) -> float:
    """2 * prod(result) * K for a dot instruction line (operand shapes are
    looked up in the computation's symbol table — CPU HLO prints operands
    as bare %names)."""
    res_dims = _shape_dims(result_str)
    if res_dims is None:
        return 0.0
    ops = _operand_names(line)
    lhs_dims = _shape_dims(table.get(ops[0], "")) if ops else None
    if not lhs_dims:
        return 0.0
    mcd = _DOT_DIMS_RE.search(line)
    if mcd and mcd.group(1):
        cdims = [int(x) for x in mcd.group(1).split(",") if x]
        K = 1
        for c in cdims:
            if c < len(lhs_dims):
                K *= lhs_dims[c]
    else:
        K = lhs_dims[-1] if lhs_dims else 1
    n_res = 1
    for d in res_dims:
        n_res *= d
    return 2.0 * n_res * K


def _analyze_computation(comp: Computation):
    # symbol table: instruction name -> result type string
    table: dict[str, str] = {}
    for line in comp.lines:
        m = _INSTR_RE.match(line)
        dm = _DEF_RE.match(line)
        if m and dm:
            table[dm.group(1)] = m.group(1)
    for line in comp.lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_str, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base == "while":
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else None
                comp.whiles.append((wm.group(1), wm.group(2), trips))
            continue
        if base in ("call", "fusion", "reduce", "map", "sort", "scatter",
                    "select-and-scatter", "reduce-window", "all-reduce"):
            cm = _CALL_RE.search(line)
            if cm and base == "call":
                comp.calls.append(cm.group(1))
        if base == "conditional":
            bm = _BRANCH_RE.search(line)
            if bm:
                comp.calls.extend(
                    x.strip().lstrip("%") for x in bm.group(1).split(","))
        if base == "dot":
            comp.flops += _dot_flops(line, result_str, table)
        if base in COLLECTIVE_OPS:
            nbytes = _all_shapes_bytes(result_str)
            comp.coll_bytes[base] = comp.coll_bytes.get(base, 0) + nbytes
            comp.coll_count[base] = comp.coll_count.get(base, 0) + 1
        dm = _DEF_RE.match(line)
        instr_name = dm.group(1) if dm else ""
        if base in ("dynamic-update-slice", "scatter"):
            # in-place update: traffic = the update tensor (read) + the
            # written region (+ indices), NOT the whole target buffer
            # (XLA aliases the target).
            ops_ = _operand_names(line)
            upd_idx = 1 if base == "dynamic-update-slice" else 2
            upd = table.get(ops_[upd_idx], "") if len(ops_) > upd_idx else ""
            comp.bytes_accessed += 2 * _all_shapes_bytes(upd)
        elif base in ("dynamic-slice", "gather"):
            # read traffic = the fetched region, not the whole table
            comp.bytes_accessed += 2 * _all_shapes_bytes(result_str)
        elif base == "fusion" and "dynamic-update-slice" in instr_name:
            # XLA-CPU wraps in-place slice updates of loop carries in
            # fusions whose result is the whole carried buffer; charge the
            # written region (smallest real operand) instead.
            sizes = [s_ for s_ in (_all_shapes_bytes(table.get(n, ""))
                                   for n in _operand_names(line)) if s_ > 0]
            comp.bytes_accessed += 2 * (min(sizes) if sizes else 0)
        elif base == "fusion" and ("convert" in instr_name
                                   or "bitcast" in instr_name):
            # dtype-convert/slice-view fusions: charge the produced view
            # only — the (often whole-buffer) operand is merely sliced,
            # and the converted value is re-charged at its consumers.
            comp.bytes_accessed += _all_shapes_bytes(result_str)
        elif base not in _SKIP_BYTES_OPS:
            # bytes accessed ~ result bytes + operand bytes (via table)
            comp.bytes_accessed += _all_shapes_bytes(result_str)
            for name in _operand_names(line):
                comp.bytes_accessed += _all_shapes_bytes(table.get(name, ""))


def _trip_count(cond: Computation) -> int:
    """Trip count from a lax.scan-style condition: max int constant."""
    best = 1
    for line in cond.lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def collective_count(self) -> int:
        return int(sum(self.coll_count.values()))

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "total_bytes": self.collective_bytes,
            "total_count": self.collective_count,
            "bytes_by_op": dict(self.coll_bytes),
            "count_by_op": dict(self.coll_count),
        }


def analyze(hlo: str, entry: str | None = None) -> HloStats:
    """Multiplicity-aware flops / bytes / collective totals (per device)."""
    comps = _split_computations(hlo)
    for c in comps.values():
        _analyze_computation(c)

    if entry is None:
        for name in comps:
            if name.startswith("main") or ".main" in name:
                entry = name
                break
        else:
            entry = next(iter(comps))

    stats = HloStats()
    visited_stack: set = set()

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in visited_stack:
            return
        visited_stack.add(name)
        stats.flops += mult * comp.flops
        stats.bytes_accessed += mult * comp.bytes_accessed
        for k, v in comp.coll_bytes.items():
            stats.coll_bytes[k] = stats.coll_bytes.get(k, 0) + mult * v
        for k, v in comp.coll_count.items():
            stats.coll_count[k] = stats.coll_count.get(k, 0) + mult * v
        for cond_name, body_name, trips in comp.whiles:
            if trips is None:
                trips = (_trip_count(comps[cond_name])
                         if cond_name in comps else 1)
            visit(body_name, mult * trips)
            visit(cond_name, mult * trips)
        for callee in comp.calls:
            visit(callee, mult)
        visited_stack.discard(name)

    visit(entry, 1.0)
    return stats


# ---- legacy single-pass API (kept for tests / quick summaries) -----------
@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
        }


def collective_stats(hlo_text: str, multiplicity: bool = True) -> CollectiveStats:
    """Collective byte totals; multiplicity-aware by default."""
    out = CollectiveStats()
    if multiplicity:
        st = analyze(hlo_text)
        out.bytes_by_op = {k: int(v) for k, v in st.coll_bytes.items()}
        out.count_by_op = {k: int(v) for k, v in st.coll_count.items()}
        return out
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_str, opcode = m.groups()
        base = opcode
        if base.endswith("-start"):
            base = base[: -len("-start")]
        elif base.endswith("-done"):
            continue
        if base not in COLLECTIVE_OPS:
            continue
        nbytes = _all_shapes_bytes(result_str)
        out.bytes_by_op[base] = out.bytes_by_op.get(base, 0) + nbytes
        out.count_by_op[base] = out.count_by_op.get(base, 0) + 1
    return out


def count_op(hlo_text: str, opcode: str) -> int:
    n = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m and m.group(2) == opcode:
            n += 1
    return n
