"""Small pytree helpers (no external deps beyond jax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_norm(tree) -> jax.Array:
    """Global l2 norm over all leaves."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def tree_any_nan(tree) -> jax.Array:
    return jnp.any(jnp.stack([jnp.any(~jnp.isfinite(x.astype(jnp.float32)))
                              for x in jax.tree.leaves(tree)]))


def flatten_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def named_leaves(tree):
    """Yield (path_string, leaf) pairs."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield flatten_path(path), leaf
