"""Attention variants: GQA (+qk-norm, sliding window), MLA, cross-attention.

Each variant provides ``*_spec`` (ParamSpec tree), a full-sequence forward
(training/prefill) and a single-token decode path against a KV cache.

Cache layouts
-------------
GQA:   {"k": [B, C, Hkv, Dh], "v": [B, C, Hkv, Dh], "pos": [B] int32}
        where C = min(max_len, window or max_len); ring-buffer writes when a
        sliding window is configured.
MLA:   {"ckv": [B, C, R], "krope": [B, C, Dr], "pos": [B]} — the compressed
        KV latent is cached (the whole point of MLA), decompressed per read.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AttnConfig, MLAConfig
from .layers import head_rmsnorm, rope, shd, spec


NEG_INF = -1e30


# ==========================================================================
# masks
# ==========================================================================
def causal_mask(q_pos, k_pos, window=None):
    """Boolean [.., Sq, Sk] mask: k visible to q (causal, optional window)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return ok


def _sdpa(q, k, v, mask, scale):
    """q [B,Sq,H,Dh], k/v [B,Sk,Hkv,Dh] with GQA head repetition."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, Dh)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v)
    return out.reshape(B, Sq, H, Dh)


# ==========================================================================
# GQA attention
# ==========================================================================
def gqa_spec(cfg_attn: AttnConfig, d_model: int, dtype=jnp.float32):
    a = cfg_attn
    dh = a.head_dim if a.head_dim is not None else d_model // a.n_heads
    p = {
        "wq": spec((d_model, a.n_heads, dh), ("embed", "heads", "head_dim"),
                   dtype=dtype),
        "wk": spec((d_model, a.n_kv_heads, dh), ("embed", "kv_heads", "head_dim"),
                   dtype=dtype),
        "wv": spec((d_model, a.n_kv_heads, dh), ("embed", "kv_heads", "head_dim"),
                   dtype=dtype),
        "wo": spec((a.n_heads, dh, d_model), ("heads", "head_dim", "embed"),
                   dtype=dtype),
    }
    if a.qk_norm:
        p["q_norm"] = spec((dh,), ("head_dim",), init="ones", dtype=dtype)
        p["k_norm"] = spec((dh,), ("head_dim",), init="ones", dtype=dtype)
    return p


def _project_qkv(p, a: AttnConfig, x, positions):
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt))
    if a.qk_norm:
        q = head_rmsnorm(p["q_norm"], q)
        k = head_rmsnorm(p["k_norm"], k)
    q = rope(q, positions, a.rope_theta)
    k = rope(k, positions, a.rope_theta)
    return q, k, v


def gqa_forward(p, a: AttnConfig, x, positions=None):
    """Full-sequence attention, blocked (never materializes S x S logits)."""
    from ..kernels import ops
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, a, x, positions)
    q = shd(q, "batch", "seq", "heads", None)
    k = shd(k, "batch", "seq", "kv_heads", None)
    dh = q.shape[-1]
    out = ops.attention(q, k, v, scale=1.0 / np.sqrt(dh),
                        q_pos=positions, kv_pos=positions,
                        causal=a.causal, window=a.window)
    out = shd(out, "batch", "seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def gqa_init_cache(a: AttnConfig, d_model, batch, max_len, dtype):
    dh = a.head_dim if a.head_dim is not None else d_model // a.n_heads
    C = min(max_len, a.window) if a.window else max_len
    z = jnp.zeros((batch, C, a.n_kv_heads, dh), dtype)
    return {"k": z, "v": z,
            "pos": jnp.zeros((batch,), jnp.int32)}


def gqa_decode(p, a: AttnConfig, x, cache):
    """Single-token decode. x: [B,1,d]; returns (out [B,1,d], new cache).

    The cache is a ring buffer of size C (= window when sliding): slot
    ``pos % C`` is overwritten; visibility is decided by true positions.
    """
    B = x.shape[0]
    pos = cache["pos"]                                     # [B]
    q, k, v = _project_qkv(p, a, x, pos[:, None])
    C = cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    bidx = jnp.arange(B)
    knew = cache["k"].at[bidx, slot].set(k[:, 0])
    vnew = cache["v"].at[bidx, slot].set(v[:, 0])
    # true position of every cache slot given the ring write pattern
    slots = jnp.arange(C)[None, :]                          # [1, C]
    wraps = (pos[:, None] - slots + C) // C                 # writes so far
    slot_pos = slots + wraps * C - C                        # last write position
    slot_pos = jnp.where(slot_pos == pos[:, None], pos[:, None], slot_pos)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if a.window:
        valid &= slot_pos > (pos[:, None] - a.window)
    mask = valid[:, None, :]                                # [B, 1, C]
    dh = q.shape[-1]
    out = _sdpa(q, knew, vnew, mask, 1.0 / np.sqrt(dh))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, {"k": knew, "v": vnew, "pos": pos + 1}


def gqa_prefill_cache(p, a: AttnConfig, x, positions, cache):
    """Fill the cache from a full-sequence prefill (no sliding rewrap: the
    last C positions land in their ring slots)."""
    B, S, _ = x.shape
    _, k, v = _project_qkv(p, a, x, positions)
    C = cache["k"].shape[1]
    take = min(S, C)
    ks, vs = k[:, -take:], v[:, -take:]
    pos_tail = positions[:, -take:]
    slots = jnp.mod(pos_tail, C)
    bidx = jnp.arange(B)[:, None]
    knew = cache["k"].at[bidx, slots].set(ks)
    vnew = cache["v"].at[bidx, slots].set(vs)
    return {"k": knew, "v": vnew, "pos": positions[:, -1] + 1}


# ==========================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ==========================================================================
def mla_spec(a: AttnConfig, d_model: int, dtype=jnp.float32):
    m: MLAConfig = a.mla
    H = a.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": spec((d_model, H, qd), ("embed", "heads", "head_dim"), dtype=dtype),
        "w_dkv": spec((d_model, m.kv_lora_rank), ("embed", "kv_lora"), dtype=dtype),
        "w_krope": spec((d_model, m.qk_rope_head_dim), ("embed", None), dtype=dtype),
        "kv_norm": spec((m.kv_lora_rank,), ("kv_lora",), init="ones", dtype=dtype),
        "w_uk": spec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                     ("kv_lora", "heads", "head_dim"), dtype=dtype),
        "w_uv": spec((m.kv_lora_rank, H, m.v_head_dim),
                     ("kv_lora", "heads", "head_dim"), dtype=dtype),
        "wo": spec((H, m.v_head_dim, d_model), ("heads", "head_dim", "embed"),
                   dtype=dtype),
    }


def _mla_project(p, a: AttnConfig, x, positions):
    m = a.mla
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, a.rope_theta)
    ckv = x @ p["w_dkv"].astype(cdt)                        # [B,S,R]
    ckv = head_rmsnorm(p["kv_norm"], ckv)
    krope = x @ p["w_krope"].astype(cdt)                    # [B,S,Dr] (shared)
    krope = rope(krope[..., None, :], positions, a.rope_theta)[..., 0, :]
    return q_nope, q_rope, ckv, krope


def _mla_attend(p, a: AttnConfig, q_nope, q_rope, ckv, krope, mask):
    """Latent-space attention: scores via decompressed keys, values from the
    latent, computed without materializing per-head K/V of full length."""
    m = a.mla
    cdt = q_nope.dtype
    # absorb W_uk into the query: q_lat [B,S,H,R]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(cdt))
    scores = jnp.einsum("bshr,btr->bhst", q_lat, ckv)
    scores += jnp.einsum("bshk,btk->bhst", q_rope, krope)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = scores.astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cdt)
    ctx = jnp.einsum("bhst,btr->bshr", w, ckv)              # latent context
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(cdt))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))


def mla_forward(p, a: AttnConfig, x, positions=None):
    """Blocked latent attention: MLA is exactly MQA with shared "key" =
    [c_kv ; k_rope] and "value" = c_kv, queries [W_uk-absorbed q_nope ;
    q_rope] — so we reuse the blocked attention primitive (Dk != Dv)."""
    from ..kernels import ops
    m = a.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope, ckv, krope = _mla_project(p, a, x, positions)
    cdt = q_nope.dtype
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(cdt))
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)       # [B,S,H,R+Dr]
    k_cat = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]  # MQA
    v_lat = ckv[:, :, None, :]
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    ctx = ops.attention(q_cat, k_cat, v_lat, scale=scale,
                        q_pos=positions, kv_pos=positions,
                        causal=a.causal, window=a.window)   # [B,S,H,R]
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(cdt))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))


def mla_init_cache(a: AttnConfig, batch, max_len, dtype):
    m = a.mla
    C = min(max_len, a.window) if a.window else max_len
    return {
        "ckv": jnp.zeros((batch, C, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, C, m.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def mla_decode(p, a: AttnConfig, x, cache):
    B = x.shape[0]
    pos = cache["pos"]
    q_nope, q_rope, ckv, krope = _mla_project(p, a, x, pos[:, None])
    C = cache["ckv"].shape[1]
    slot = jnp.mod(pos, C)
    bidx = jnp.arange(B)
    ckv_new = cache["ckv"].at[bidx, slot].set(ckv[:, 0])
    krope_new = cache["krope"].at[bidx, slot].set(krope[:, 0])
    slots = jnp.arange(C)[None, :]
    wraps = (pos[:, None] - slots + C) // C
    slot_pos = slots + wraps * C - C
    slot_pos = jnp.where(slot_pos == pos[:, None], pos[:, None], slot_pos)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if a.window:
        valid &= slot_pos > (pos[:, None] - a.window)
    out = _mla_attend(p, a, q_nope, q_rope, ckv_new, krope_new,
                      valid[:, None, :])
    return out, {"ckv": ckv_new, "krope": krope_new, "pos": pos + 1}


def mla_prefill_cache(p, a: AttnConfig, x, positions, cache):
    B, S, _ = x.shape
    _, _, ckv, krope = _mla_project(p, a, x, positions)
    C = cache["ckv"].shape[1]
    take = min(S, C)
    slots = jnp.mod(positions[:, -take:], C)
    bidx = jnp.arange(B)[:, None]
    return {
        "ckv": cache["ckv"].at[bidx, slots].set(ckv[:, -take:]),
        "krope": cache["krope"].at[bidx, slots].set(krope[:, -take:]),
        "pos": positions[:, -1] + 1,
    }


# ==========================================================================
# cross-attention (VLM image layers, enc-dec)
# ==========================================================================
def cross_attn_spec(a: AttnConfig, d_model: int, dtype=jnp.float32):
    dh = a.head_dim if a.head_dim is not None else d_model // a.n_heads
    return {
        "wq": spec((d_model, a.n_heads, dh), ("embed", "heads", "head_dim"),
                   dtype=dtype),
        "wk": spec((d_model, a.n_kv_heads, dh), ("embed", "kv_heads", "head_dim"),
                   dtype=dtype),
        "wv": spec((d_model, a.n_kv_heads, dh), ("embed", "kv_heads", "head_dim"),
                   dtype=dtype),
        "wo": spec((a.n_heads, dh, d_model), ("heads", "head_dim", "embed"),
                   dtype=dtype),
    }


def cross_attn_kv(p, mem):
    """Precompute cross-attention K/V from encoder/vision memory [B,M,d]."""
    cdt = mem.dtype
    k = jnp.einsum("bmd,dhk->bmhk", mem, p["wk"].astype(cdt))
    v = jnp.einsum("bmd,dhk->bmhk", mem, p["wv"].astype(cdt))
    return k, v


def cross_attn(p, _a: AttnConfig, x, mem_kv):
    """x [B,S,d] attends to precomputed memory K/V (no positional enc)."""
    k, v = mem_kv
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    B, S = q.shape[:2]
    M = k.shape[1]
    mask = jnp.ones((B, S, M), bool)
    out = _sdpa(q, k, v, mask, 1.0 / np.sqrt(q.shape[-1]))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
