"""Top-level Model API: specs, forward, prefill, decode for every family.

`build_model(cfg)` returns a `Model` whose methods are pure functions of
(params, batch) — ready for `jax.jit`/`pjit` with shardings from
`launch.sharding`.  Batch conventions:

  train/prefill:  {"tokens": [B,S] int32, ("frames"|"image_embeds")...}
  decode:         {"tokens": [B,1] int32} + cache pytree

Modality stubs (assignment carve-out): whisper takes ``frames``
[B, enc_ctx, d] and VLMs take ``image_embeds`` [B, n_img, d] — precomputed
frontend embeddings provided by ``input_specs``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import encdec, transformer as tf
from .layers import embed, embed_spec, rmsnorm, rmsnorm_spec, shd, unembed
from .params import init_params, param_count, spec


@dataclass
class Model:
    cfg: ModelConfig
    param_specs: Any
    forward: Callable          # (params, batch) -> (logits, aux)
    init_cache: Callable       # (batch, max_len, dtype) -> cache
    prefill: Callable          # (params, batch, cache) -> (logits_last, cache)
    decode_step: Callable      # (params, batch, cache) -> (logits, cache)

    def init(self, rng):
        return init_params(self.param_specs, rng)

    @property
    def n_params(self) -> int:
        return param_count(self.param_specs)


def _n_outer(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "vlm":
        assert cfg.n_layers % cfg.vision.cross_attn_every == 0
        return cfg.n_layers // cfg.vision.cross_attn_every
    return cfg.n_layers


def build_model(cfg: ModelConfig) -> Model:
    dtype = cfg.pdtype
    n_outer = _n_outer(cfg)

    # ---------------- parameter specs ------------------------------------
    if cfg.family == "audio":
        body = encdec.encdec_specs(cfg, dtype)
    elif cfg.family == "hybrid":
        body = {"blocks": tf.stack_specs(n_outer, tf.hybrid_group_spec(cfg, dtype))}
    elif cfg.family == "vlm":
        body = {"blocks": tf.stack_specs(n_outer, tf.vlm_group_spec(cfg, dtype))}
    elif cfg.family == "ssm":
        body = {"blocks": tf.stack_specs(n_outer, tf.mamba_block_spec(cfg, dtype))}
    else:  # dense | moe
        body = {"blocks": tf.stack_specs(n_outer, tf.block_spec(cfg, dtype))}

    specs = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model, dtype),
        **body,
        "final_norm": rmsnorm_spec(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = spec((cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"), dtype=dtype)

    def logits_of(params, x):
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        x = shd(x, "batch", "seq", "embed")
        if cfg.tie_embeddings:
            out = unembed(params["embed"], x)
        else:
            out = x @ params["lm_head"].astype(x.dtype)
        return shd(out, "batch", "seq", "vocab")

    def embed_tokens(params, tokens):
        x = embed(params["embed"], tokens, cfg.cdtype)
        return shd(x, "batch", "seq", "embed")

    # ---------------- forward (train / full-seq) --------------------------
    def forward(params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embed_tokens(params, tokens)

        if cfg.family == "audio":
            memory = encdec.encode(params, cfg, batch["frames"].astype(cfg.cdtype))
            x = encdec.decoder_forward(params, cfg, x, positions, memory)
            aux = 0.0
        elif cfg.family == "hybrid":
            fn = lambda p, x: tf.hybrid_group_fwd(p, cfg, x, positions)
            x, aux = tf._scan_blocks(fn, params["blocks"], x, 0.0, cfg.remat,
                                     cfg.scan_layers)
        elif cfg.family == "vlm":
            mem = batch["image_embeds"].astype(cfg.cdtype)
            fn = lambda p, x: tf.vlm_group_fwd(p, cfg, x, positions, mem)
            x, aux = tf._scan_blocks(fn, params["blocks"], x, 0.0, cfg.remat,
                                     cfg.scan_layers)
        elif cfg.family == "ssm":
            fn = lambda p, x: tf.mamba_block_fwd(p, cfg, x)
            x, aux = tf._scan_blocks(fn, params["blocks"], x, 0.0, cfg.remat,
                                     cfg.scan_layers)
        else:
            fn = lambda p, x: tf.block_fwd(p, cfg, x, positions)
            x, aux = tf._scan_blocks(fn, params["blocks"], x, 0.0, cfg.remat,
                                     cfg.scan_layers)
        return logits_of(params, x), aux

    # ---------------- caches ----------------------------------------------
    def init_cache(batch, max_len, dtype_=None):
        dt = dtype_ or cfg.cdtype
        if cfg.family == "audio":
            return encdec.decoder_cache(cfg, batch, max_len, dt)
        if cfg.family == "hybrid":
            one = tf.hybrid_group_cache(cfg, batch, max_len, dt)
        elif cfg.family == "vlm":
            one = tf.vlm_group_cache(cfg, batch, max_len, dt)
        elif cfg.family == "ssm":
            from . import mamba2
            one = mamba2.mamba_init_cache(cfg.mamba, cfg.d_model, batch, dt)
        else:
            one = tf._attn_cache(cfg, batch, max_len, dt)
        return jax.tree.map(
            lambda v: jnp.broadcast_to(v, (n_outer,) + v.shape).copy(), one)

    # ---------------- decode step -----------------------------------------
    def decode_step(params, batch, cache):
        tokens = batch["tokens"]                      # [B, 1]
        x = embed_tokens(params, tokens)
        if cfg.family == "audio":
            x, cache = encdec.decoder_decode_step(params, cfg, x, cache)
        else:
            if cfg.family == "hybrid":
                fn = lambda p, x, c: tf.hybrid_group_decode(p, cfg, x, c)
            elif cfg.family == "vlm":
                fn = lambda p, x, c: tf.vlm_group_decode(p, cfg, x, c)
            elif cfg.family == "ssm":
                fn = lambda p, x, c: tf.mamba_block_decode(p, cfg, x, c)
            else:
                fn = lambda p, x, c: tf.block_decode(p, cfg, x, c)
            x, cache = tf._scan_blocks_cache(fn, params["blocks"], cache, x)
        return logits_of(params, x), cache

    # ---------------- prefill ----------------------------------------------
    def prefill(params, batch, cache):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embed_tokens(params, tokens)
        if cfg.family == "audio":
            memory = encdec.encode(params, cfg, batch["frames"].astype(cfg.cdtype))
            x, cache = encdec.decoder_prefill(params, cfg, x, positions,
                                              cache, memory)
        else:
            if cfg.family == "hybrid":
                fn = lambda p, x, c: tf.hybrid_group_prefill(p, cfg, x,
                                                             positions, c)
            elif cfg.family == "vlm":
                mem = batch["image_embeds"].astype(cfg.cdtype)
                fn = lambda p, x, c: tf.vlm_group_prefill(p, cfg, x, positions,
                                                          c, mem)
            elif cfg.family == "ssm":
                def fn(p, x, c):
                    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
                    h, st = tf._mamba_forward_with_state(p["mixer"], cfg, xn)
                    conv = tf._mamba_conv_tail(p["mixer"], cfg, xn, c["conv"])
                    return x + h, {"conv": conv, "ssm": st,
                                   "pos": positions[:, -1] + 1}
            else:
                fn = lambda p, x, c: tf.block_prefill(p, cfg, x, positions, c)
            x, cache = tf._scan_blocks_cache(fn, params["blocks"], cache, x)
        logits = logits_of(params, x[:, -1:])
        return logits, cache


    return Model(cfg=cfg, param_specs=specs, forward=forward,
                 init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)
