"""Parameter-spec trees: one source of truth for init, shapes and sharding.

No flax in this environment, so we roll a minimal functional parameter
system.  A model is described by a nested dict of `ParamSpec`s; from that
single tree we derive:

- materialized parameters (`init_params`, per-path PRNG folding),
- `jax.ShapeDtypeStruct` stand-ins with `NamedSharding` attached
  (`shape_structs`) for `.lower()`-based dry-runs without allocation,
- sharding trees (`shardings`) for `jax.jit` in/out specs.

Every spec carries *logical axis names* (e.g. ``("vocab", "embed")``); the
launcher maps logical names to mesh axes with a rules table
(`repro.launch.sharding`).  This is the t5x/MaxText idiom, minus the
dependency.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple  # tuple[str | None, ...]


@dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: tuple
    axes: Axes                    # logical axis name per dim (None = replicated)
    init: str = "normal"          # normal | zeros | ones | scaled | embed
    scale: float | None = None    # stddev override; default fan-in scaled
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")


def spec(shape, axes, init="normal", scale=None, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, scale, dtype)


def _fan_in(shape) -> int:
    # last-but-one dim heuristic: weights are [..., in, out]
    return int(shape[-2]) if len(shape) >= 2 else int(shape[-1])


def _init_one(ps: ParamSpec, key) -> jax.Array:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, ps.dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, ps.dtype)
    if ps.init == "embed":
        std = ps.scale if ps.scale is not None else 1.0
        return (std * jax.random.normal(key, ps.shape)).astype(ps.dtype)
    # normal / scaled: truncated-normal, fan-in scaled
    std = ps.scale if ps.scale is not None else 1.0 / np.sqrt(max(1, _fan_in(ps.shape)))
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, ps.shape)).astype(ps.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn: Callable[[str, ParamSpec], Any], tree, prefix=""):
    if is_spec(tree):
        return fn(prefix, tree)
    if isinstance(tree, Mapping):
        return {k: _map_specs(fn, v, f"{prefix}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_specs(fn, v, f"{prefix}/{i}")
                          for i, v in enumerate(tree))
    raise TypeError(f"unexpected node at {prefix}: {type(tree)}")


def init_params(specs, rng) -> Any:
    """Materialize a spec tree; PRNG folded per path for determinism."""
    def make(path, ps):
        key = jax.random.fold_in(rng, zlib_crc(path))
        return _init_one(ps, key)
    return _map_specs(make, specs)


def zlib_crc(s: str) -> int:
    import zlib
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def cast_params(specs, dtype):
    """Return a spec tree with every float param cast to ``dtype``."""
    def cast(_path, ps):
        if jnp.issubdtype(ps.dtype, jnp.floating):
            return dataclasses.replace(ps, dtype=dtype)
        return ps
    return _map_specs(cast, specs)


def logical_to_sharding(axes: Axes, mesh, rules: Mapping[str, Any]):
    """Map logical axis names to a NamedSharding via a rules table.

    ``rules[name]`` is a mesh-axis name, a tuple of mesh axes, or None.
    Mesh axes already consumed by an earlier dim are dropped (a mesh axis may
    shard only one dim of a given tensor).
    """
    from jax.sharding import NamedSharding, PartitionSpec
    used: set = set()
    out = []
    for name in axes:
        assign = rules.get(name) if name is not None else None
        if assign is None:
            out.append(None)
            continue
        maxes = (assign,) if isinstance(assign, str) else tuple(assign)
        maxes = tuple(a for a in maxes
                      if a in mesh.axis_names and a not in used)
        # drop axes that do not divide the dim? checked by caller per shape
        if not maxes:
            out.append(None)
        elif len(maxes) == 1:
            out.append(maxes[0]); used.update(maxes)
        else:
            out.append(maxes); used.update(maxes)
    return NamedSharding(mesh, PartitionSpec(*out))


def _divisible(shape, sharding) -> bool:
    from jax.sharding import PartitionSpec
    spec_ = sharding.spec
    mesh = sharding.mesh
    for dim, names in zip(shape, tuple(spec_) + (None,) * (len(shape) - len(spec_)),
                          strict=True):
        if names is None:
            continue
        names = (names,) if isinstance(names, str) else names
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if dim % total != 0:
            return False
    return True


def shardings(specs, mesh, rules):
    """NamedSharding tree for a spec tree (replicating non-divisible dims)."""
    from jax.sharding import NamedSharding, PartitionSpec

    def one(_path, ps):
        sh = logical_to_sharding(ps.axes, mesh, rules)
        if not _divisible(ps.shape, sh):
            # drop offending axes one by one (keep what divides)
            names = []
            used = set()
            for dim, ax in zip(ps.shape,
                               sh.spec + (None,) * (len(ps.shape) - len(sh.spec)),
                               strict=True):
                if ax is None:
                    names.append(None); continue
                axs = (ax,) if isinstance(ax, str) else tuple(ax)
                keep = []
                for a in axs:
                    size = mesh.shape[a]
                    cur = int(np.prod([mesh.shape[k] for k in keep])) if keep else 1
                    if dim % (cur * size) == 0 and a not in used:
                        keep.append(a)
                used.update(keep)
                names.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
            sh = NamedSharding(mesh, PartitionSpec(*names))
        return sh
    return _map_specs(one, specs)


def shape_structs(specs, mesh=None, rules=None):
    """ShapeDtypeStruct tree (with shardings if mesh given) — dry-run inputs."""
    shard_tree = shardings(specs, mesh, rules) if mesh is not None else None

    def one(path, ps):
        if shard_tree is None:
            return jax.ShapeDtypeStruct(ps.shape, ps.dtype)
        # look up the matching sharding by path
        return jax.ShapeDtypeStruct(ps.shape, ps.dtype,
                                    sharding=_lookup(shard_tree, path))
    def _lookup(tree, path):
        node = tree
        for part in path.strip("/").split("/"):
            if isinstance(node, Mapping):
                node = node[part]
            else:
                node = node[int(part)]
        return node
    return _map_specs(one, specs)


def param_count(specs) -> int:
    total = 0

    def count(_path, ps):
        nonlocal total
        total += int(np.prod(ps.shape))
        return ps
    _map_specs(count, specs)
    return total
