"""Mamba-2 block (SSD — state-space duality form, arXiv:2405.21060).

Forward path: in_proj -> short causal conv (x, B, C streams) -> SSD scan
(chunked dual form; Pallas kernel on TPU) -> gated RMSNorm -> out_proj.

Decode path: single-token recurrence with carried (conv window, SSM state).

Cache layout: {"conv": [B, W-1, d_conv], "ssm": [B, H, P, N], "pos": [B]}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MambaConfig
from .layers import rmsnorm, shd, spec


def dims(cfg: MambaConfig, d_model: int):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.headdim
    n_groups = max(1, n_heads // 8)  # B/C groups (tensor-parallel friendly)
    d_conv = d_inner + 2 * n_groups * cfg.d_state
    return d_inner, n_heads, n_groups, d_conv


def mamba_spec(cfg: MambaConfig, d_model: int, dtype=jnp.float32):
    d_inner, H, G, d_conv = dims(cfg, d_model)
    return {
        # projections for [z (gate), x, B, C, dt]
        "in_proj": spec((d_model, 2 * d_inner + 2 * G * cfg.d_state + H),
                        ("embed", "mlp"), dtype=dtype),
        "conv_w": spec((cfg.conv_width, d_conv), (None, "mlp"),
                       scale=0.3, dtype=dtype),
        "conv_b": spec((d_conv,), ("mlp",), init="zeros", dtype=dtype),
        "a_log": spec((H,), ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": spec((H,), ("heads",), init="zeros", dtype=jnp.float32),
        "d_skip": spec((H,), ("heads",), init="ones", dtype=jnp.float32),
        "norm_scale": spec((d_inner,), ("mlp",), init="ones", dtype=dtype),
        "out_proj": spec((d_inner, d_model), ("mlp", "embed"), dtype=dtype),
    }


def _split(cfg: MambaConfig, d_model: int, zxbcdt):
    d_inner, H, G, _ = dims(cfg, d_model)
    n = cfg.d_state
    z, xin, Braw, Craw, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + G * n, 2 * d_inner + 2 * G * n],
        axis=-1)
    return z, xin, Braw, Craw, dt


def _gated_norm(p, y, z, eps=1e-5):
    """Mamba-2's RMSNorm(y * silu(z)) with learned scale."""
    h = y * jax.nn.silu(z)
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    out = hf * jax.lax.rsqrt(var + eps) * p["norm_scale"].astype(jnp.float32)
    return out.astype(y.dtype)


def mamba_forward(p, cfg: MambaConfig, d_model: int, x):
    """x [B, S, d_model] -> [B, S, d_model].  S must divide by cfg.chunk
    (the stack pads positions; configs guarantee divisibility)."""
    from ..kernels import ops
    B, S, _ = x.shape
    d_inner, H, G, d_conv = dims(cfg, d_model)
    n = cfg.d_state
    cdt = x.dtype

    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xin, Braw, Craw, dt = _split(cfg, d_model, zxbcdt)

    # short causal conv over the (x, B, C) streams
    xbc = jnp.concatenate([xin, Braw, Craw], axis=-1)       # [B,S,d_conv]
    w = p["conv_w"].astype(cdt)                              # [W, d_conv]
    pad = cfg.conv_width - 1
    xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xbc_p[:, i:i + S] * w[i] for i in range(cfg.conv_width))
    conv = jax.nn.silu(conv + p["conv_b"].astype(cdt))
    xin, Braw, Craw = jnp.split(conv, [d_inner, d_inner + G * n], axis=-1)

    xh = xin.reshape(B, S, H, cfg.headdim)
    Bm = Braw.reshape(B, S, G, n)
    Cm = Craw.reshape(B, S, G, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["a_log"])                                 # [H], negative

    xh = shd(xh, "batch", "seq", "heads", None)
    y, _ = ops.ssd(xh, dt, A, Bm, Cm, chunk=cfg.chunk)
    y = y + p["d_skip"].astype(cdt)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = _gated_norm(p, y, z)
    return y @ p["out_proj"].astype(cdt)


def mamba_init_cache(cfg: MambaConfig, d_model: int, batch: int, dtype):
    d_inner, H, G, d_conv = dims(cfg, d_model)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_conv), dtype),
        "ssm": jnp.zeros((batch, H, cfg.headdim, cfg.d_state), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def mamba_decode(p, cfg: MambaConfig, d_model: int, x, cache):
    """Single-token recurrent step. x [B,1,d_model]."""
    from ..kernels import ops
    B = x.shape[0]
    d_inner, H, G, d_conv = dims(cfg, d_model)
    n = cfg.d_state
    cdt = x.dtype

    zxbcdt = (x[:, 0] @ p["in_proj"].astype(cdt))
    z, xin, Braw, Craw, dt = _split(cfg, d_model, zxbcdt)

    xbc = jnp.concatenate([xin, Braw, Craw], axis=-1)       # [B, d_conv]
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # [B,W,d_conv]
    w = p["conv_w"].astype(cdt)
    conv = jnp.einsum("bwd,wd->bd", hist, w)
    conv = jax.nn.silu(conv + p["conv_b"].astype(cdt))
    xin, Braw, Craw = jnp.split(conv, [d_inner, d_inner + G * n], axis=-1)

    xh = xin.reshape(B, H, cfg.headdim)
    Bm = Braw.reshape(B, G, n)
    Cm = Craw.reshape(B, G, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["a_log"])

    y, ssm = ops.ssd_decode(xh, dt, A, Bm, Cm, cache["ssm"])
    y = y + p["d_skip"].astype(cdt)[None, :, None] * xh
    y = y.reshape(B, d_inner).astype(cdt)
    y = _gated_norm(p, y, z)
    out = (y @ p["out_proj"].astype(cdt))[:, None]
    new_cache = {"conv": hist[:, 1:].astype(cache["conv"].dtype),
                 "ssm": ssm.astype(cache["ssm"].dtype),
                 "pos": cache["pos"] + 1}
    return out, new_cache
