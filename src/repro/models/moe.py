"""Mixture-of-Experts layer: top-k routing with per-sequence capacity.

Implementation strategy (TPU/pjit friendly, scales to 128 experts × 1M
tokens): we avoid the Mesh-TensorFlow one-hot dispatch *mask* ([tokens, E,
capacity] — infeasible at assigned scales) and instead build gather/scatter
indices per token block.  Blocks are the batch dim (one sequence per block),
so the block axis shards over ("pod","data") like every other activation,
and expert weights shard over "model" (expert parallelism).  The scatter to
``[block, E, capacity, d]`` followed by expert einsum is then partitioned by
XLA into the standard all-to-all dispatch pattern.

Capacity per block: C = ceil(S·top_k/E · capacity_factor) (tokens above
capacity are dropped — the classic Switch/GShard behaviour; the aux loss
keeps the router balanced).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .layers import shd, spec


def moe_spec(cfg: MoEConfig, d_model: int, dtype=jnp.float32):
    E, ff = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": spec((d_model, E), ("embed", "experts"), scale=0.02,
                       dtype=jnp.float32),   # router kept in f32 (standard)
        "wi_gate": spec((E, d_model, ff), ("experts", "embed", "mlp"), dtype=dtype),
        "wi_up": spec((E, d_model, ff), ("experts", "embed", "mlp"), dtype=dtype),
        "wo": spec((E, ff, d_model), ("experts", "mlp", "embed"), dtype=dtype),
    }
    if cfg.n_shared:
        sff = ff * cfg.n_shared
        p["shared_wi_gate"] = spec((d_model, sff), ("embed", "mlp"), dtype=dtype)
        p["shared_wi_up"] = spec((d_model, sff), ("embed", "mlp"), dtype=dtype)
        p["shared_wo"] = spec((sff, d_model), ("mlp", "embed"), dtype=dtype)
    return p


def _capacity(S: int, cfg: MoEConfig) -> int:
    c = int(S * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    c = -(-c // 4) * 4 if c > 4 else c      # round up to multiple of 4
    return min(max(c, 1), S)


def moe_forward(p, cfg: MoEConfig, x):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)
    cdt = x.dtype

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                    # [B,S,K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) ----------------------
    me = jnp.mean(probs, axis=(0, 1))                       # mean router prob
    one_hot_top1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))                # expert load
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- build per-block dispatch slots ----------------------------------
    # flatten (S, K) assignment list per block, ordered by position so the
    # earliest tokens win capacity (GShard behaviour).
    e_flat = eidx.reshape(B, S * K)                         # [B, N]
    g_flat = gate.reshape(B, S * K).astype(cdt)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # [B, N, E]
    pos_in_e = jnp.cumsum(oh, axis=1) - oh                  # rank within expert
    slot_pos = jnp.take_along_axis(pos_in_e, e_flat[..., None], -1)[..., 0]
    keep = slot_pos < C                                      # [B, N]
    slot = e_flat * C + slot_pos                             # [B, N] in [0, E*C)
    slot = jnp.where(keep, slot, E * C)                      # overflow -> drop row

    # ---- dispatch: scatter tokens into [B, E*C(+1), d] --------------------
    tok = jnp.repeat(x, K, axis=1)                           # [B, N, d] token per assignment
    xe = jnp.zeros((B, E * C + 1, d), cdt)
    xe = jax.vmap(lambda buf, idx, val: buf.at[idx].set(val))(xe, slot, tok)
    xe = xe[:, : E * C].reshape(B, E, C, d)
    xe = shd(xe, "batch", "experts", None, "embed")

    # ---- expert computation ----------------------------------------------
    h_g = jnp.einsum("becd,edf->becf", xe, p["wi_gate"].astype(cdt))
    h_u = jnp.einsum("becd,edf->becf", xe, p["wi_up"].astype(cdt))
    h = jax.nn.silu(h_g) * h_u
    h = shd(h, "batch", "experts", None, "mlp")
    ye = jnp.einsum("becf,efd->becd", h, p["wo"].astype(cdt))

    # ---- combine: gather back and weight by gate --------------------------
    ye_flat = ye.reshape(B, E * C, d)
    ye_flat = jnp.concatenate([ye_flat, jnp.zeros((B, 1, d), cdt)], axis=1)
    back = jax.vmap(lambda buf, idx: buf[idx])(ye_flat, slot)  # [B, N, d]
    back = back * (g_flat * keep.astype(cdt))[..., None]
    y = back.reshape(B, S, K, d).sum(axis=2)

    # ---- shared experts (DeepSeek-style, always on) -----------------------
    if "shared_wi_gate" in p:
        sg = x @ p["shared_wi_gate"].astype(cdt)
        su = x @ p["shared_wi_up"].astype(cdt)
        y = y + (jax.nn.silu(sg) * su) @ p["shared_wo"].astype(cdt)
    return y, aux
