"""Shared neural-net building blocks (pure functions + ParamSpec builders)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import spec


# --------------------------------------------------------------------------
# activation sharding hook: launch/sharding.py installs the active rules;
# models annotate activations with logical axis names.
# --------------------------------------------------------------------------
_ACTIVATION_RULES: list = []


def push_rules(mesh, rules):
    _ACTIVATION_RULES.append((mesh, rules))


def pop_rules():
    _ACTIVATION_RULES.pop()


def shd(x, *axes):
    """Apply a sharding constraint by logical axis names (no-op outside a
    launch context)."""
    if not _ACTIVATION_RULES:
        return x
    mesh, rules = _ACTIVATION_RULES[-1]
    from jax.sharding import NamedSharding, PartitionSpec
    used: set = set()
    names = []
    for i, a in enumerate(axes):
        assign = rules.get(a) if a is not None else None
        if assign is None:
            names.append(None)
            continue
        maxes = (assign,) if isinstance(assign, str) else tuple(assign)
        maxes = tuple(m for m in maxes if m in mesh.axis_names and m not in used)
        total = 1
        for m in maxes:
            total *= mesh.shape[m]
        if not maxes or x.shape[i] % total != 0:
            names.append(None)
            continue
        used.update(maxes)
        names.append(maxes if len(maxes) > 1 else maxes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*names)))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm_spec(d, dtype=jnp.float32):
    return {"scale": spec((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(scale, x, eps=1e-5):
    """qwen3-style per-head q/k norm: x [..., H, Dh], scale [Dh]."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope(x, positions, theta=10000.0):
    """Apply rotary embedding. x: [..., S, H, Dh], positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                       # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_spec(d, ff, act="swiglu", dtype=jnp.float32):
    if act == "swiglu":
        return {
            "wi_gate": spec((d, ff), ("embed", "mlp"), dtype=dtype),
            "wi_up": spec((d, ff), ("embed", "mlp"), dtype=dtype),
            "wo": spec((ff, d), ("mlp", "embed"), dtype=dtype),
        }
    return {
        "wi": spec((d, ff), ("embed", "mlp"), dtype=dtype),
        "wo": spec((ff, d), ("mlp", "embed"), dtype=dtype),
    }


def mlp(p, x, act="swiglu"):
    cdt = x.dtype
    if act == "swiglu":
        g = x @ p["wi_gate"].astype(cdt)
        u = x @ p["wi_up"].astype(cdt)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(cdt))
    h = shd(h, "batch", "seq", "mlp")
    return h @ p["wo"].astype(cdt)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------
def embed_spec(vocab, d, dtype=jnp.float32):
    return {"embedding": spec((vocab, d), ("vocab", "embed"),
                              init="embed", scale=1.0, dtype=dtype)}


def embed(p, tokens, cdtype):
    return p["embedding"].astype(cdtype)[tokens]


def unembed(p, x):
    return x @ p["embedding"].astype(x.dtype).T


def linear_spec(d_in, d_out, axes=("embed", None), dtype=jnp.float32,
                init="normal", scale=None):
    return spec((d_in, d_out), axes, init=init, scale=scale, dtype=dtype)
