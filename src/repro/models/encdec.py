"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + conv downsampling) is stubbed per the assignment
carve-out: ``frames`` inputs are precomputed frame embeddings
[B, n_ctx, d_model].  We implement the transformer: a non-causal encoder and
a causal decoder with per-layer cross-attention, plus the decode path with
self-attn KV cache + precomputed cross KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from .layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec, shd, spec
from .transformer import _attn_cache, _attn_decode, _attn_prefill, _attn_fwd, stack_specs


def encoder_layer_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": rmsnorm_spec(cfg.d_model, dtype),
        "attn": attn_mod.gqa_spec(cfg.attn, cfg.d_model, dtype),
        "ln2": rmsnorm_spec(cfg.d_model, dtype),
        "ffn": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def decoder_layer_spec(cfg: ModelConfig, dtype):
    s = encoder_layer_spec(cfg, dtype)
    s["lnx"] = rmsnorm_spec(cfg.d_model, dtype)
    s["xattn"] = attn_mod.cross_attn_spec(cfg.attn, cfg.d_model, dtype)
    return s


def encdec_specs(cfg: ModelConfig, dtype):
    enc_layers = cfg.encoder.n_layers
    return {
        "enc_pos": spec((cfg.encoder.n_ctx, cfg.d_model), (None, "embed"),
                        init="embed", scale=0.02, dtype=dtype),
        "encoder": stack_specs(enc_layers, encoder_layer_spec(cfg, dtype)),
        "enc_norm": rmsnorm_spec(cfg.d_model, dtype),
        "decoder": stack_specs(cfg.n_layers, decoder_layer_spec(cfg, dtype)),
    }


def _nc_attn_cfg(cfg: ModelConfig):
    import dataclasses
    return dataclasses.replace(cfg.attn, causal=False, window=None)


def encode(p, cfg: ModelConfig, frames):
    """frames [B, n_ctx, d_model] (stub frontend output) -> memory."""
    x = frames + p["enc_pos"].astype(frames.dtype)[None]
    a_nc = _nc_attn_cfg(cfg)

    def layer(pl, x):
        x = shd(x, "batch", "seq_res", "embed")
        h = attn_mod.gqa_forward(pl["attn"], a_nc,
                                 rmsnorm(pl["ln1"], x, cfg.norm_eps))
        x = x + h
        x = x + mlp(pl["ffn"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg.act)
        return x, 0.0

    from .transformer import _scan_blocks
    x, _ = _scan_blocks(layer, p["encoder"], x, 0.0, cfg.remat)
    return rmsnorm(p["enc_norm"], x, cfg.norm_eps)


def decoder_forward(p, cfg: ModelConfig, x, positions, memory):
    """Causal decoder over token embeddings x, cross-attending to memory."""
    def layer(pl, x):
        x = shd(x, "batch", "seq_res", "embed")
        h = _attn_fwd(pl["attn"], cfg,
                      rmsnorm(pl["ln1"], x, cfg.norm_eps), positions)
        x = x + h
        mem_kv = attn_mod.cross_attn_kv(pl["xattn"], memory)
        h = attn_mod.cross_attn(pl["xattn"], cfg.attn,
                                rmsnorm(pl["lnx"], x, cfg.norm_eps), mem_kv)
        x = x + h
        x = x + mlp(pl["ffn"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg.act)
        return x, 0.0

    from .transformer import _scan_blocks
    x, _ = _scan_blocks(layer, p["decoder"], x, 0.0, cfg.remat)
    return x


def decoder_cache(cfg: ModelConfig, batch, max_len, dtype):
    self_c = _attn_cache(cfg, batch, max_len, dtype)
    dh = cfg.head_dim
    memkv = jnp.zeros((batch, cfg.encoder.n_ctx, cfg.attn.n_kv_heads, dh),
                      dtype)
    one = {"self": self_c, "cross_k": memkv, "cross_v": memkv}
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v, (cfg.n_layers,) + v.shape).copy(), one)


def decoder_decode_step(p, cfg: ModelConfig, x, caches):
    """One decoder token against stacked caches (cross KV precomputed)."""
    def layer(x, inp):
        pl, cl = inp
        h, c_new = _attn_decode(pl["attn"], cfg,
                                rmsnorm(pl["ln1"], x, cfg.norm_eps),
                                cl["self"])
        x = x + h
        h = attn_mod.cross_attn(pl["xattn"], cfg.attn,
                                rmsnorm(pl["lnx"], x, cfg.norm_eps),
                                (cl["cross_k"], cl["cross_v"]))
        x = x + h
        x = x + mlp(pl["ffn"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg.act)
        return x, dict(cl, self=c_new)

    return jax.lax.scan(layer, x, (p["decoder"], caches))


def decoder_prefill(p, cfg: ModelConfig, x, positions, caches, memory):
    """Prefill decoder self caches and compute/populate cross KV."""
    def layer(x, inp):
        pl, cl = inp
        xn = rmsnorm(pl["ln1"], x, cfg.norm_eps)
        c_new = _attn_prefill(pl["attn"], cfg, xn, positions, cl["self"])
        x = x + _attn_fwd(pl["attn"], cfg, xn, positions)
        mem_k, mem_v = attn_mod.cross_attn_kv(pl["xattn"], memory)
        h = attn_mod.cross_attn(pl["xattn"], cfg.attn,
                                rmsnorm(pl["lnx"], x, cfg.norm_eps),
                                (mem_k, mem_v))
        x = x + h
        x = x + mlp(pl["ffn"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg.act)
        return x, dict(cl, self=c_new, cross_k=mem_k, cross_v=mem_v)

    return jax.lax.scan(layer, x, (p["decoder"], caches))
