"""Decoder stacks for all assigned families (dense / moe / ssm / hybrid /
vlm), built from stacked ParamSpec trees and executed with
``lax.scan``-over-layers (+ optional remat) so that compile time and HBM
stay bounded even for 72-layer × 512-device dry-runs.

Layer stacking: per-layer specs get a leading "layers" axis; hybrid models
scan over *groups* (e.g. Jamba's period of 7 mamba + 1 attention sublayer)
so the scanned body stays homogeneous.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import mamba2, moe as moe_mod
from .layers import embed, embed_spec, mlp, mlp_spec, rmsnorm, rmsnorm_spec, shd
from .params import ParamSpec, _map_specs, spec


# --------------------------------------------------------------------------
# spec stacking
# --------------------------------------------------------------------------
def stack_specs(n: int, tree):
    """Prepend a ``layers`` axis of size n to every spec in the tree."""
    def one(_path, ps: ParamSpec):
        return dataclasses.replace(
            ps, shape=(n,) + ps.shape, axes=("layers",) + ps.axes)
    return _map_specs(one, tree)


def _scan_blocks(block_fn, stacked_params, x, aux0, remat: bool,
                 scan: bool = True):
    """Run x through stacked blocks; block_fn(p_layer, x) -> (x, aux)."""
    f = jax.checkpoint(block_fn) if remat else block_fn

    if not scan:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        aux = aux0
        for i in range(n):
            p_i = jax.tree.map(lambda a, i=i: a[i], stacked_params)
            x, a = f(p_i, x)
            aux = aux + a
        return x, aux

    def body(carry, p_layer):
        x, aux = carry
        x, a = f(p_layer, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, aux0), stacked_params)
    return x, aux


def _scan_blocks_cache(block_fn, stacked_params, caches, x):
    """Decode/prefill through stacked blocks threading per-layer caches.

    block_fn(p_layer, x, cache_layer) -> (x, new_cache_layer)."""
    def body(x, inp):
        p_layer, c_layer = inp
        x, c_new = block_fn(p_layer, x, c_layer)
        return x, c_new

    return jax.lax.scan(body, x, (stacked_params, caches))


# --------------------------------------------------------------------------
# block definitions (specs + forward + decode) per family
# --------------------------------------------------------------------------
def _attn_spec(cfg: ModelConfig, dtype):
    a = cfg.attn
    if a.mla is not None:
        return attn_mod.mla_spec(a, cfg.d_model, dtype)
    return attn_mod.gqa_spec(a, cfg.d_model, dtype)


def _attn_fwd(p, cfg: ModelConfig, x, positions):
    a = cfg.attn
    if a.mla is not None:
        return attn_mod.mla_forward(p, a, x, positions)
    return attn_mod.gqa_forward(p, a, x, positions)


def _attn_decode(p, cfg: ModelConfig, x, cache):
    a = cfg.attn
    if a.mla is not None:
        return attn_mod.mla_decode(p, a, x, cache)
    return attn_mod.gqa_decode(p, a, x, cache)


def _attn_cache(cfg: ModelConfig, batch, max_len, dtype):
    a = cfg.attn
    if a.mla is not None:
        return attn_mod.mla_init_cache(a, batch, max_len, dtype)
    return attn_mod.gqa_init_cache(a, cfg.d_model, batch, max_len, dtype)


def _attn_prefill(p, cfg: ModelConfig, x, positions, cache):
    a = cfg.attn
    if a.mla is not None:
        return attn_mod.mla_prefill_cache(p, a, x, positions, cache)
    return attn_mod.gqa_prefill_cache(p, a, x, positions, cache)


def _ffn_spec(cfg: ModelConfig, dtype, use_moe: bool):
    if use_moe:
        return moe_mod.moe_spec(cfg.moe, cfg.d_model, dtype)
    return mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype)


def _ffn_fwd(p, cfg: ModelConfig, x, use_moe: bool):
    if use_moe:
        return moe_mod.moe_forward(p, cfg.moe, x)
    return mlp(p, x, cfg.act), 0.0


# ---- standard transformer block (dense or MoE ffn) ------------------------
def block_spec(cfg: ModelConfig, dtype, use_moe=None):
    use_moe = cfg.moe is not None if use_moe is None else use_moe
    return {
        "ln1": rmsnorm_spec(cfg.d_model, dtype),
        "attn": _attn_spec(cfg, dtype),
        "ln2": rmsnorm_spec(cfg.d_model, dtype),
        "ffn": _ffn_spec(cfg, dtype, use_moe),
    }


def block_fwd(p, cfg: ModelConfig, x, positions, use_moe=None):
    use_moe = cfg.moe is not None if use_moe is None else use_moe
    x = shd(x, "batch", "seq_res", "embed")
    x = x + _attn_fwd(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                      positions)
    h, aux = _ffn_fwd(p["ffn"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps),
                      use_moe)
    return x + h, aux


def block_decode(p, cfg: ModelConfig, x, cache, use_moe=None):
    use_moe = cfg.moe is not None if use_moe is None else use_moe
    h, cache = _attn_decode(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cache)
    x = x + h
    h, _ = _ffn_fwd(p["ffn"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), use_moe)
    return x + h, cache


def block_prefill(p, cfg: ModelConfig, x, positions, cache, use_moe=None):
    use_moe = cfg.moe is not None if use_moe is None else use_moe
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    cache = _attn_prefill(p["attn"], cfg, xn, positions, cache)
    x = x + _attn_fwd(p["attn"], cfg, xn, positions)
    h, _ = _ffn_fwd(p["ffn"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), use_moe)
    return x + h, cache


# ---- mamba block -----------------------------------------------------------
def mamba_block_spec(cfg: ModelConfig, dtype):
    return {
        "ln": rmsnorm_spec(cfg.d_model, dtype),
        "mixer": mamba2.mamba_spec(cfg.mamba, cfg.d_model, dtype),
    }


def mamba_block_fwd(p, cfg: ModelConfig, x):
    x = shd(x, "batch", "seq_res", "embed")
    return x + mamba2.mamba_forward(p["mixer"], cfg.mamba, cfg.d_model,
                                    rmsnorm(p["ln"], x, cfg.norm_eps)), 0.0


def mamba_block_decode(p, cfg: ModelConfig, x, cache):
    h, cache = mamba2.mamba_decode(p["mixer"], cfg.mamba, cfg.d_model,
                                   rmsnorm(p["ln"], x, cfg.norm_eps), cache)
    return x + h, cache


# ---- hybrid (Jamba) group --------------------------------------------------
# One group = `period` sublayers: (period-1) mamba + 1 attention, each
# followed by an FFN sublayer alternating dense-MLP / MoE (MoE on odd
# sublayer indices, as in Jamba's every-other-layer MoE).
def hybrid_group_spec(cfg: ModelConfig, dtype):
    period = cfg.attn_every
    n_mamba = period - 1
    n_moe = period // 2
    n_mlp = period - n_moe
    return {
        "mamba": stack_specs(n_mamba, mamba_block_spec(cfg, dtype)),
        "attn": {
            "ln1": rmsnorm_spec(cfg.d_model, dtype),
            "attn": _attn_spec(cfg, dtype),
        },
        "mlp": stack_specs(n_mlp, {
            "ln": rmsnorm_spec(cfg.d_model, dtype),
            "ffn": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype)}),
        "moe": stack_specs(n_moe, {
            "ln": rmsnorm_spec(cfg.d_model, dtype),
            "ffn": moe_mod.moe_spec(cfg.moe, cfg.d_model, dtype)}),
    }


def _hybrid_sublayers(cfg: ModelConfig):
    period = cfg.attn_every
    plan = []
    i_mamba = i_mlp = i_moe = 0
    for i in range(period):
        mixer = ("attn", 0) if i == period - 1 else ("mamba", i_mamba)
        if i != period - 1:
            i_mamba += 1
        if i % 2 == 1:
            ffn = ("moe", i_moe); i_moe += 1
        else:
            ffn = ("mlp", i_mlp); i_mlp += 1
        plan.append((mixer, ffn))
    return plan


def hybrid_group_fwd(p, cfg: ModelConfig, x, positions):
    """Forward one Jamba group.

    The first period-2 sublayers form (period//2 - 1) homogeneous
    (mamba+mlp, mamba+moe) *pairs* executed with an inner ``lax.scan``: the
    while-loop boundary forces XLA to release each pair's FSDP parameter
    gathers before the next pair runs, bounding live gathered params to one
    pair instead of the whole 45B-param group (§Perf jamba log: 67 GiB ->
    measured below).  The tail (mamba+mlp, attn+moe) is unrolled+remat'ed.
    """
    aux = 0.0
    take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)
    period = cfg.attn_every
    n_pairs = period // 2 - 1

    def sublayer(pm_pa_pf, x, mixer, ffn):
        pm, pf = pm_pa_pf
        x = shd(x, "batch", "seq_res", "embed")
        if mixer == "mamba":
            x, _ = mamba_block_fwd(pm, cfg, x)
        else:
            x = x + _attn_fwd(pm["attn"], cfg,
                              rmsnorm(pm["ln1"], x, cfg.norm_eps), positions)
        h, a = _ffn_fwd(pf["ffn"], cfg, rmsnorm(pf["ln"], x, cfg.norm_eps),
                        use_moe=(ffn == "moe"))
        return x + h, a

    if n_pairs > 0:
        sl = lambda tree, s: jax.tree.map(lambda a: a[s], tree)
        pairs = {
            "ma": sl(p["mamba"], slice(0, 2 * n_pairs, 2)),
            "mb": sl(p["mamba"], slice(1, 2 * n_pairs, 2)),
            "mlp": sl(p["mlp"], slice(0, n_pairs)),
            "moe": sl(p["moe"], slice(0, n_pairs)),
        }

        def pair_fn(pp, x):
            x, a1 = sublayer((pp["ma"], pp["mlp"]), x, "mamba", "mlp")
            x, a2 = sublayer((pp["mb"], pp["moe"]), x, "mamba", "moe")
            return x, a1 + a2

        x, aux = _scan_blocks(pair_fn, pairs, x, aux, cfg.remat)

    # tail: (mamba + mlp), (attn + moe)
    tail = [(("mamba", 2 * n_pairs), ("mlp", n_pairs)),
            (("attn", 0), ("moe", n_pairs))]
    for (mixer, mi), (ffn, fi) in tail:
        pm = take(p["mamba"], mi) if mixer == "mamba" else p["attn"]
        pf = take(p[ffn], fi)
        f = (jax.checkpoint(sublayer, static_argnums=(2, 3))
             if cfg.remat else sublayer)
        x, a = f((pm, pf), x, mixer, ffn)
        aux = aux + a
    return x, aux


def hybrid_group_cache(cfg: ModelConfig, batch, max_len, dtype):
    n_mamba = cfg.attn_every - 1
    mcache = mamba2.mamba_init_cache(cfg.mamba, cfg.d_model, batch, dtype)
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_mamba,) + a.shape).copy(), mcache),
        "attn": _attn_cache(cfg, batch, max_len, dtype),
    }


def hybrid_group_decode(p, cfg: ModelConfig, x, cache):
    take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)
    new_m = []
    for (mixer, mi), (ffn, fi) in _hybrid_sublayers(cfg):
        if mixer == "mamba":
            x, c = mamba_block_decode(take(p["mamba"], mi), cfg, x,
                                      take(cache["mamba"], mi))
            new_m.append(c)
        else:
            pa = p["attn"]
            h, ca = _attn_decode(pa["attn"], cfg,
                                 rmsnorm(pa["ln1"], x, cfg.norm_eps),
                                 cache["attn"])
            x = x + h
        pf = take(p[ffn], fi)
        h, _ = _ffn_fwd(pf["ffn"], cfg, rmsnorm(pf["ln"], x, cfg.norm_eps),
                        use_moe=(ffn == "moe"))
        x = x + h
    stacked_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
    return x, {"mamba": stacked_m, "attn": ca}


def hybrid_group_prefill(p, cfg: ModelConfig, x, positions, cache):
    """Prefill for hybrid: run the full-seq forward while (a) filling the
    attention KV cache and (b) producing the final mamba SSM states."""
    take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)
    new_m = []
    for (mixer, mi), (ffn, fi) in _hybrid_sublayers(cfg):
        if mixer == "mamba":
            pm = take(p["mamba"], mi)
            xn = rmsnorm(pm["ln"], x, cfg.norm_eps)
            h, st = _mamba_forward_with_state(pm["mixer"], cfg, xn)
            c = take(cache["mamba"], mi)
            conv_hist = _mamba_conv_tail(pm["mixer"], cfg, xn, c["conv"])
            new_m.append({"conv": conv_hist, "ssm": st,
                          "pos": positions[:, -1] + 1})
            x = x + h
        else:
            pa = p["attn"]
            xn = rmsnorm(pa["ln1"], x, cfg.norm_eps)
            ca = _attn_prefill(pa["attn"], cfg, xn, positions, cache["attn"])
            x = x + _attn_fwd(pa["attn"], cfg, xn, positions)
        pf = take(p[ffn], fi)
        h, _ = _ffn_fwd(pf["ffn"], cfg, rmsnorm(pf["ln"], x, cfg.norm_eps),
                        use_moe=(ffn == "moe"))
        x = x + h
    stacked_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
    return x, {"mamba": stacked_m, "attn": ca}


def _mamba_forward_with_state(p, cfg: ModelConfig, x):
    """mamba_forward that also returns the final SSM state (for prefill)."""
    from ..kernels import ops
    m = cfg.mamba
    B, S, _ = x.shape
    d_inner, H, G, d_conv = mamba2.dims(m, cfg.d_model)
    n = m.d_state
    cdt = x.dtype
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xin, Braw, Craw, dt = mamba2._split(m, cfg.d_model, zxbcdt)
    xbc = jnp.concatenate([xin, Braw, Craw], axis=-1)
    w = p["conv_w"].astype(cdt)
    pad = m.conv_width - 1
    xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xbc_p[:, i:i + S] * w[i] for i in range(m.conv_width))
    conv = jax.nn.silu(conv + p["conv_b"].astype(cdt))
    xin, Braw, Craw = jnp.split(conv, [d_inner, d_inner + G * n], axis=-1)
    xh = xin.reshape(B, S, H, m.headdim)
    Bm = Braw.reshape(B, S, G, n)
    Cm = Craw.reshape(B, S, G, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, state = ops.ssd(xh, dt, A, Bm, Cm, chunk=m.chunk)
    y = y + p["d_skip"].astype(cdt)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = mamba2._gated_norm(p, y, z)
    return y @ p["out_proj"].astype(cdt), state.astype(cdt)


def _mamba_conv_tail(p, cfg: ModelConfig, x, _conv_cache):
    """Last (conv_width-1) pre-conv activations, for decode continuation."""
    m = cfg.mamba
    cdt = x.dtype
    zxbcdt = x @ p["in_proj"].astype(cdt)
    _, xin, Braw, Craw, _ = mamba2._split(m, cfg.d_model, zxbcdt)
    xbc = jnp.concatenate([xin, Braw, Craw], axis=-1)
    W = m.conv_width - 1
    return xbc[:, -W:]


# ---- VLM group (Llama-3.2-Vision style) ------------------------------------
def vlm_group_spec(cfg: ModelConfig, dtype):
    n_self = cfg.vision.cross_attn_every - 1
    return {
        "self": stack_specs(n_self, block_spec(cfg, dtype)),
        "cross": {
            "ln1": rmsnorm_spec(cfg.d_model, dtype),
            "xattn": attn_mod.cross_attn_spec(cfg.attn, cfg.d_model, dtype),
            "gate": spec((1,), (None,), init="zeros", dtype=dtype),
            "ln2": rmsnorm_spec(cfg.d_model, dtype),
            "ffn": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype),
        },
    }


def vlm_group_fwd(p, cfg: ModelConfig, x, positions, image_embeds):
    def self_block(pl, x):
        return block_fwd(pl, cfg, x, positions, use_moe=False)
    x, aux = _scan_blocks(self_block, p["self"], x, 0.0, cfg.remat)
    x = shd(x, "batch", "seq_res", "embed")
    pc = p["cross"]
    mem_kv = attn_mod.cross_attn_kv(pc["xattn"], image_embeds)
    h = attn_mod.cross_attn(pc["xattn"], cfg.attn,
                            rmsnorm(pc["ln1"], x, cfg.norm_eps), mem_kv)
    x = x + jnp.tanh(pc["gate"].astype(x.dtype)) * h
    h, _ = _ffn_fwd(pc["ffn"], cfg, rmsnorm(pc["ln2"], x, cfg.norm_eps), False)
    return x + h, aux


def vlm_group_cache(cfg: ModelConfig, batch, max_len, dtype):
    n_self = cfg.vision.cross_attn_every - 1
    a = _attn_cache(cfg, batch, max_len, dtype)
    stacked = jax.tree.map(
        lambda v: jnp.broadcast_to(v, (n_self,) + v.shape).copy(), a)
    dh = cfg.head_dim
    memkv = jnp.zeros((batch, cfg.vision.n_image_tokens,
                       cfg.attn.n_kv_heads, dh), dtype)
    return {"self": stacked, "cross_k": memkv, "cross_v": memkv}


def vlm_group_decode(p, cfg: ModelConfig, x, cache):
    def self_block(x, inp):
        pl, cl = inp
        x, c = block_decode(pl, cfg, x, cl, use_moe=False)
        return x, c
    x, new_self = jax.lax.scan(self_block, x, (p["self"], cache["self"]))
    pc = p["cross"]
    h = attn_mod.cross_attn(pc["xattn"], cfg.attn,
                            rmsnorm(pc["ln1"], x, cfg.norm_eps),
                            (cache["cross_k"], cache["cross_v"]))
    x = x + jnp.tanh(pc["gate"].astype(x.dtype)) * h
    h, _ = _ffn_fwd(pc["ffn"], cfg, rmsnorm(pc["ln2"], x, cfg.norm_eps), False)
    return x + h, dict(cache, self=new_self)


def vlm_group_prefill(p, cfg: ModelConfig, x, positions, cache, image_embeds):
    def self_block(x, inp):
        pl, cl = inp
        xn = rmsnorm(pl["ln1"], x, cfg.norm_eps)
        c = _attn_prefill(pl["attn"], cfg, xn, positions, cl)
        x = x + _attn_fwd(pl["attn"], cfg, xn, positions)
        h, _ = _ffn_fwd(pl["ffn"], cfg, rmsnorm(pl["ln2"], x, cfg.norm_eps),
                        False)
        return x + h, c
    x, new_self = jax.lax.scan(self_block, x, (p["self"], cache["self"]))
    pc = p["cross"]
    mem_k, mem_v = attn_mod.cross_attn_kv(pc["xattn"], image_embeds)
    h = attn_mod.cross_attn(pc["xattn"], cfg.attn,
                            rmsnorm(pc["ln1"], x, cfg.norm_eps),
                            (mem_k, mem_v))
    x = x + jnp.tanh(pc["gate"].astype(x.dtype)) * h
    h, _ = _ffn_fwd(pc["ffn"], cfg, rmsnorm(pc["ln2"], x, cfg.norm_eps), False)
    return x + h, dict(cache, self=new_self, cross_k=mem_k, cross_v=mem_v)
