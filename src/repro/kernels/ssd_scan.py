"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

One program instance processes one (batch, head, chunk) tile:

  - intra-chunk dual form on the MXU: (C B^T ∘ decay ∘ causal) X,
  - carries the inter-chunk SSM state [headdim, d_state] in VMEM scratch
    across the chunk grid dimension (innermost), multiplying by the chunk's
    cumulative decay and adding its summary state.

Grid: (batch*heads, n_chunks); chunk is the innermost dimension so the
state scratch persists across it (sequential dependence), while
batch*heads programs are independent (parallel grid dim).

Tiles: chunk length = 128 aligns the intra-chunk [l, l] score matmul to the
MXU; headdim (64-256) x d_state (128) state tiles are VMEM-resident.

Validated under interpret=True against `ref.ssd_chunked`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def supported(x, B, chunk) -> bool:
    b, s, h, p = x.shape
    return s % chunk == 0 and p % 8 == 0 and B.shape[-1] % 8 == 0


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
            *, chunk):
    # st_ref is an *output* block revisited across the (innermost) chunk
    # grid dim — it doubles as the carried SSM state (legal accumulation
    # pattern on TPU; the value after the last chunk is the final state).
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[...]                                   # [l, p]
    dt = dt_ref[...]                                 # [l, 1]  (f32)
    A = a_ref[0]                                     # scalar (f32, negative)
    Bm = b_ref[...]                                  # [l, n]
    Cm = c_ref[...]                                  # [l, n]

    xbar = (x * dt).astype(jnp.float32)              # dt-weighted input
    da = dt[:, 0] * A                                # [l] log decay
    cum = jnp.cumsum(da)                             # [l]

    # intra-chunk dual form
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [l, l]
    # exponent clamped at 0 (upper triangle masked below; avoids inf)
    decay = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(li >= lj, scores * decay, 0.0)
    y = jnp.dot(w.astype(xbar.dtype), xbar,
                preferred_element_type=jnp.float32)  # [l, p]

    # inter-chunk contribution from the carried state
    state = st_ref[...]                              # [p, n] f32
    y += jnp.dot(Cm.astype(jnp.float32) * jnp.exp(cum)[:, None],
                 state.T, preferred_element_type=jnp.float32)

    # update carried state: decay to end-of-chunk, add chunk summary
    decay_to_end = jnp.exp(cum[-1] - cum)            # [l]
    summary = jnp.dot((xbar * decay_to_end[:, None]).T,
                      Bm.astype(jnp.float32),
                      preferred_element_type=jnp.float32)   # [p, n]
    st_ref[...] = state * jnp.exp(cum[-1]) + summary

    y_ref[...] = y.astype(y_ref.dtype)


def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """Contract identical to `ref.ssd_chunked` (returns y and final state).

    x [b,s,h,p], dt [b,s,h] (f32), A [h], B/C [b,s,g,n] with g | h.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0
    nc = s // chunk

    # flatten (batch, head); repeat B/C per head group
    xt = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtt = dt.transpose(0, 2, 1).reshape(b * h, s, 1).astype(jnp.float32)
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Ah = jnp.tile(A.astype(jnp.float32), (b,)).reshape(b * h, 1)

    kernel = functools.partial(_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((None, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((None, p, n), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, Ah, Bh, Ch)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, st.reshape(b, h, p, n)
