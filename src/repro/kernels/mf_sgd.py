"""Pallas TPU kernel for the paper's MF-SGD hot loop (dense-block form).

The paper's benchmark updates rows of L and columns of R for every observed
rating.  On TPU we adapt the insight (DESIGN.md §Hardware adaptation): the
scatter-style per-rating update becomes a *dense block* update — ratings are
tiled into MXU-aligned [block_n x block_m] blocks; each program instance:

  1. loads its L [block_n, K] and R [K, block_m] tiles into VMEM,
  2. computes the residual E = mask * (D - L R) on the MXU,
  3. emits the paper's gradient-summed updates
         dL = gamma (E R^T - lam * count_row * L)
         dR = gamma (L^T E - lam * count_col * R)
     and the block's squared-error loss.

TPU constraint: an output tile may only be *accumulated* across consecutive
(innermost) grid steps — revisiting a tile non-consecutively is undefined on
hardware.  dL accumulates over column blocks and dR over row blocks, so we
run two passes with transposed grids: pass 1 (grid i,j) accumulates dL+loss
over the innermost j; pass 2 (grid j,i) accumulates dR over the innermost i.
E is recomputed (cheap: one MXU matmul per tile) — trading flops for a
hardware-legal accumulation pattern.

K (the rank) stays whole in VMEM: an L tile is block_n x K x 4B = 128 KiB at
K=256 — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def supported(L, R, _D) -> bool:
    n, k = L.shape
    m = R.shape[1]
    return k % 8 == 0 and n % 8 == 0 and m % 128 == 0


def _residual(L, R, D, mask):
    pred = jnp.dot(L, R, preferred_element_type=jnp.float32)
    return jnp.where(mask, D - pred, 0.0)


def _dl_kernel(L_ref, R_ref, D_ref, mask_ref, dL_ref, loss_ref,
               *, gamma, lam):
    i = pl.program_id(0)
    j = pl.program_id(1)
    L, R = L_ref[...], R_ref[...]
    mask = mask_ref[...]
    E = _residual(L, R, D_ref[...], mask)
    cnt_row = jnp.sum(mask.astype(jnp.float32), axis=1, keepdims=True)
    dL = gamma * (jnp.dot(E, R.T, preferred_element_type=jnp.float32)
                  - lam * cnt_row * L)

    @pl.when(j == 0)
    def _zero_dl():
        dL_ref[...] = jnp.zeros_like(dL_ref)

    # the loss tile has a constant index map (visited every step) — zero it
    # only on the very first program instance.
    @pl.when((i == 0) & (j == 0))
    def _zero_loss():
        loss_ref[0, 0] = 0.0

    dL_ref[...] += dL.astype(dL_ref.dtype)
    loss_ref[0, 0] += jnp.sum(jnp.square(E))


def _dr_kernel(L_ref, R_ref, D_ref, mask_ref, dR_ref, *, gamma, lam):
    i = pl.program_id(1)                       # transposed grid: (j, i)
    L, R = L_ref[...], R_ref[...]
    mask = mask_ref[...]
    E = _residual(L, R, D_ref[...], mask)
    cnt_col = jnp.sum(mask.astype(jnp.float32), axis=0, keepdims=True)
    dR = gamma * (jnp.dot(L.T, E, preferred_element_type=jnp.float32)
                  - lam * cnt_col * R)

    @pl.when(i == 0)
    def _zero():
        dR_ref[...] = jnp.zeros_like(dR_ref)

    dR_ref[...] += dR.astype(dR_ref.dtype)


def mf_sgd_block(L, R, D, mask, gamma, lam, *, block_n: int = 128,
                 block_m: int = 128, interpret: bool = False):
    """Contract identical to `ref.mf_sgd_block` (loss normalized by count)."""
    n, K = L.shape
    m = R.shape[1]
    block_n = min(block_n, n)
    block_m = min(block_m, m)
    assert n % block_n == 0 and m % block_m == 0
    n_n, n_m = n // block_n, m // block_m

    dL, loss = pl.pallas_call(
        functools.partial(_dl_kernel, gamma=gamma, lam=lam),
        grid=(n_n, n_m),
        in_specs=[
            pl.BlockSpec((block_n, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, K), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, K), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(L, R, D, mask)

    dR = pl.pallas_call(
        functools.partial(_dr_kernel, gamma=gamma, lam=lam),
        grid=(n_m, n_n),                        # transposed
        in_specs=[
            pl.BlockSpec((block_n, K), lambda j, i: (i, 0)),
            pl.BlockSpec((K, block_m), lambda j, i: (0, j)),
            pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
            pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((K, block_m), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((K, m), jnp.float32),
        interpret=interpret,
    )(L, R, D, mask)

    cnt = jnp.maximum(jnp.sum(mask), 1)
    return dL, dR, loss[0, 0] / cnt
