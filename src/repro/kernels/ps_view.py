"""Pallas TPU kernels for the PS simulator's per-clock hot path.

Two kernels back `core/ps.py` (dispatched via `ops.ring_view` /
`ops.vap_suffix_norms`; the pure-jnp contracts live in `ref.py`):

1. ``ring_view`` — masked ring-buffer view materialization.  The reader
   views ``view[r] = base + Σ_{w,q visible} uring[w,q]`` are a [P, W·P]
   visibility mask times the [W·P, d] update ring.  Rather than
   materializing the mask @ ring matmul with a broadcast (what XLA does for
   the reference einsum), the kernel streams d-blocks of the ring through
   VMEM once and accumulates one small [P,P] × [P, block_d] MXU matmul per
   ring slot, with the visibility mask computed in-register from the slot
   clock and the per-channel ``cview`` clocks.

2. ``vap_suffix_norms`` — per-producer inf-norms of the suffix aggregates of
   the newest k clocks (k = 0..W), the quantity the paper's VAP model
   bounds by ``v_t``.  Replaces a Python-unrolled O(W²) chain of einsums
   over the full [W,P,d] ring with a single pass per d-block: a running
   suffix accumulator in VMEM and a max-reduction into the [W+1, P] output,
   accumulated across d-blocks via output revisiting (constant index map,
   innermost grid dim — the TPU-legal accumulation pattern, cf. mf_sgd.py).

Both kernels keep the last axis blocked at a multiple of 128 lanes; the
sublane axis is the worker count P (small: 4–16), which Mosaic pads.  W is
a small static ring window (≤ ~16), so per-slot loops are unrolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import RING_INVALID


def supported(uring, block_d: int = 128) -> bool:
    W, P, d = uring.shape
    return d % block_d == 0 and P <= 128 and W <= 64


def _ring_view_kernel(uclock_ref, cview_ref, base_ref, uring_ref, out_ref):
    W = uring_ref.shape[0]
    cview = cview_ref[...]                                   # [P, P] int32
    acc = jnp.broadcast_to(base_ref[...], out_ref.shape).astype(jnp.float32)
    for w in range(W):                                       # static unroll
        uc = uclock_ref[w, 0]
        vis = (cview >= uc) & (uc > RING_INVALID)            # [P(r), P(q)]
        acc = acc + jnp.dot(vis.astype(jnp.float32), uring_ref[w],
                            preferred_element_type=jnp.float32)
    out_ref[...] = acc


def ring_view(base, uring, uclock, cview, *, block_d: int = 128,
              interpret: bool = False):
    """Contract identical to `ref.ring_view`."""
    W, P, d = uring.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    return pl.pallas_call(
        _ring_view_kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((W, 1), lambda i: (0, 0)),           # uclock
            pl.BlockSpec((P, P), lambda i: (0, 0)),           # cview
            pl.BlockSpec((1, block_d), lambda i: (0, i)),     # base
            pl.BlockSpec((W, P, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((P, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((P, d), jnp.float32),
        interpret=interpret,
    )(uclock.reshape(W, 1), cview, base.reshape(1, d),
      uring.astype(jnp.float32))


def _suffix_norms_kernel(uclock_ref, c_ref, uring_ref, out_ref):
    i = pl.program_id(0)
    W, P, block_d = uring_ref.shape
    c = c_ref[0, 0]

    @pl.when(i == 0)
    def _init():                                             # norms are >= 0
        out_ref[...] = jnp.zeros_like(out_ref)

    suffix = jnp.zeros((P, block_d), jnp.float32)
    for k in range(1, W + 1):                                # static unroll
        for w in range(W):
            sel = uclock_ref[w, 0] == c - k                  # scalar
            suffix = suffix + jnp.where(sel, uring_ref[w], 0.0)
        norm_k = jnp.max(jnp.abs(suffix), axis=-1)           # [P]
        out_ref[k, :] = jnp.maximum(out_ref[k, :], norm_k)


def vap_suffix_norms(uring, uclock, c, *, block_d: int = 128,
                     interpret: bool = False):
    """Contract identical to `ref.vap_suffix_norms`."""
    W, P, d = uring.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    return pl.pallas_call(
        _suffix_norms_kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((W, 1), lambda i: (0, 0)),           # uclock
            pl.BlockSpec((1, 1), lambda i: (0, 0)),           # clock c
            pl.BlockSpec((W, P, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((W + 1, P), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((W + 1, P), jnp.float32),
        interpret=interpret,
    )(uclock.reshape(W, 1), jnp.asarray(c, jnp.int32).reshape(1, 1),
      uring.astype(jnp.float32))
