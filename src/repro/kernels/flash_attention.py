"""Pallas TPU flash attention (blocked, online softmax, GQA-aware).

TPU adaptation notes (DESIGN.md §Hardware adaptation):
- Q/K tiles sized to MXU multiples (block_q x block_k default 128x128); the
  kv stream is the innermost grid dimension so the Q tile and the running
  softmax state stay resident in VMEM across the online-softmax update.
- The running max/denominator (m, l) and the f32 output accumulator live in
  VMEM scratch; the output is cast once on the final kv block.
- Masking is positional (q_pos/kv_pos tiles), so the same kernel serves
  full-causal, sliding-window and padded layouts; kv tiles with no visible
  keys are skipped via `pl.when` — no MXU work issued (the pure-jnp
  reference cannot skip, which is exactly the 2x causal waste the §Perf
  log measures).
- GQA: one program instance serves all `rep` = H/Hkv query heads of one kv
  head — they share the K/V tile in VMEM (the q tile is [rep*block_q, d]).

Grid: (batch*kv_heads, q_blocks, kv_blocks).

Validated under interpret=True against `ref.attention_dense` in
tests/test_kernel_flash.py (shape/dtype sweeps + hypothesis cases).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min) / 2


def supported(q, k, v, _kv_chunk=None) -> bool:
    B, Sq, H, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    return (H % Hkv == 0 and Dk % 8 == 0 and v.shape[-1] % 8 == 0)


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref,
            *, scale, causal, window, rep, n_kv):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qpos_ref[...]                                   # [block_q]
    k_pos = kpos_ref[...]                                   # [block_k]
    valid = jnp.broadcast_to((k_pos >= 0)[None, :],
                             (q_pos.shape[0], k_pos.shape[0]))
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (k_pos[None, :] > (q_pos[:, None] - window))

    @pl.when(jnp.any(valid))
    def _compute():
        rq, bq, dk = q_ref.shape
        q = q_ref[...].reshape(rq * bq, dk)                 # [rep*bq, d]
        k = k_ref[...]                                      # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [rep*bq, bk]
        vmask = jnp.tile(valid, (rep, 1))
        s = jnp.where(vmask, s, NEG_INF)

        m_prev = m_ref[...]                                 # [rep*bq, 1]
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(vmask, p, 0.0)
        corr = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        rq, bq, dv = o_ref.shape
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(
            o_ref.dtype).reshape(rq, bq, dv)


def flash_attention(q, k, v, *, scale, q_pos, kv_pos, causal=True,
                    window=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Contract identical to `ref.attention` (q [B,Sq,H,Dk], k [B,Sk,Hkv,Dk],
    v [B,Sk,Hkv,Dv] -> [B,Sq,H,Dv]); padded kv slots carry kv_pos = -1."""
    B, Sq, H, Dk = q.shape
    Sk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    rep = H // Hkv
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, Sk)

    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=2**30)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=-1)
    Sq_p, Sk_p = Sq + pq, Sk + pk
    n_q, n_kv = Sq_p // block_q, Sk_p // block_k

    # [B*Hkv, rep, Sq_p, Dk]: all q heads of one kv group share K/V tiles.
    q_r = q.reshape(B, Sq_p, Hkv, rep, Dk).transpose(0, 2, 3, 1, 4)
    q_r = q_r.reshape(B * Hkv, rep, Sq_p, Dk)
    k_r = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk_p, Dk)
    v_r = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk_p, Dv)
    qpos_r = jnp.repeat(q_pos, Hkv, axis=0)
    kpos_r = jnp.repeat(kv_pos, Hkv, axis=0)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, rep=rep, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((None, block_k), lambda b, i, j: (b, j)),
            pl.BlockSpec((None, rep, block_q, Dk),
                         lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((None, block_k, Dk), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, rep, block_q, Dv),
                               lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, rep, Sq_p, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep * block_q, 1), jnp.float32),
            pltpu.VMEM((rep * block_q, 1), jnp.float32),
            pltpu.VMEM((rep * block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_r, kpos_r, q_r, k_r, v_r)

    out = out.reshape(B, Hkv, rep, Sq_p, Dv)[:, :, :, :Sq]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
