"""jit-friendly dispatch wrappers for the Pallas kernels.

On TPU the Pallas implementations run natively; on CPU (this container) the
wrappers dispatch to the pure-jnp references, and tests exercise the Pallas
bodies under ``interpret=True``.  Selection can be forced with
``set_backend("pallas"|"ref")`` (used by kernel tests and benchmarks).
"""
from __future__ import annotations

import functools

import jax

from . import ref

_BACKEND = "auto"

# Perf toggles (see EXPERIMENTS.md §Perf): static_causal skips fully-masked
# causal KV blocks in full-sequence attention (positions are arange there).
# Default OFF so baseline dry-runs measure the oblivious blocked loop; the
# §Perf hillclimb runs enable it (and the Pallas kernel always skips).
_FLAGS = {"static_causal": False,
          "kv_chunk": 1024, "q_chunk": 2048}


def set_flag(name: str, value):
    assert name in _FLAGS
    _FLAGS[name] = value


def get_flag(name: str) -> bool:
    return _FLAGS[name]


def set_backend(name: str):
    global _BACKEND
    assert name in ("auto", "ref", "pallas", "pallas_interpret")
    _BACKEND = name


def get_backend() -> str:
    if _BACKEND != "auto":
        return _BACKEND
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "ref"


def attention(q, k, v, *, scale, q_pos, kv_pos, causal=True, window=None,
              kv_chunk=None, q_chunk=None):
    """Blocked attention; see `ref.attention` for the contract."""
    kv_chunk = kv_chunk or _FLAGS["kv_chunk"]
    q_chunk = q_chunk or _FLAGS["q_chunk"]
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import flash_attention as fa
        # The Pallas kernel requires hardware-aligned tiles; fall back for
        # odd shapes (tests cover both paths).
        if fa.supported(q, k, v, kv_chunk):
            return fa.flash_attention(
                q, k, v, scale=scale, q_pos=q_pos, kv_pos=kv_pos,
                causal=causal, window=window,
                interpret=(backend == "pallas_interpret"))
    return ref.attention(q, k, v, scale=scale, q_pos=q_pos, kv_pos=kv_pos,
                         causal=causal, window=window, kv_chunk=kv_chunk,
                         q_chunk=q_chunk,
                         assume_prefix=_FLAGS["static_causal"])


def ring_view(base, uring, uclock, cview):
    """PS view materialization; see `ref.ring_view` for the contract."""
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import ps_view
        if ps_view.supported(uring):
            return ps_view.ring_view(
                base, uring, uclock, cview,
                interpret=(backend == "pallas_interpret"))
    return ref.ring_view(base, uring, uclock, cview)


def vap_suffix_norms(uring, uclock, c):
    """VAP suffix-aggregate inf-norms; see `ref.vap_suffix_norms`."""
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import ps_view
        if ps_view.supported(uring):
            return ps_view.vap_suffix_norms(
                uring, uclock, c,
                interpret=(backend == "pallas_interpret"))
    return ref.vap_suffix_norms(uring, uclock, c)


def delta_pack(delta, thresh, scale, quant: str = "f32"):
    """Comm-substrate compression pack; see `ref.delta_pack`."""
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import delta_pack as dp
        if dp.supported(delta):
            return dp.delta_pack(
                delta, thresh, scale, quant,
                interpret=(backend == "pallas_interpret"))
    return ref.delta_pack(delta, thresh, scale, quant)


def mf_sgd_block(L, R, D, mask, gamma, lam):
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import mf_sgd
        if mf_sgd.supported(L, R, D):
            return mf_sgd.mf_sgd_block(
                L, R, D, mask, gamma, lam,
                interpret=(backend == "pallas_interpret"))
    return ref.mf_sgd_block(L, R, D, mask, gamma, lam)


def ssd(x, dt, A, B, C, chunk=128):
    backend = get_backend()
    if backend in ("pallas", "pallas_interpret"):
        from . import ssd_scan
        if ssd_scan.supported(x, B, chunk):
            return ssd_scan.ssd(x, dt, A, B, C, chunk=chunk,
                                interpret=(backend == "pallas_interpret"))
    return ref.ssd_chunked(x, dt, A, B, C, chunk)


def ssd_decode(x, dt, A, B, C, state):
    # decode step is tiny; always the reference path
    return ref.ssd_recurrent(x, dt, A, B, C, state)
