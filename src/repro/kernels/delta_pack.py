"""Pallas TPU kernel for the comm substrate's hot compaction path.

``delta_pack`` backs `repro.comm`'s per-shipment pack (dispatched via
`ops.delta_pack`; the pure-jnp contract lives in `ref.delta_pack`): one
VMEM pass per d-block does the masked top-k select/scatter, the value
quantization, and the error-feedback residual fold —

    mask     = |delta| >= thresh          (thresh = k-th largest |row|)
    wire     = Q(where(mask, delta, 0))
    residual = where(mask, delta - Q(delta), delta)

so the shipped delta and the held-back residual are produced together
without materializing the mask or a second pass over the rows.  The
per-row threshold/scale scalars ride in as [P, 1] blocks (computed
upstream by ``comm.substrate.row_threshold`` / ``quant_scale`` — a sort is
not kernel material), and ``quant`` is static: each format compiles its
own elementwise body.

Layout mirrors `ps_view.py`: the last axis is blocked at a multiple of 128
lanes, the sublane axis is the worker count P (small; Mosaic pads), and
the grid is 1-D over d-blocks.  Verified against the jnp reference under
``interpret=True`` by ``tests/test_comm.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def supported(delta, block_d: int = 128) -> bool:
    P, d = delta.shape
    return d % block_d == 0 and P <= 128


def _delta_pack_kernel(thresh_ref, scale_ref, delta_ref, wire_ref, res_ref,
                       *, quant: str):
    delta = delta_ref[...]                                 # [P, block_d]
    mask = jnp.abs(delta) >= thresh_ref[...]               # [P,1] broadcast
    if quant == "f32":
        q = delta
        res = jnp.where(mask, 0.0, delta)
    elif quant == "bf16":
        q = delta.astype(jnp.bfloat16).astype(jnp.float32)
        res = jnp.where(mask, delta - q, delta)
    else:  # int8
        s = scale_ref[...]                                 # [P, 1]
        q = jnp.clip(jnp.round(delta / s), -127.0, 127.0) * s
        res = jnp.where(mask, delta - q, delta)
    wire_ref[...] = jnp.where(mask, q, 0.0)
    res_ref[...] = res


def delta_pack(delta, thresh, scale, quant: str = "f32", *,
               block_d: int = 128, interpret: bool = False):
    """Contract identical to `ref.delta_pack`."""
    P, d = delta.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    kernel = functools.partial(_delta_pack_kernel, quant=quant)
    return pl.pallas_call(
        kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((P, 1), lambda i: (0, 0)),        # thresh
            pl.BlockSpec((P, 1), lambda i: (0, 0)),        # scale
            pl.BlockSpec((P, block_d), lambda i: (0, i)),  # delta
        ],
        out_specs=[
            pl.BlockSpec((P, block_d), lambda i: (0, i)),
            pl.BlockSpec((P, block_d), lambda i: (0, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((P, d), jnp.float32),
                   jax.ShapeDtypeStruct((P, d), jnp.float32)],
        interpret=interpret,
    )(thresh.reshape(P, 1).astype(jnp.float32),
      scale.reshape(P, 1).astype(jnp.float32),
      delta.astype(jnp.float32))
