"""Pure-jnp reference oracles for every kernel.

These are the semantics contracts: Pallas kernels must match them (tests
sweep shapes/dtypes with assert_allclose), and on CPU the ops dispatch here.

``attention`` is written *blocked* (lax.scan over KV chunks with online
softmax) so that even the reference path never materializes S×S logits —
required for the 32k/500k dry-run shapes.  ``attention_dense`` is the naive
quadratic oracle used only in tests at small sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = float(np.finfo(np.float32).min) / 2


# ==========================================================================
# attention
# ==========================================================================
def _block_mask(q_pos, kv_pos, causal, window):
    """[B,Sq,Ck] visibility of kv positions (pad slots have kv_pos < 0)."""
    valid = (kv_pos >= 0)[:, None, :]
    if causal:
        valid = valid & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        valid = valid & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    return valid


def attention_dense(q, k, v, *, scale, q_pos, kv_pos, causal=True,
                    window=None):
    """Naive quadratic oracle. q [B,Sq,H,Dk], k [B,Sk,Hkv,Dk],
    v [B,Sk,Hkv,Dv] -> [B,Sq,H,Dv]."""
    B, Sq, H, Dk = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, Dk)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _block_mask(q_pos, kv_pos, causal, window)       # [B,Sq,Sk]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])


def attention(q, k, v, *, scale, q_pos, kv_pos, causal=True, window=None,
              kv_chunk=1024, q_chunk=2048, assume_prefix=False):
    """Blocked flash-style attention (online softmax over KV chunks, outer
    map over Q chunks).

    Shapes as `attention_dense`; Dk and Dv may differ (MLA uses this as MQA
    over the latent).  Never materializes more than
    [B,Hkv,rep,q_chunk,kv_chunk] logits at a time.

    ``assume_prefix=True`` asserts that positions are ``arange`` (the
    standard full-forward layout): causal q-chunks then only visit their
    *static* KV prefix (and, with a window, only the in-window suffix of
    that prefix) — skipping fully-masked KV blocks.  This halves causal
    attention flops vs the oblivious blocked loop (§Perf llama3-8b log);
    it is what the Pallas kernel's `pl.when` skip does on TPU.
    """
    Sq_full = q.shape[1]
    if (assume_prefix and causal and Sq_full == k.shape[1]
            and Sq_full > q_chunk and Sq_full % q_chunk == 0):
        nq = Sq_full // q_chunk
        outs = []
        for i in range(nq):                      # static loop: shapes differ
            sl = slice(i * q_chunk, (i + 1) * q_chunk)
            end = (i + 1) * q_chunk              # static causal KV prefix
            start = 0
            if window is not None:               # static window lower bound
                start = max(0, i * q_chunk - window)
            outs.append(_attention_impl(
                q[:, sl], k[:, start:end], v[:, start:end], scale=scale,
                q_pos=q_pos[:, sl], kv_pos=kv_pos[:, start:end],
                causal=True, window=window, kv_chunk=kv_chunk))
        return jnp.concatenate(outs, axis=1)
    if Sq_full > q_chunk and Sq_full % q_chunk == 0:
        nq = Sq_full // q_chunk
        qs = q.reshape(q.shape[0], nq, q_chunk, *q.shape[2:]).transpose(
            1, 0, 2, 3, 4)
        ps = q_pos.reshape(q_pos.shape[0], nq, q_chunk).transpose(1, 0, 2)
        out = jax.lax.map(
            lambda args: _attention_impl(
                args[0], k, v, scale=scale, q_pos=args[1], kv_pos=kv_pos,
                causal=causal, window=window, kv_chunk=kv_chunk),
            (qs, ps))
        return out.transpose(1, 0, 2, 3, 4).reshape(
            q.shape[0], Sq_full, q.shape[2], v.shape[-1])
    return _attention_impl(q, k, v, scale=scale, q_pos=q_pos, kv_pos=kv_pos,
                           causal=causal, window=window, kv_chunk=kv_chunk)


def _attention_impl(q, k, v, *, scale, q_pos, kv_pos, causal, window,
                    kv_chunk):
    B, Sq, H, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    rep = H // Hkv
    C = min(kv_chunk, Sk)
    nc = -(-Sk // C)
    pad = nc * C - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)

    qg = q.reshape(B, Sq, Hkv, rep, Dk)
    kc = k.reshape(B, nc, C, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, C, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, nc, C).transpose(1, 0, 2)

    m0 = jnp.full((B, Hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Sq, Dv), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(q_pos, pb, causal, window)        # [B,Sq,C]
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(m <= NEG_INF, NEG_INF, m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


# ==========================================================================
# PS simulator ring-buffer ops (core/ps.py per-clock hot path)
# ==========================================================================
RING_INVALID = -(10**8)   # uclock values below this mark empty ring slots
RING_EMPTY = -(10**9)     # initial uclock fill (no clock stored yet)
# Both sentinels are part of the Trace-producer contract (core/ps.py):
# the simulator and the psrun runtime import them from here so the two
# engines' validity masks can never silently diverge.


def ring_view(base, uring, uclock, cview):
    """Materialize per-reader parameter views from the update ring.

    base [d], uring [W,P,d] (slot, producer, dim), uclock [W] (clock stored
    in each slot; < RING_INVALID when empty), cview [P,P] (reader, producer)
    visibility clocks.  Returns views [P,d]:

        view[r] = base + Σ_{w,q : uclock[w] <= cview[r,q], slot valid} uring[w,q]
    """
    valid = uclock > RING_INVALID
    vis = (uclock[None, :, None] <= cview[:, None, :]) & valid[None, :, None]
    return base[None, :] + jnp.einsum("rwq,wqd->rd", vis.astype(uring.dtype),
                                      uring)


def delta_pack(delta, thresh, scale, quant: str = "f32"):
    """Error-feedback compression pack of per-producer delta rows.

    ``delta [P, d]`` aggregated deltas, ``thresh [P]`` per-row magnitude
    threshold (the k-th largest ``|delta|``, see
    ``comm.substrate.row_threshold``), ``scale [P]`` int8 dequant scale
    (absmax/127; ignored unless ``quant == "int8"``).  Returns
    ``(wire [P, d], residual [P, d])``::

        mask     = |delta| >= thresh
        wire     = Q(where(mask, delta, 0))          # dequantized values
        residual = where(mask, delta - Q(delta), delta)

    ``quant`` is static ("f32" | "bf16" | "int8").  Mass conservation:
    ``wire + residual == delta`` — *exact* in the "f32" path (selected
    coordinates never round: residual is the masked complement, not a
    subtraction), to float rounding otherwise (residual is computed as
    ``delta - dequant`` so the quantization error re-ships later).
    """
    mask = jnp.abs(delta) >= thresh[:, None]
    if quant == "f32":
        q = delta
        residual = jnp.where(mask, 0.0, delta)
    elif quant == "bf16":
        q = delta.astype(jnp.bfloat16).astype(jnp.float32)
        residual = jnp.where(mask, delta - q, delta)
    elif quant == "int8":
        s = scale[:, None]
        q = jnp.clip(jnp.round(delta / s), -127.0, 127.0) * s
        residual = jnp.where(mask, delta - q, delta)
    else:
        raise ValueError(f"unknown quant {quant!r}")
    wire = jnp.where(mask, q, 0.0)
    return wire, residual


def vap_suffix_norms(uring, uclock, c):
    """Inf-norms of per-producer suffix aggregates of the newest k clocks.

    Returns norms [W+1, P] with norms[k, q] = || Σ_{j=1..k} u_q(c-j) ||_inf
    (norms[0] = 0: the empty suffix).  This is the quantity VAP bounds by
    v_t, and the one-gather source of the in-transit metric in `ps.py`.
    """
    W, P, _ = uring.shape
    ks = jnp.arange(1, W + 1, dtype=uclock.dtype)
    sel = (uclock[None, :] == (c - ks)[:, None]).astype(uring.dtype)  # [k,w]
    contrib = jnp.einsum("kw,wqd->kqd", sel, uring)
    suffix = jnp.cumsum(contrib, axis=0)
    norms = jnp.max(jnp.abs(suffix), axis=-1)                         # [W,P]
    return jnp.concatenate([jnp.zeros((1, P), norms.dtype), norms], axis=0)


# ==========================================================================
# MF-SGD block update (the paper's hot loop, dense-block form)
# ==========================================================================
def mf_sgd_block(L, R, D, mask, gamma, lam):
    """One SGD step over a dense block of ratings.

    L [N,K], R [K,M], D [N,M] ratings with validity ``mask`` [N,M].
    Returns (dL, dR, loss) where dL/dR are the additive updates for the
    paper's update equations applied to every observed entry of the block
    (gradient summed over the block) and loss is the squared error.
    """
    E = jnp.where(mask, D - L @ R, 0.0)                     # residual
    cnt = jnp.maximum(jnp.sum(mask, axis=None), 1)
    dL = gamma * (E @ R.T - lam * jnp.sum(mask, 1, keepdims=True) * L)
    dR = gamma * (L.T @ E - lam * jnp.sum(mask, 0, keepdims=True) * R)
    loss = jnp.sum(jnp.square(E)) / cnt
    return dL, dR, loss


# ==========================================================================
# Mamba-2 SSD (state-space duality) chunked scan
# ==========================================================================
def ssd_chunked(x, dt, A, B, C, chunk):
    """SSD forward (matches Mamba-2's `ssd_minimal_discrete`).

    x  [b, s, h, p]   per-head inputs (p = headdim)
    dt [b, s, h]      softplus-activated step sizes (>= 0)
    A  [h]            negative state decay rates (A < 0)
    B  [b, s, g, n]   input projections (g groups, n = d_state)
    C  [b, s, g, n]   output projections
    Returns y [b, s, h, p] and final state [b, h, p, n].
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:
        # pad with dt=0 / x=0 positions: decay exp(0)=1 and zero input leave
        # the carried state untouched; padded outputs are sliced off below.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g

    xbar = x * dt[..., None]                                # dt-weighted input
    da = dt * A[None, None, :]                              # [b,s,h] log-decay
    # reshape into chunks
    xc = xbar.reshape(b, nc, chunk, h, p)
    dac = da.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    # cumulative log decay within chunk
    cum = jnp.cumsum(dac, axis=2)                           # [b,nc,l,h]
    # intra-chunk (dual / quadratic) term:
    #   y_intra[i] = sum_{j<=i} C_i . B_j * exp(cum_i - cum_j) xbar_j
    Bh = jnp.repeat(Bc, rep, axis=3)                        # [b,nc,l,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bclhn,bcmhn->bclmh", Ch, Bh)       # l=query m=key
    # clamp the exponent at 0: the upper triangle (j > i, positive exponent)
    # is masked below, but letting it overflow to inf first produces
    # 0 * inf = NaN in the backward pass of the where().
    decay = jnp.exp(jnp.minimum(
        cum[:, :, :, None, :] - cum[:, :, None, :, :], 0.0))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(causal[None, None, :, :, None], scores * decay, 0.0)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w, xc)

    # chunk summary states: S_c = sum_j exp(cum_last - cum_j) B_j ⊗ xbar_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # [b,nc,l,h]
    state_c = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                         Bh, decay_to_end, xc)

    # inter-chunk recurrence over chunk summaries
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # [b,nc,h]

    def body(carry, inp):
        s_prev = carry                                      # [b,h,p,n]
        st, dec = inp                                       # [b,h,p,n], [b,h]
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    st_t = state_c.transpose(1, 0, 2, 3, 4)                 # [nc,b,h,p,n]
    dec_t = chunk_decay.transpose(1, 0, 2)                  # [nc,b,h]
    final_state, prev_states = jax.lax.scan(
        body, jnp.zeros((b, h, p, n), jnp.float32), (st_t.astype(jnp.float32),
                                                     dec_t.astype(jnp.float32)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # [b,nc,h,p,n]

    # inter-chunk contribution: y_inter[i] = C_i exp(cum_i) S_prev
    y_inter = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                         Ch, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    return y[:, :s_orig], final_state


def ssd_recurrent(x, dt, A, B, C, state):
    """Single-token SSD decode step.

    x [b,h,p], dt [b,h], B/C [b,g,n], state [b,h,p,n] -> (y, state')."""
    g = B.shape[1]
    h = x.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                         # [b,h,n]
    Ch = jnp.repeat(C, rep, axis=1)
    decay = jnp.exp(dt * A[None, :])[..., None, None]       # [b,h,1,1]
    upd = (dt[..., None] * x)[..., None] * Bh[:, :, None, :]
    state = state * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y, state
