"""The paper's consistency models as gradient-synchronization policies for
pod-scale SPMD training (see DESIGN.md §3 for the mapping).

- BSP   — the standard fused end-of-step gradient mean over the data axes.
- SSP(s) — *delayed gradient application*: the train state carries a FIFO of
  ``s`` gradient pytrees; step ``t`` applies the (all-reduced) gradient from
  step ``t-s`` and enqueues the fresh one.  On hardware, this lets the
  collective for grad_t overlap up to ``s`` steps of compute — exactly SSP's
  bounded-staleness window, with the staleness now buying collective-latency
  hiding rather than straggler tolerance (there are no stragglers inside one
  SPMD program).  ``s=0`` degenerates to BSP.
- ESSP  — *eager bucketed collectives*: gradients are reduced per layer
  bucket as they are produced instead of as one fused tree at the end,
  mirroring ESSPTable's push-as-ready callbacks.  Same payload bytes, many
  smaller collectives that the scheduler can overlap with the remaining
  backward pass; we quantify the schedule difference in §Roofline.

All three are expressed through two orthogonal knobs on `GradSync`:
``staleness`` (FIFO depth) and ``n_buckets`` (collective granularity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.consistency import ConsistencyConfig


@dataclass(frozen=True)
class GradSync:
    model: str = "bsp"            # bsp | ssp | essp
    staleness: int = 0            # SSP FIFO depth (0 = synchronous apply)
    n_buckets: int = 1            # ESSP: number of eager collective buckets

    @classmethod
    def from_consistency(cls, c: ConsistencyConfig, n_buckets: int = 8):
        if c.model == "bsp":
            return cls("bsp", 0, 1)
        if c.model == "ssp":
            return cls("ssp", c.staleness, 1)
        if c.model == "essp":
            return cls("essp", c.staleness, n_buckets)
        raise ValueError(f"{c.model} has no pod-side realization "
                         "(VAP is simulator-only; see DESIGN.md)")


# --------------------------------------------------------------------------
# bucketed collective mean (ESSP's eager push schedule)
# --------------------------------------------------------------------------
def bucket_assignment(grads, n_buckets: int):
    """Greedy size-balanced assignment of leaves to buckets."""
    leaves, _ = jax.tree_util.tree_flatten(grads)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    order = sorted(range(len(leaves)), key=lambda i: -sizes[i])
    loads = [0] * n_buckets
    assign = [0] * len(leaves)
    for i in order:
        b = loads.index(min(loads))
        assign[i] = b
        loads[b] += sizes[i]
    return assign


def psum_mean_bucketed(grads, axis_names, n_buckets: int):
    """Mean-reduce gradients over mesh axes in ``n_buckets`` separate
    collectives (1 bucket = the fused BSP schedule).

    Inside ``shard_map`` this lowers to explicit psums; under plain pjit
    (params replicated over data axes) XLA inserts the equivalent
    all-reduces — bucketing still controls how many independent collectives
    appear in the HLO.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if n_buckets <= 1:
        reduced = [jax.lax.pmean(l, axis_names) for l in leaves]
        return jax.tree_util.tree_unflatten(treedef, reduced)
    assign = bucket_assignment(grads, n_buckets)
    out = [None] * len(leaves)
    for b in range(n_buckets):
        idx = [i for i, a in enumerate(assign) if a == b]
        if not idx:
            continue
        # one logical collective per bucket: reduce leaves of this bucket
        group = [jax.lax.pmean(leaves[i], axis_names) for i in idx]
        for i, g in zip(idx, group, strict=True):
            out[i] = g
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# SSP gradient FIFO
# --------------------------------------------------------------------------
def init_fifo(sync: GradSync, params):
    """Gradient FIFO of depth ``staleness`` (empty for BSP/ESSP with s=0).

    Leaves are stacked along a leading FIFO axis to keep the pytree static.
    """
    if sync.staleness == 0:
        return None
    def z(p):
        return jnp.zeros((sync.staleness,) + p.shape, jnp.float32)
    return {"buf": jax.tree.map(z, params),
            "filled": jnp.zeros((), jnp.int32)}


def push_pop(fifo, grads):
    """Push fresh grads, pop the stalest entry.

    Returns (stale_grads, new_fifo, valid) — ``valid`` is 0 during warm-up
    (the FIFO not yet full: apply nothing, matching SSP's first ``s`` clocks
    where nothing is guaranteed-visible yet).
    """
    s = jax.tree.leaves(fifo["buf"])[0].shape[0]
    popped = jax.tree.map(lambda b: b[0], fifo["buf"])
    pushed = jax.tree.map(
        lambda b, g: jnp.concatenate(
            [b[1:], g.astype(jnp.float32)[None]], axis=0),
        fifo["buf"], grads)
    filled = jnp.minimum(fifo["filled"] + 1, s)
    valid = (fifo["filled"] >= s).astype(jnp.float32)
    return popped, {"buf": pushed, "filled": filled}, valid


def sync_gradients(sync: GradSync, grads, fifo, data_axes=("data",)):
    """Full consistency pipeline for one step.

    Returns (grads_to_apply, new_fifo, apply_scale).  ``apply_scale`` is 0/1
    during SSP warm-up.  When running under pjit (no named axes in scope),
    pass ``data_axes=()`` — the all-reduce is implicit in the sharding.
    """
    if data_axes:
        grads = psum_mean_bucketed(grads, data_axes, sync.n_buckets)
    if sync.staleness == 0 or fifo is None:
        return grads, fifo, jnp.ones(())
    stale, fifo, valid = push_pop(fifo, grads)
    return stale, fifo, valid
