"""Collective-schedule analysis: the ESSP exposure model on pods.

The paper's Fig 1-right argument — eager pushes hide communication behind
computation — maps on a pod to *collective exposure*: how much collective
time cannot be overlapped with compute.  Given per-step compute time and a
bucketed collective schedule, this module computes the exposed time under
the simple "overlap with remaining backward" model:

- **lazy (1 bucket)**: the fused gradient collective starts when the whole
  backward pass is done — fully exposed.
- **eager (B buckets)**: bucket i's collective starts as soon as its layers'
  gradients exist, overlapping the remaining backward compute; only what
  spills past the end of compute is exposed.

This is the scheduling intuition behind the ESSP mapping; the dry-run HLO
gives the bytes (utils/hlo.py) and compute/collective terms (roofline),
and this model turns a (compute_s, collective_s, n_buckets) triple into an
exposed-time estimate used in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ScheduleModel:
    compute_s: float          # backward-pass compute time per step
    collective_s: float       # total gradient-collective time per step
    n_buckets: int = 1

    def exposed_s(self) -> float:
        """Exposed (non-overlapped) collective seconds per step.

        Buckets become ready uniformly through the backward pass; bucket i
        (0-based, reverse layer order) is ready at compute * (i+1)/B and
        takes collective_s/B.  Each bucket runs after both its readiness
        and the previous bucket's completion (one shared ICI channel).
        """
        B = max(1, self.n_buckets)
        t = 0.0
        per = self.collective_s / B
        for i in range(B):
            ready = self.compute_s * (i + 1) / B
            t = max(t, ready) + per
        return max(0.0, t - self.compute_s)

    def speedup_vs_lazy(self) -> float:
        lazy = ScheduleModel(self.compute_s, self.collective_s, 1)
        mine = self.compute_s + self.exposed_s()
        base = lazy.compute_s + lazy.exposed_s()
        return base / mine


def exposure_table(compute_s: float, collective_s: float,
                   buckets=(1, 2, 4, 8, 16, 32)) -> list:
    """Exposed seconds + step time for a sweep of bucket counts."""
    rows = []
    for b in buckets:
        m = ScheduleModel(compute_s, collective_s, b)
        e = m.exposed_s()
        rows.append({"buckets": b, "exposed_s": e,
                     "step_s": compute_s + e,
                     "speedup_vs_lazy": m.speedup_vs_lazy()})
    return rows
