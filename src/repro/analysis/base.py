"""Rule engine of the consistency-contract checker (``repro.analysis``).

The analyzer is a *static* pass: it parses every Python module under the
scanned roots into an AST and runs a registry of rule checkers over each.
Nothing is imported or executed — the checker runs in milliseconds and has
no JAX dependency, so it can gate CI before any compile happens.

Vocabulary shared by the rule modules:

- **traced context** — a function whose body is (or may be) staged by a JAX
  transform: decorated with ``jit``/``pmap``, passed by name to
  ``jit``/``vmap``/``lax.scan``/``shard_map``/``pallas_call``/..., returned
  by a ``make_*`` factory (the repo's idiom for building jit targets), or
  lexically nested in / called from one of those.  Python-level control
  flow on *traced values* inside such a context is a recompile (or
  concretization error) hazard — rule family ``recompile``.
- **suppression** — an inline ``# analysis: ignore[rule-id] -- reason``
  comment on the flagged line.  ``--strict`` additionally reports ignores
  written without a reason (``bare-ignore``): every intentional exception
  must say *why*.  A repo-level suppression file (``--suppressions``,
  lines of ``path-glob:rule-id``) covers generated or vendored code.

Rule checkers are registered with :func:`checker`; each returns
`Finding`s tagged with a rule id from :data:`RULE_DOCS` (the catalog the
CLI prints with ``--list-rules``).
"""
from __future__ import annotations

import ast
import fnmatch
import os
import re
from dataclasses import dataclass

IGNORE_RE = re.compile(
    r"#\s*analysis:\s*ignore\[([A-Za-z0-9_\-, ]+)\]"
    r"(?:\s*--\s*(\S.*))?")

# rule id -> one-line doc (the catalog; see the rule modules for details)
RULE_DOCS: dict = {}

# registered checker callables: fn(module: ModuleInfo, ctx: RepoContext)
CHECKERS: list = []


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def checker(rule_ids: dict):
    """Register a rule checker along with the rule ids it may emit."""
    def deco(fn):
        RULE_DOCS.update(rule_ids)
        CHECKERS.append(fn)
        return fn
    return deco


def add_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]


def dotted(node) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def enclosing_function(node):
    """Nearest enclosing FunctionDef/AsyncFunctionDef/Lambda (or None)."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return cur
        cur = getattr(cur, "parent", None)
    return None


class ModuleInfo:
    """One parsed module plus its inline suppressions."""

    def __init__(self, path: str, source: str, rel: str | None = None):
        self.path = path
        self.rel = (rel or path).replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        add_parents(self.tree)
        self.name = os.path.splitext(os.path.basename(path))[0]
        # line -> suppressed rule ids; bare = ignores missing a reason
        self.ignores: dict = {}
        self.bare_ignores: list = []
        for ln, text in enumerate(source.splitlines(), 1):
            m = IGNORE_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")
                         if r.strip()}
                self.ignores[ln] = rules
                if not (m.group(2) or "").strip():
                    self.bare_ignores.append((ln, tuple(sorted(rules))))

    def suppressed(self, rule: str, line: int) -> bool:
        rules = self.ignores.get(line)
        return bool(rules) and (rule in rules or "*" in rules)


# --------------------------------------------------------------------------
# repo context: knowledge extracted statically from the scanned tree
# --------------------------------------------------------------------------

# Fallbacks when the scan set does not contain the repo source (e.g. the
# fixture tests): the knob split of `repro.core.consistency` at the time of
# writing, and the mesh axes of `repro.launch.mesh`.
_DEFAULT_DATA = {"staleness", "v0", "push_prob", "straggler_prob",
                 "straggler_workers", "straggler_rate",
                 "s_xpod", "t_net_intra", "t_net_xpod",
                 "agg_clocks", "topk_frac"}
_DEFAULT_META = {"model", "read_my_writes", "window", "max_extra_delay",
                 "n_pods", "quant", "wire"}
_DEFAULT_AXES = {"data", "model", "pod", "batch"}


def _literal_strings(node) -> set:
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.add(n.value)
    return out


def _tuple_of_names(node) -> set | None:
    """String elements of a literal tuple/list/set, else None."""
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        vals = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                vals.add(e.value)
            else:
                return None
        return vals
    return None


class RepoContext:
    """Statically extracted repo knowledge shared by the rule checkers."""

    def __init__(self, modules: list):
        self.modules = modules
        self.knob_data = set(_DEFAULT_DATA)
        self.knob_meta = set(_DEFAULT_META)
        self.knob_bounds: dict = {}
        self.int_knobs: set = set()
        self.mesh_axes = set(_DEFAULT_AXES)
        self.consistency_mod: ModuleInfo | None = None
        # (kernel module name, function name) pairs dispatched with a jnp
        # reference fallback in kernels/ops.py
        self.pallas_dispatched: set = set()
        self.ref_names: set = set()
        for mod in modules:
            if mod.rel.endswith("core/consistency.py"):
                self._load_knobs(mod)
            if mod.rel.endswith("launch/mesh.py"):
                self.mesh_axes |= _literal_strings(mod.tree)
            if mod.rel.endswith("kernels/ops.py"):
                self._load_dispatch(mod)
            if mod.rel.endswith("kernels/ref.py"):
                self.ref_names |= {
                    n.name for n in mod.tree.body
                    if isinstance(n, ast.FunctionDef)}

    def _load_knobs(self, mod: ModuleInfo) -> None:
        self.consistency_mod = mod
        for stmt in mod.tree.body:
            if not (isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                continue
            name = stmt.targets[0].id
            vals = _tuple_of_names(stmt.value)
            if name == "DATA_FIELDS" and vals is not None:
                self.knob_data = vals
            elif name == "META_FIELDS" and vals is not None:
                self.knob_meta = vals
            elif name == "INT_KNOBS" and vals is not None:
                self.int_knobs = vals
            elif name == "KNOB_BOUNDS" and isinstance(stmt.value, ast.Dict):
                self.knob_bounds = {
                    k.value: True for k in stmt.value.keys
                    if isinstance(k, ast.Constant)}

    def _load_dispatch(self, mod: ModuleInfo) -> None:
        """Parse kernels/ops.py: a kernel function counts as *registered*
        when some dispatch function references both ``<alias>.<fn>`` and a
        ``ref.*`` fallback."""
        for fn in mod.tree.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            aliases = {"ref": "ref"}
            for st in ast.walk(fn):
                if isinstance(st, ast.ImportFrom):
                    for a in st.names:
                        aliases[a.asname or a.name] = a.name
            attrs = [(n.value.id, n.attr) for n in ast.walk(fn)
                     if isinstance(n, ast.Attribute)
                     and isinstance(n.value, ast.Name)]
            has_ref = any(aliases.get(base) == "ref" for base, _ in attrs)
            if not has_ref:
                continue
            for base, attr in attrs:
                target_mod = aliases.get(base)
                if target_mod and target_mod != "ref":
                    self.pallas_dispatched.add((target_mod, attr))


# --------------------------------------------------------------------------
# traced-context detection
# --------------------------------------------------------------------------

# call names (last dotted segment) that stage their function arguments
TRANSFORM_CALLEES = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint", "remat",
    "scan", "fori_loop", "while_loop", "cond", "switch", "map",
    "associative_scan", "shard_map", "pallas_call", "custom_vjp",
    "custom_jvp", "named_call",
}


def _decorator_traced(dec) -> bool:
    d = dotted(dec)
    if d and d.split(".")[-1] in ("jit", "pmap"):
        return True
    if isinstance(dec, ast.Call):
        if _decorator_traced(dec.func):
            return True
        return any(_decorator_traced(a) for a in dec.args)
    return False


def traced_functions(mod: ModuleInfo) -> dict:
    """Map of function/lambda nodes considered traced contexts -> reason.

    Heuristic closure: decorated with jit/pmap; passed by name (or as a
    lambda) to a staging transform; defined inside and returned by a
    ``make_*`` factory; nested in a traced function; or called by name
    from a traced body (fixpoint within the module).
    """
    defs: dict = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    traced: dict = {}

    def mark(node, reason):
        if node not in traced:
            traced[node] = reason

    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_decorator_traced(d) for d in node.decorator_list):
                mark(node, "jit-decorated")
            # `make_*` factory returning an inner def: the repo idiom for
            # building jit targets (make_run_fn/body, make_train_step/...)
            outer = enclosing_function(node)
            if (isinstance(outer, ast.FunctionDef)
                    and outer.name.startswith("make_")):
                for ret in ast.walk(outer):
                    if (isinstance(ret, ast.Return)
                            and ret.value is not None):
                        for n in ast.walk(ret.value):
                            if (isinstance(n, ast.Name)
                                    and n.id == node.name):
                                mark(node, f"returned by {outer.name}")
        elif isinstance(node, ast.Call):
            callee = dotted(node.func)
            base = callee.split(".")[-1] if callee else None
            if base not in TRANSFORM_CALLEES:
                continue
            cargs = list(node.args) + [kw.value for kw in node.keywords]
            for arg in cargs:
                if isinstance(arg, ast.Name) and arg.id in defs:
                    for d in defs[arg.id]:
                        mark(d, f"passed to {callee}")
                elif isinstance(arg, ast.Lambda):
                    mark(arg, f"passed to {callee}")

    # fixpoint: nesting + same-module calls from traced bodies
    changed = True
    while changed:
        changed = False
        for node in list(traced):
            for inner in ast.walk(node):
                if inner is node:
                    continue
                if isinstance(inner, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)):
                    if inner not in traced:
                        traced[inner] = "nested in traced context"
                        changed = True
                if isinstance(inner, ast.Call):
                    callee = dotted(inner.func)
                    if callee and "." not in callee and callee in defs:
                        for d in defs[callee]:
                            if d not in traced:
                                traced[d] = f"called from traced context"
                                changed = True
    return traced


def statements_of(fnode):
    """Direct statements of a function body, recursing into compound
    statements but NOT into nested function/lambda definitions."""
    out = []

    def visit(stmts):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            out.append(st)
            for field in ("body", "orelse", "finalbody"):
                if hasattr(st, field):
                    visit(getattr(st, field))
            if hasattr(st, "handlers"):
                for h in st.handlers:
                    visit(h.body)
    if isinstance(fnode, ast.Lambda):
        return []
    visit(fnode.body)
    return out


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def collect_files(paths) -> list:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = [d for d in dirs if d != "__pycache__"]
            for n in sorted(names):
                if n.endswith(".py"):
                    files.append(os.path.join(root, n))
    return files


def load_modules(paths):
    """(modules, findings): unparsable files become syntax-error findings."""
    modules, findings = [], []
    for f in collect_files(paths):
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        try:
            modules.append(ModuleInfo(f, src, rel=os.path.relpath(f)))
        except SyntaxError as e:
            findings.append(Finding("syntax-error", f, e.lineno or 0,
                                    str(e.msg)))
    return modules, findings


def load_suppression_file(path: str) -> list:
    """Lines of ``path-glob:rule-id  # reason`` -> [(glob, rule)]."""
    out = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            glob, _, rule = line.rpartition(":")
            if glob and rule:
                out.append((glob, rule))
    return out


def analyze_paths(paths, strict: bool = False,
                  suppressions: list | None = None,
                  model_check: bool = True):
    """Run every registered rule over the modules under ``paths``.

    Returns the filtered (non-suppressed) findings, sorted by location.
    ``suppressions`` is a list of ``(path-glob, rule-id)`` pairs from a
    repo-level suppression file.
    """
    # the rule modules self-register on import
    from . import callbacks, collectives, pallas_rules, pytree_rules, \
        recompile, rng  # noqa: F401
    modules, findings = load_modules(paths)
    ctx = RepoContext(modules)
    for mod in modules:
        for check in CHECKERS:
            for f in check(mod, ctx):
                if mod.suppressed(f.rule, f.line):
                    continue
                findings.append(f)
        if strict:
            for ln, rules in mod.bare_ignores:
                findings.append(Finding(
                    "bare-ignore", mod.rel, ln,
                    f"suppression of {', '.join(rules)} has no reason; "
                    f"write `# analysis: ignore[rule] -- why`"))
    if model_check:
        from .staleness_check import check_repo
        findings.extend(check_repo(modules))
    if suppressions:
        findings = [
            f for f in findings
            if not any(r == f.rule and fnmatch.fnmatch(f.path, g)
                       for g, r in suppressions)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
