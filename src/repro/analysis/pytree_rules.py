"""Rule family ``pytree``: registered-dataclass and knob-split contracts.

- ``pytree-frozen`` — a ``jax.tree_util.register_dataclass`` dataclass
  must be ``frozen=True``.  Registered pytrees are flattened/unflattened
  by value; in-place mutation of an instance desynchronizes it from the
  traced copies JAX holds, and a frozen class turns that bug into an
  immediate ``FrozenInstanceError``.
- ``pytree-mutation`` — attribute assignment (or
  ``object.__setattr__``) on an instance of a registered pytree class.
- ``knob-split`` — the static/traced leaf classification of
  ``ConsistencyConfig`` must be internally consistent: ``DATA_FIELDS``
  and ``META_FIELDS`` partition the dataclass fields exactly (no overlap,
  no stragglers), every ``KNOB_BOUNDS`` entry is a traced DATA field
  (bounds describe sweepable knobs), and ``INT_KNOBS`` is a subset of
  ``KNOB_BOUNDS``.  This is the contract the sweep engine, the tuner and
  the recompile rules all assume.
"""
from __future__ import annotations

import ast

from .base import Finding, checker, dotted

_DOCS = {
    "pytree-frozen": "registered pytree dataclass is not frozen=True",
    "pytree-mutation": "attribute assignment on a registered pytree "
                       "instance",
    "knob-split": "ConsistencyConfig static/traced field classification "
                  "is inconsistent",
}


def _is_register_dataclass(dec) -> bool:
    d = dotted(dec if not isinstance(dec, ast.Call) else dec.func)
    return bool(d) and d.split(".")[-1] == "register_dataclass"


def _dataclass_frozen(cls) -> bool | None:
    """True/False if decorated with @dataclass, None if not a dataclass."""
    for dec in cls.decorator_list:
        d = dotted(dec if not isinstance(dec, ast.Call) else dec.func)
        if d and d.split(".")[-1] == "dataclass":
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "frozen" \
                            and isinstance(kw.value, ast.Constant):
                        return bool(kw.value.value)
            return False
    return None


def _registered_classes(mod) -> dict:
    """Registered pytree dataclass name -> ClassDef in this module."""
    out = {}
    classes = {n.name: n for n in ast.walk(mod.tree)
               if isinstance(n, ast.ClassDef)}
    for name, cls in classes.items():
        if any(_is_register_dataclass(d) for d in cls.decorator_list):
            out[name] = cls
    # call form: jax.tree_util.register_dataclass(Cls, ...)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and _is_register_dataclass(node.func):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name) and arg.id in classes:
                    out[arg.id] = classes[arg.id]
    return out


def _instance_vars(mod, class_names: set) -> dict:
    """var name -> class name, for vars provably bound to instances."""
    out = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            d = dotted(node.value.func)
            if d and d.split(".")[-1] in class_names:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = d.split(".")[-1]
        elif isinstance(node, ast.arg) and node.annotation is not None:
            d = dotted(node.annotation)
            if d and d.split(".")[-1] in class_names:
                out[node.arg] = d.split(".")[-1]
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.annotation is not None:
            d = dotted(node.annotation)
            if d and d.split(".")[-1] in class_names:
                out[node.target.id] = d.split(".")[-1]
    return out


@checker(_DOCS)
def check_pytree(mod, ctx):
    findings = []
    registered = _registered_classes(mod)
    for name, cls in registered.items():
        frozen = _dataclass_frozen(cls)
        if frozen is False:
            findings.append(Finding(
                "pytree-frozen", mod.rel, cls.lineno,
                f"registered pytree dataclass `{name}` is not "
                f"frozen=True — in-place mutation would desynchronize "
                f"instances from their traced flatten/unflatten copies"))

    if registered:
        inst = _instance_vars(mod, set(registered))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id in inst \
                            and t.value.id != "self":
                        findings.append(Finding(
                            "pytree-mutation", mod.rel, node.lineno,
                            f"attribute assignment on registered pytree "
                            f"instance `{t.value.id}` "
                            f"({inst[t.value.id]}) — use dataclasses."
                            f"replace / construct a new instance"))
            elif isinstance(node, ast.Call):
                d = dotted(node.func)
                if d == "object.__setattr__" and node.args \
                        and isinstance(node.args[0], ast.Name) \
                        and node.args[0].id in inst:
                    findings.append(Finding(
                        "pytree-mutation", mod.rel, node.lineno,
                        f"object.__setattr__ on registered pytree "
                        f"instance `{node.args[0].id}` "
                        f"({inst[node.args[0].id]})"))

    findings.extend(_check_knob_split(mod, ctx))
    return findings


def _check_knob_split(mod, ctx):
    """Consistency of the DATA/META split — only in the defining module."""
    if ctx.consistency_mod is not mod or mod is None:
        return []
    findings = []
    line = 1
    fields = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) \
                and node.name == "ConsistencyConfig":
            line = node.lineno
            for st in node.body:
                if isinstance(st, ast.AnnAssign) \
                        and isinstance(st.target, ast.Name):
                    fields.add(st.target.id)
    data, meta = ctx.knob_data, ctx.knob_meta
    overlap = sorted(data & meta)
    if overlap:
        findings.append(Finding(
            "knob-split", mod.rel, line,
            f"fields in both DATA_FIELDS and META_FIELDS: {overlap}"))
    if fields:
        missing = sorted(fields - data - meta)
        phantom = sorted((data | meta) - fields)
        if missing:
            findings.append(Finding(
                "knob-split", mod.rel, line,
                f"ConsistencyConfig fields in neither DATA_FIELDS nor "
                f"META_FIELDS: {missing} — unclassified leaves break the "
                f"pytree registration"))
        if phantom:
            findings.append(Finding(
                "knob-split", mod.rel, line,
                f"DATA_FIELDS/META_FIELDS name non-existent fields: "
                f"{phantom}"))
    bad_bounds = sorted(set(ctx.knob_bounds) - data)
    if bad_bounds:
        findings.append(Finding(
            "knob-split", mod.rel, line,
            f"KNOB_BOUNDS entries that are not traced DATA fields: "
            f"{bad_bounds} — bounds describe sweepable (traced) knobs"))
    bad_int = sorted(ctx.int_knobs - set(ctx.knob_bounds))
    if bad_int:
        findings.append(Finding(
            "knob-split", mod.rel, line,
            f"INT_KNOBS not covered by KNOB_BOUNDS: {bad_int}"))
    return findings
