"""Rule family ``collectives``: axis hygiene + the churn mask rule.

- ``axis-unbound`` — a collective (``psum``/``all_gather``/``ppermute``/
  ...) names a mesh axis as a string literal that no ``shard_map`` /
  ``Mesh`` spec in the scanned tree binds.  An unbound axis name fails
  only at trace time *on the sharded path* — single-device CI never
  executes it, so this is exactly the class of bug the forced-device
  lanes exist for, caught statically instead.
- ``collective-outside-shardmap`` — a collective with a literal axis name
  in a function that is never (transitively) passed to ``shard_map`` —
  the axis could not be bound at the call site.
- ``unmasked-gather`` — the PR 6 churn race rule: inside churn-aware code
  (any function that derives a ``live``/``churn_live`` mask), a
  worker-axis ``all_gather``/``psum`` of a plain variable that was never
  run through the live mask (``jnp.where(live..., x, 0)``).  A dead
  producer's stale shard entering a reduction silently diverges from the
  survivor-set oracle; masking *before* the gather keeps reductions
  order-identical with the simulator.

Variable (non-literal) axis arguments are skipped — e.g. the
``worker_axes`` generalization in ``psrun.runtime`` and the
``axis_names`` parameter of ``psdist.grad_sync`` bind axes dynamically,
which this pass cannot refute.
"""
from __future__ import annotations

import ast

from .base import Finding, checker, dotted, enclosing_function

COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather", "ppermute",
               "all_to_all", "pshuffle", "psum_scatter", "axis_index"}
# collectives whose *operand* is a reduction over producers (the mask rule)
REDUCING = {"all_gather", "psum", "pmean", "psum_scatter"}
WORKER_AXIS_LITERALS = {"data", "pod"}

_DOCS = {
    "axis-unbound": "collective names a mesh axis no shard_map/mesh spec "
                    "binds",
    "collective-outside-shardmap": "collective with a literal axis name "
                                   "outside any shard_map-staged function",
    "unmasked-gather": "worker-axis gather/psum of un-live-masked data in "
                       "churn-aware code (PR 6 masked-before-all-gather "
                       "rule)",
}


def _axis_literals(node) -> set | None:
    """Literal axis names of an axis argument, or None if dynamic."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
            else:
                return None
        return out
    return None


def _axis_arg(call, base: str):
    """The axis-name argument node of a collective call, or None."""
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis"):
            # jax.lax collectives use `axis_name`; `axis=` on all_gather is
            # the positional array axis, not a mesh axis
            if kw.arg == "axis_name":
                return kw.value
    if base == "axis_index":
        return call.args[0] if call.args else None
    return call.args[1] if len(call.args) > 1 else None


def _shardmapped_functions(mod) -> set:
    """Function nodes (transitively) staged by a shard_map in this module."""
    defs: dict = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    staged: set = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if not d or d.split(".")[-1] != "shard_map":
                continue
            for arg in node.args[:1] + [kw.value for kw in node.keywords
                                        if kw.arg in (None, "f")]:
                if isinstance(arg, ast.Name):
                    for fn in defs.get(arg.id, []):
                        staged.add(fn)
                elif isinstance(arg, ast.Lambda):
                    staged.add(arg)
    changed = True
    while changed:
        changed = False
        for fn in list(staged):
            for inner in ast.walk(fn):
                if inner is fn:
                    continue
                if isinstance(inner, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)) \
                        and inner not in staged:
                    staged.add(inner)
                    changed = True
                if isinstance(inner, ast.Call):
                    d = dotted(inner.func)
                    if d and "." not in d:
                        for fn2 in defs.get(d, []):
                            if fn2 not in staged:
                                staged.add(fn2)
                                changed = True
    return staged


def _function_masked_vars(fnode):
    """(live_vars, masked_vars) within one function body."""
    live_vars: set = set()
    masked: set = set()
    for node in ast.walk(fnode):
        if isinstance(node, ast.Assign):
            rhs_names = {n.id for n in ast.walk(node.value)
                         if isinstance(n, ast.Name)}
            is_churn_src = isinstance(node.value, ast.Call) and (
                (dotted(node.value.func) or "").split(".")[-1]
                == "churn_live")
            tgt_names = [n.id for t in node.targets
                         for n in ast.walk(t) if isinstance(n, ast.Name)]
            if is_churn_src:
                live_vars.update(tgt_names)
                continue
            if any(v in live_vars or v.startswith("live")
                   for v in rhs_names):
                live_vars.update(
                    t for t in tgt_names if t.startswith("live"))
                masked.update(tgt_names)
        for n in [node] if isinstance(node, ast.arg) else []:
            if n.arg.startswith("live"):
                live_vars.add(n.arg)
    for a in getattr(fnode, "args", None).args if hasattr(fnode, "args") \
            and not isinstance(fnode, ast.Lambda) else []:
        if a.arg.startswith("live"):
            live_vars.add(a.arg)
    return live_vars, masked


def _is_worker_axis(axis_node) -> bool:
    lits = _axis_literals(axis_node)
    if lits is not None:
        return bool(lits & WORKER_AXIS_LITERALS)
    return isinstance(axis_node, ast.Name) \
        and axis_node.id in ("worker_axes", "axes")


def _lambda_params(fnode) -> set:
    if not isinstance(fnode, ast.Lambda):
        return set()
    return {a.arg for a in fnode.args.args}


@checker(_DOCS)
def check_collectives(mod, ctx):
    findings = []
    staged = _shardmapped_functions(mod)
    known_axes = set(ctx.mesh_axes)
    # axis names bound by shard_map/Mesh specs in this very module
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            last = d.split(".")[-1] if d else ""
            if last in ("shard_map", "Mesh", "make_mesh",
                        "AbstractMesh", "PartitionSpec"):
                for n in ast.walk(node):
                    if isinstance(n, ast.Constant) \
                            and isinstance(n.value, str):
                        known_axes.add(n.value)

    # per-function churn-mask context
    fn_mask_cache: dict = {}

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if not d:
            continue
        base = d.split(".")[-1]
        if base not in COLLECTIVES:
            continue
        # require a lax-ish or bare call so that e.g. np.all_to_all in
        # unrelated code does not trip the rule
        if "." in d and "lax" not in d and not d.startswith("jax."):
            continue
        axis_node = _axis_arg(node, base)
        if axis_node is None:
            continue
        lits = _axis_literals(axis_node)
        fnode = enclosing_function(node)
        if lits is not None:
            unknown = sorted(lits - known_axes)
            if unknown:
                findings.append(Finding(
                    "axis-unbound", mod.rel, node.lineno,
                    f"`{base}` names mesh axis {unknown} not bound by any "
                    f"shard_map/mesh spec in the scanned tree"))
            in_staged = False
            cur = fnode
            while cur is not None:
                if cur in staged:
                    in_staged = True
                    break
                cur = enclosing_function(cur)
            if not in_staged:
                findings.append(Finding(
                    "collective-outside-shardmap", mod.rel, node.lineno,
                    f"`{base}('{'/'.join(sorted(lits))}')` in a function "
                    f"never passed to shard_map — the axis cannot be "
                    f"bound here"))

        # masked-before-all-gather (worker-axis reductions only)
        if base in REDUCING and node.args \
                and _is_worker_axis(axis_node) and fnode is not None:
            root = fnode
            # mask context is per outermost staged function: the step/body
            # closure shares live_* locals
            while enclosing_function(root) is not None:
                root = enclosing_function(root)
            if root not in fn_mask_cache:
                fn_mask_cache[root] = _function_masked_vars(root)
            live_vars, masked = fn_mask_cache[root]
            if not live_vars:
                continue            # not churn-aware code
            operand = node.args[0]
            if isinstance(operand, ast.Name) \
                    and operand.id not in masked \
                    and operand.id not in live_vars \
                    and operand.id not in _lambda_params(fnode):
                findings.append(Finding(
                    "unmasked-gather", mod.rel, node.lineno,
                    f"worker-axis `{base}` of `{operand.id}` in "
                    f"churn-aware code without a prior live-mask "
                    f"(`jnp.where(live..., {operand.id}, 0)`) — dead "
                    f"producers' stale shards enter the reduction"))
    return findings
