"""The clock-step abstract interpreter + staleness model checker.

This is the ``staleness-contract`` rule: a *static race detector for the
consistency models themselves*.  The dynamic tests pin the bound on the
seeds they happen to run; this module instead

1. **extracts** the declared bound from the AST of
   ``core.delays.staleness_bound_matrix`` (symbolically evaluating its
   straight-line integer algebra, with and without the
   ``cfg.comm_active`` widening branch),
2. **extracts** the clock-update dataflow of each Trace producer — the
   enforcement trigger ``forced = cview < (c - s_eff - 1)``, the
   refresh targets (``c - 1`` intra-pod / unwired,
   ``comm.shipped_through(c, agg_clocks)`` on the wired cross-pod
   channel) and the delivery targets (``c`` /
   ``comm.shipped_end(c, agg_clocks)``) — from ``core/ps.py`` and
   ``psrun/runtime.py``, and verifies ``pods/runtime.py`` delegates its
   clock step to the psrun body (class ``PodsRuntime(PSRuntime)`` with no
   own enforcement code), and
3. **model-checks** the extracted transition system exhaustively over a
   grid of small ``(T, P, s, s_xpod, agg_clocks)`` configurations,
   including single reader-outage (churn) windows: per channel, the
   reader's visibility clock ``v`` evolves under adversarial delivery
   (the network may or may not deliver each clock — every subset is
   explored) and the invariant checked at every read is the contract

       c - 1 - v  <=  bound(channel)

   with ``bound = s`` intra-pod, ``s + s_xpod`` cross-pod, widened by
   ``+ agg_clocks - 1`` when the comm substrate aggregates shipments and
   by ``+ retry_budget`` (= two conforming flight windows,
   `comm.wire.WireFaults.retry_budget`) on the lossy-wire channel: there
   the adversary also schedules each shipment's arrival anywhere inside
   the flight window (stop-and-wait — a busy producer skips boundaries),
   and both refresh and delivery targets are capped by ``wire_tip``, the
   highest *arrived* boundary.

Channels are independent in the clock algebra (``cview`` updates are
elementwise), so checking one reader x producer channel per channel type
*is* exhaustive — the state space per config is tiny and the whole grid
runs in milliseconds, yet it covers delivery adversaries no seeded run
ever will.  An off-by-one anywhere in the widening algebra (bound,
trigger, refresh or delivery target) produces a concrete counterexample
trace; ``tests/test_analysis.py`` proves that by injecting a mutant
(``agg_clocks - 2``) and watching it get caught.

Extraction is deliberately *brittle*: if a producer's enforcement code
drifts so the patterns no longer match, the rule fails loudly
(``staleness-extract``) rather than silently verifying stale algebra.
"""
from __future__ import annotations

import ast
import itertools
from dataclasses import dataclass

from .base import RULE_DOCS, Finding, dotted

RULE_DOCS.update({
    "staleness-contract": "a read can observe a visibility clock outside "
                          "the declared staleness bound",
    "staleness-extract": "could not extract the bound/enforcement "
                         "dataflow from a Trace producer",
})

PRODUCER_FILES = ("core/ps.py", "psrun/runtime.py", "pods/runtime.py")


# --------------------------------------------------------------------------
# 1. bound extraction: symbolic evaluation of staleness_bound_matrix
# --------------------------------------------------------------------------

class ExtractionError(Exception):
    pass


def _sym_eval(node, env: dict):
    """Evaluate a straight-line integer expression over ``env``.

    ``cfg.<knob>`` attributes and plain names resolve through ``env``;
    supported operators are +, -, * and parenthesized constants — exactly
    the integer algebra the bound is allowed to use.
    """
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Attribute):
        key = node.attr
        if key in env:
            return env[key]
        raise ExtractionError(f"unknown attribute `{key}` in bound expr")
    if isinstance(node, ast.Name):
        if node.id in env:
            v = env[node.id]
            return _sym_eval(v, env) if isinstance(v, ast.AST) else v
        raise ExtractionError(f"unknown name `{node.id}` in bound expr")
    if isinstance(node, ast.BinOp):
        left = _sym_eval(node.left, env)
        right = _sym_eval(node.right, env)
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
        raise ExtractionError(
            f"unsupported operator {type(node.op).__name__} in bound expr")
    raise ExtractionError(
        f"unsupported node {type(node).__name__} in bound expr")


@dataclass(frozen=True)
class BoundModel:
    """The declared per-channel staleness bound, as extracted functions."""

    intra_expr: ast.AST
    xpod_expr: ast.AST            # without the comm widening
    xpod_wired_expr: ast.AST      # with the comm widening applied

    def bound(self, channel: str, s: int, s_xpod: int, agg: int,
              retry_budget: int = 0) -> int:
        env = {"staleness": s, "s_xpod": s_xpod, "agg_clocks": agg,
               "retry_budget": retry_budget}
        expr = {"intra": self.intra_expr,
                "xpod": self.xpod_expr,
                "xpod-wired": self.xpod_wired_expr,
                "xpod-faulted": self.xpod_wired_expr}[channel]
        return _sym_eval(expr, env)


def _inline_names(expr, environment: dict):
    """Copy ``expr`` with Name references replaced by their (already
    resolved) environment expressions."""
    class R(ast.NodeTransformer):
        def visit_Name(self, node):
            if node.id in environment:
                return environment[node.id]
            return node
    return R().visit(ast.parse(ast.unparse(expr), mode="eval")).body


def extract_bound_model_from_source(source: str) -> BoundModel:
    """Parse ``staleness_bound_matrix`` out of delays.py source text."""
    tree = ast.parse(source)
    fn = None
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) \
                and node.name == "staleness_bound_matrix":
            fn = node
    if fn is None:
        raise ExtractionError("staleness_bound_matrix not found")
    # assignments resolve eagerly, so `x = x + k` (the widening idiom)
    # inlines the *previous* x rather than recursing
    env: dict = {}
    env_wired: dict | None = None
    ret = None
    for st in fn.body:
        if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                and isinstance(st.targets[0], ast.Name):
            env[st.targets[0].id] = _inline_names(st.value, env)
        elif isinstance(st, ast.If):
            # the comm_active widening branch
            names = {dotted(n) for n in ast.walk(st.test)
                     if isinstance(n, (ast.Attribute, ast.Name))}
            if not any(d and d.endswith("comm_active") for d in names):
                raise ExtractionError(
                    "unexpected branch in staleness_bound_matrix (not on "
                    "comm_active)")
            env_wired = dict(env)
            for sub in st.body:
                if isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                        and isinstance(sub.targets[0], ast.Name):
                    env_wired[sub.targets[0].id] = _inline_names(
                        sub.value, env_wired)
        elif isinstance(st, ast.Return):
            ret = st.value
    if ret is None or not isinstance(ret, ast.Call):
        raise ExtractionError("no jnp.where return in "
                              "staleness_bound_matrix")
    d = dotted(ret.func)
    if not d or d.split(".")[-1] != "where" or len(ret.args) != 3:
        raise ExtractionError("return is not jnp.where(same, intra, xpod)")
    _, intra, xpod = ret.args
    return BoundModel(
        intra_expr=_inline_names(intra, env),
        xpod_expr=_inline_names(xpod, env),
        xpod_wired_expr=_inline_names(
            xpod, env_wired if env_wired is not None else env))


def extract_bound_model(delays_path: str) -> BoundModel:
    with open(delays_path, encoding="utf-8") as fh:
        return extract_bound_model_from_source(fh.read())


# --------------------------------------------------------------------------
# 2. producer extraction: the enforcement/delivery dataflow
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class EnforcementModel:
    """The clock-update dataflow of one Trace producer."""

    producer: str
    trigger_offset: int       # forced = cview < (c - s_eff - OFFSET)
    refresh_lag: int          # intra/unwired refresh target = c - LAG
    xpod_refresh_shipped: bool  # wired refresh -> shipped_through(c, agg)
    delivery_shipped: bool      # wired delivery -> shipped_end(c, agg)
    xpod_refresh_capped: bool = False  # faulted refresh min()s wire_tip
    delivery_capped: bool = False      # faulted delivery min()s wire_tip
    delegate: str | None = None


def _match_trigger(node) -> int | None:
    """``cview < (c - s_eff - K)`` -> K."""
    if not (isinstance(node, ast.Compare) and len(node.ops) == 1
            and isinstance(node.ops[0], ast.Lt)
            and isinstance(node.left, ast.Name)
            and node.left.id == "cview"):
        return None
    rhs = node.comparators[0]
    # (c - s_eff) - K
    if isinstance(rhs, ast.BinOp) and isinstance(rhs.op, ast.Sub) \
            and isinstance(rhs.right, ast.Constant) \
            and isinstance(rhs.left, ast.BinOp) \
            and isinstance(rhs.left.op, ast.Sub):
        inner = rhs.left
        if isinstance(inner.left, ast.Name) and inner.left.id == "c" \
                and isinstance(inner.right, ast.Name) \
                and inner.right.id == "s_eff":
            return rhs.right.value
    return None


def _calls_named(node, name: str) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = dotted(n.func)
            if d and d.split(".")[-1] == name:
                return True
    return False


def _caps_wire_tip(node) -> bool:
    """True when the expression reads ``...["wire_tip"]`` — the faulted
    target's arrived-boundary cap."""
    for n in ast.walk(node):
        if isinstance(n, ast.Subscript):
            sl = n.slice
            if isinstance(sl, ast.Constant) and sl.value == "wire_tip":
                return True
    return False


def _refresh_lag(node) -> int | None:
    """``c - K`` -> K (the non-shipped refresh/delivery target)."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
            and isinstance(node.left, ast.Name) and node.left.id == "c" \
            and isinstance(node.right, ast.Constant):
        return node.right.value
    if isinstance(node, ast.Name) and node.id == "c":
        return 0
    return None


def extract_enforcement_from_source(source: str,
                                    producer: str) -> EnforcementModel:
    """Extract the SSP/ESSP enforcement dataflow from a producer module."""
    tree = ast.parse(source)

    # delegation: PodsRuntime subclasses PSRuntime and defines no
    # enforcement of its own — its clock step IS the psrun body
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) \
                and any(isinstance(b, (ast.Name, ast.Attribute))
                        and (dotted(b) or "").split(".")[-1] == "PSRuntime"
                        for b in node.bases):
            if any(_match_trigger(n) is not None
                   for n in ast.walk(node)):
                raise ExtractionError(
                    f"{producer}: delegating runtime re-implements "
                    f"enforcement — update the model checker")
            return EnforcementModel(
                producer=producer, trigger_offset=1, refresh_lag=1,
                xpod_refresh_shipped=True, delivery_shipped=True,
                xpod_refresh_capped=True, delivery_capped=True,
                delegate="psrun/runtime.py")

    trigger = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "forced":
            k = _match_trigger(node.value)
            if k is not None:
                trigger = k
    if trigger is None:
        raise ExtractionError(
            f"{producer}: no `forced = cview < (c - s_eff - K)` "
            f"enforcement trigger found")
    if not any(_calls_named(n, "staleness_bound_matrix")
               for n in ast.walk(tree) if isinstance(n, ast.Assign)
               and any(isinstance(t, ast.Name) and t.id == "s_eff"
                       for t in n.targets)):
        raise ExtractionError(
            f"{producer}: `s_eff` is not derived from "
            f"staleness_bound_matrix — the declared bound is not the one "
            f"enforced")

    # refresh/delivery targets: `cview = jnp.where(forced, c - K, cview)`
    # on the unwired path; on the wired path the target routes through
    # `tgt = jnp.where(in_pod, c - K, comm.shipped_through(c, agg))` (and
    # delivery through comm.shipped_end) before the forced/delivered where
    refresh_lag = None
    xpod_refresh_shipped = False
    delivery_shipped = False
    xpod_refresh_capped = False
    delivery_capped = False
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        d = dotted(node.value.func)
        if not d or d.split(".")[-1] != "where":
            continue
        args = node.value.args
        if len(args) != 3:
            continue
        cond, then, _other = args
        cond_name = cond.id if isinstance(cond, ast.Name) else None
        if cond_name == "forced" and _refresh_lag(then) is not None:
            refresh_lag = _refresh_lag(then)
        if _calls_named(node.value, "shipped_through"):
            xpod_refresh_shipped = True
            if _caps_wire_tip(node.value):
                xpod_refresh_capped = True   # the faulted branch's tgt
            if refresh_lag is None and _refresh_lag(then) is not None:
                refresh_lag = _refresh_lag(then)   # the intra arm of tgt
        if _calls_named(node.value, "shipped_end"):
            delivery_shipped = True
            if _caps_wire_tip(node.value):
                delivery_capped = True
    if refresh_lag is None:
        raise ExtractionError(
            f"{producer}: no forced-refresh target "
            f"`jnp.where(forced, c - K, cview)` found")
    if not xpod_refresh_shipped:
        raise ExtractionError(
            f"{producer}: wired cross-pod refresh does not route through "
            f"comm.shipped_through — a forced refresh could observe "
            f"unshipped clocks")
    if not delivery_shipped:
        raise ExtractionError(
            f"{producer}: wired delivery does not route through "
            f"comm.shipped_end")
    if not xpod_refresh_capped:
        raise ExtractionError(
            f"{producer}: no faulted cross-pod refresh caps the shipped "
            f"boundary on cst[\"wire_tip\"] — a lossy-wire refresh could "
            f"observe unarrived clocks")
    if not delivery_capped:
        raise ExtractionError(
            f"{producer}: no faulted delivery caps comm.shipped_end on "
            f"cst[\"wire_tip\"]")
    return EnforcementModel(
        producer=producer, trigger_offset=trigger,
        refresh_lag=refresh_lag,
        xpod_refresh_shipped=xpod_refresh_shipped,
        delivery_shipped=delivery_shipped,
        xpod_refresh_capped=xpod_refresh_capped,
        delivery_capped=delivery_capped)


def extract_enforcement(path: str, producer: str) -> EnforcementModel:
    with open(path, encoding="utf-8") as fh:
        return extract_enforcement_from_source(fh.read(), producer)


# --------------------------------------------------------------------------
# 3. the model checker
# --------------------------------------------------------------------------

def _shipped_through(c: int, agg: int) -> int:
    return (c // agg) * agg - 1


def _shipped_end(c: int, agg: int) -> int:
    return ((c + 1) // agg) * agg - 1


@dataclass(frozen=True)
class Counterexample:
    producer: str
    channel: str
    config: tuple              # (T, P, s, s_xpod, agg)
    clock: int
    cview: int
    bound: int
    outage: tuple | None
    flight: int = 0            # conforming flight window (faulted channel)

    def __str__(self) -> str:
        T, P, s, s_xpod, agg = self.config
        churn = (f", reader dead on [{self.outage[0]},{self.outage[1]})"
                 if self.outage else "")
        faulted = (f", flight_budget={self.flight}"
                   if self.channel == "xpod-faulted" else "")
        return (f"{self.producer} {self.channel} channel, "
                f"(T={T}, P={P}, s={s}, s_xpod={s_xpod}, "
                f"agg_clocks={agg}){churn}{faulted}: read at clock "
                f"{self.clock} observes cview={self.cview} — lag "
                f"{self.clock - 1 - self.cview} > bound {self.bound}")


def check_channel(bound_model: BoundModel, enf: EnforcementModel,
                  channel: str, config: tuple,
                  outage: tuple | None = None) -> Counterexample | None:
    """Exhaustive DFS of one channel's (clock, cview) transition system.

    Per clock: (1) SSP/ESSP enforcement fires iff
    ``v < c - b - trigger_offset`` and refreshes to the channel's target,
    (2) the contract ``c - 1 - v <= b`` is checked at the read, (3) the
    adversary picks any delivery outcome for the end of the clock.  Dead
    readers (``outage``: clocks [t0, t1)) neither enforce, read, nor
    advance — their first read back must be forced back within bound.
    """
    T, _, s, s_xpod, agg = config
    b = bound_model.bound(channel, s, s_xpod, agg)
    wired = channel == "xpod-wired"
    states = {-1}                  # initial visibility: nothing seen
    for c in range(T):
        dead = outage is not None and outage[0] <= c < outage[1]
        next_states = set()
        for v in states:
            if not dead:
                if v < c - b - enf.trigger_offset:
                    if wired and enf.xpod_refresh_shipped:
                        v = max(v, _shipped_through(c, agg))
                    else:
                        v = max(v, c - enf.refresh_lag)
                if c - 1 - v > b:
                    return Counterexample(
                        producer=enf.producer, channel=channel,
                        config=config, clock=c, cview=v, bound=b,
                        outage=outage)
                # adversarial delivery: none, or advance to the channel's
                # delivery target
                next_states.add(v)
                if wired and enf.delivery_shipped:
                    next_states.add(max(v, _shipped_end(c, agg)))
                else:
                    next_states.add(max(v, c))
            else:
                next_states.add(v)   # frozen rows: no reads, no advance
        states = next_states
    return None


def check_channel_faulted(bound_model: BoundModel, enf: EnforcementModel,
                          config: tuple, flight: int,
                          outage: tuple | None = None
                          ) -> Counterexample | None:
    """Exhaustive DFS of the lossy-wire cross-pod channel.

    State is ``(v, tip, pend)``: the reader's visibility clock, the
    highest *arrived* shipment boundary (``wire_tip``), and the in-flight
    shipment as ``(boundary, arrival_clock)`` or None.  Per clock:
    (1) enforcement fires iff ``v < c - b - trigger_offset`` and
    refreshes to ``min(shipped_through(c, agg), tip)`` — the wire_tip
    cap; (2) the widened contract ``c - 1 - v <= b`` (``b`` includes
    ``retry_budget = 2 * flight``) is checked at the read; (3) a due
    arrival acks (``tip`` advances to its boundary); an idle-at-start
    producer ships at an aggregation boundary and the *conforming*
    adversary schedules its arrival anywhere in ``[c, c + flight]``
    (stop-and-wait: a busy producer skips the boundary — this is why two
    flight windows stack); (4) the adversary picks end-of-clock delivery
    or not, advancing ``v`` to ``min(shipped_end(c, agg), tip)``.
    Give-up is out of scope: a given-up shipment voids any finite bound
    (there the contract is mass conservation — `comm.wire`).
    """
    T, _, s, s_xpod, agg = config
    b = bound_model.bound("xpod-faulted", s, s_xpod, agg,
                          retry_budget=2 * flight)
    states = {(-1, -1, None)}
    for c in range(T):
        dead = outage is not None and outage[0] <= c < outage[1]
        nxt = set()
        for v, tip, pend in states:
            if not dead:
                if v < c - b - enf.trigger_offset:
                    if enf.xpod_refresh_capped:
                        v = max(v, min(_shipped_through(c, agg), tip))
                    else:  # uncapped mutant: sees unarrived clocks
                        v = max(v, _shipped_through(c, agg))
                if c - 1 - v > b:
                    return Counterexample(
                        producer=enf.producer, channel="xpod-faulted",
                        config=config, clock=c, cview=v, bound=b,
                        outage=outage, flight=flight)
            busy0 = pend is not None           # start-of-clock idleness
            if pend is not None and pend[1] == c:
                tip = max(tip, pend[0])        # due arrival acks
                pend = None
            if not busy0 and (c + 1) % agg == 0:
                wires = [(max(tip, c), None) if a == c else (tip, (c, a))
                         for a in range(c, c + flight + 1)]
            else:
                wires = [(tip, pend)]
            for tip2, pend2 in wires:
                nxt.add((v, tip2, pend2))      # adversary withholds
                if not dead:
                    tgt = (min(_shipped_end(c, agg), tip2)
                           if enf.delivery_capped
                           else _shipped_end(c, agg))
                    nxt.add((max(v, tgt), tip2, pend2))
        states = nxt
    return None


def model_check(bound_model: BoundModel, enf: EnforcementModel,
                Ts=(6, 9), Ps=((4, 1), (4, 2), (6, 3)),
                svals=(0, 1, 2), xvals=(0, 1, 2), aggs=(1, 2, 3),
                churn: bool = True, flights=(0, 1, 2),
                faulted_T: int = 12) -> list:
    """Exhaustively model-check the producer over the small-config grid.

    ``Ps`` pairs are (P, n_pods): n_pods == 1 exercises only the intra
    channel; n_pods > 1 adds the cross-pod channel, unwired and wired
    (the wired variant only when ``agg_clocks`` matters, i.e. always —
    agg=1 must reduce to the unwired algebra).  With ``churn`` every
    single reader-outage window [t0, t1) x each config is also explored.

    The lossy-wire channel runs per ``flights`` value at ``faulted_T``
    clocks (long enough for two stacked flight windows to bite on every
    agg; ``flight=0`` must reduce exactly to the wired algebra) without
    outage windows — the reader-outage interplay is already covered on
    the other channels, and a producer-side outage voids the conforming
    premise (churn drain gates retransmission).
    """
    ces = []
    for T, (P, n_pods), s, s_xpod, agg in itertools.product(
            Ts, Ps, svals, xvals, aggs):
        config = (T, P, s, s_xpod, agg)
        channels = ["intra"]
        if n_pods > 1:
            channels += ["xpod", "xpod-wired"]
        outages = [None]
        if churn:
            outages += [(t0, t1) for t0 in range(T)
                        for t1 in range(t0 + 1, T + 1)]
        for channel in channels:
            for outage in outages:
                ce = check_channel(bound_model, enf, channel, config,
                                   outage)
                if ce is not None:
                    ces.append(ce)
                    break          # one trace per (channel, config) row
    for (P, n_pods), s, s_xpod, agg in itertools.product(
            Ps, svals, xvals, aggs):
        if n_pods == 1:
            continue
        config = (faulted_T, P, s, s_xpod, agg)
        for flight in flights:
            ce = check_channel_faulted(bound_model, enf, config, flight)
            if ce is not None:
                ces.append(ce)
                break              # one trace per (config, flights) row
    return ces


# --------------------------------------------------------------------------
# repo entry point (called from analyze_paths)
# --------------------------------------------------------------------------

def check_repo(modules) -> list:
    """Run extraction + model check when the scan set contains the three
    Trace producers; silently skip when it does not (fixture scans)."""
    by_suffix = {}
    delays = None
    for mod in modules:
        for suffix in PRODUCER_FILES:
            if mod.rel.endswith(suffix):
                by_suffix[suffix] = mod
        if mod.rel.endswith("core/delays.py"):
            delays = mod
    if delays is None or len(by_suffix) != len(PRODUCER_FILES):
        return []
    findings = []
    try:
        bound_model = extract_bound_model_from_source(delays.source)
    except ExtractionError as e:
        return [Finding("staleness-extract", delays.rel, 1, str(e))]
    for suffix in PRODUCER_FILES:
        mod = by_suffix[suffix]
        try:
            enf = extract_enforcement_from_source(mod.source, suffix)
        except ExtractionError as e:
            findings.append(Finding("staleness-extract", mod.rel, 1,
                                    str(e)))
            continue
        for ce in model_check(bound_model, enf):
            findings.append(Finding("staleness-contract", mod.rel, 1,
                                    str(ce)))
    return findings
