"""Rule family ``pallas``: kernel-call hygiene.

- ``pallas-interpret`` — a literal ``interpret=True`` on a
  ``pallas_call`` outside ``tests/``.  Interpret mode is the
  correctness fallback; a hardcoded True in library code silently turns
  the "Pallas" path into a slow emulation everywhere (the repo threads a
  runtime ``interpret=interpret`` flag instead, selected by
  ``kernels.ops.set_backend``).
- ``pallas-blockspec`` — statically checkable ``BlockSpec`` mismatches:
  the index-map lambda must return as many coordinates as the block
  shape has dimensions, and (when the grid is a literal) take one
  parameter per grid axis.  Both mistakes lower to wrong-strided loads
  that interpret mode happily executes — the worst kind of silent wrong.
- ``pallas-ref`` — every function containing a ``pallas_call`` must have
  a registered jnp reference: either the kernels/ops.py dispatcher
  routes it with a ``ref.*`` fallback in the same dispatch function, or
  (for standalone modules/fixtures) the defining module itself
  references a ``<name>_ref`` implementation.  The reference is what
  CI's oracle tests diff the kernel against; an unreferenced kernel is
  unverifiable.
"""
from __future__ import annotations

import ast

from .base import Finding, checker, dotted, enclosing_function

_DOCS = {
    "pallas-interpret": "literal interpret=True on a pallas_call outside "
                        "tests/",
    "pallas-blockspec": "BlockSpec index-map arity mismatches block shape "
                        "or grid rank",
    "pallas-ref": "pallas_call without a registered jnp reference "
                  "(ops.py dispatch or <name>_ref)",
}


def _literal_tuple_len(node) -> int | None:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1
    return None


def _kwarg(call, name):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _blockspec_calls(node):
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = dotted(n.func)
            if d and d.split(".")[-1] == "BlockSpec":
                yield n


def _check_blockspec(spec, grid_rank, mod, findings):
    shape_node = spec.args[0] if spec.args else _kwarg(spec, "block_shape")
    imap = spec.args[1] if len(spec.args) > 1 \
        else _kwarg(spec, "index_map")
    if imap is None or not isinstance(imap, ast.Lambda):
        return
    rank = _literal_tuple_len(shape_node)
    n_params = len(imap.args.args)
    ret_len = _literal_tuple_len(imap.body)
    if ret_len is None and not isinstance(imap.body, ast.Tuple):
        # single-expression body: one coordinate
        ret_len = 1
    if rank is not None and ret_len is not None and ret_len != rank:
        findings.append(Finding(
            "pallas-blockspec", mod.rel, spec.lineno,
            f"BlockSpec index map returns {ret_len} coordinate(s) for a "
            f"rank-{rank} block shape — wrong-strided loads"))
    if grid_rank is not None and n_params != grid_rank:
        findings.append(Finding(
            "pallas-blockspec", mod.rel, spec.lineno,
            f"BlockSpec index map takes {n_params} grid index(es) but the "
            f"grid is rank {grid_rank}"))


def _module_has_ref(mod, fn_name: str) -> bool:
    """Standalone registration: the module references `<fn_name>_ref` or a
    `ref.`-qualified fallback."""
    want = f"{fn_name}_ref"
    for n in ast.walk(mod.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and n.name == want:
            return True
        if isinstance(n, ast.Name) and n.id == want:
            return True
    return False


@checker(_DOCS)
def check_pallas(mod, ctx):
    findings = []
    parts = mod.rel.split("/")
    in_tests = ("tests" in parts or "test" in parts) \
        and "analysis_fixtures" not in parts
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if not d or d.split(".")[-1] != "pallas_call":
            continue
        interp = _kwarg(node, "interpret")
        if isinstance(interp, ast.Constant) and interp.value is True \
                and not in_tests:
            findings.append(Finding(
                "pallas-interpret", mod.rel, interp.lineno,
                "literal interpret=True outside tests/ — hardcodes the "
                "emulated path; thread the backend's interpret flag "
                "(kernels.ops.set_backend) instead"))

        grid = _kwarg(node, "grid")
        grid_rank = _literal_tuple_len(grid) if grid is not None else None
        for key in ("in_specs", "out_specs"):
            specs = _kwarg(node, key)
            if specs is None:
                continue
            for spec in _blockspec_calls(specs):
                _check_blockspec(spec, grid_rank, mod, findings)

        fnode = enclosing_function(node)
        while isinstance(fnode, ast.Lambda) or (
                fnode is not None
                and enclosing_function(fnode) is not None):
            fnode = enclosing_function(fnode)
        if fnode is None or in_tests:
            continue
        fn_name = fnode.name
        dispatched = any(f == fn_name for _, f in ctx.pallas_dispatched)
        if not dispatched and not _module_has_ref(mod, fn_name):
            findings.append(Finding(
                "pallas-ref", mod.rel, node.lineno,
                f"`{fn_name}` wraps a pallas_call but has no registered "
                f"jnp reference (no kernels/ops.py dispatch with a ref.* "
                f"fallback, no `{fn_name}_ref` in the module) — the "
                f"kernel is unverifiable against an oracle"))
    return findings
