"""Rule ``host-callback``: host callbacks inside traced contexts.

The telemetry substrate (`repro.obs`) is built on the zero-host-callback
contract: traced code accumulates metrics *on device* (appended to the
scan carry, one collective per reduced leaf after the scan) and the host
drains them once the run returns.  ``io_callback`` / ``pure_callback`` /
``jax.debug.print`` / ``jax.debug.callback`` inside a jitted or
shard_mapped body break that contract three ways: they serialize the
device stream on every firing (the ≤5 % obs overhead budget is gone the
moment one lands in the scan), they perturb XLA scheduling so the
obs-on/obs-off bit-identity guarantee no longer holds, and under
``shard_map`` they fire per shard with no ordering.

The rule flags any such call whose enclosing function is a traced
context (``base.traced_functions``: jit-decorated, staged by a
transform, returned by a ``make_*`` factory, or reachable from one).
Modules under ``repro/obs/`` are exempt — that package *is* the
sanctioned bridge between device accumulators and the host.  A genuine
one-off (debugging a kernel, a deliberately-impure probe) takes the
standard reasoned suppression::

    jax.debug.print("u={}", u)  # analysis: ignore[host-callback] -- why
"""
from __future__ import annotations

import ast

from .base import Finding, checker, dotted, enclosing_function, \
    traced_functions

# bare callable names that are host callbacks wherever they come from
CALLBACK_NAMES = {"io_callback", "pure_callback"}
# dotted suffixes (matched against the full dotted callee)
CALLBACK_SUFFIXES = ("debug.print", "debug.callback",
                     "host_callback.call", "experimental.io_callback")

_DOCS = {
    "host-callback": "io_callback/pure_callback/debug.print/debug.callback "
                     "inside a traced context — route telemetry through "
                     "the repro.obs on-device accumulators",
}


def _callback_name(call) -> str | None:
    """The matched callback callee of ``call``, or None."""
    d = dotted(call.func)
    if not d:
        return None
    if d.split(".")[-1] in CALLBACK_NAMES:
        return d
    for suffix in CALLBACK_SUFFIXES:
        if d == suffix or d.endswith("." + suffix):
            return d
    return None


@checker(_DOCS)
def check_callbacks(mod, _ctx):
    rel = mod.rel.replace("\\", "/")
    if "/obs/" in rel or rel.startswith("obs/"):
        return []        # the sanctioned device->host telemetry bridge
    findings = []
    for fnode in traced_functions(mod):
        for node in ast.walk(fnode):
            if not isinstance(node, ast.Call) \
                    or enclosing_function(node) is not fnode:
                continue
            name = _callback_name(node)
            if name is None:
                continue
            where = getattr(fnode, "name", "<lambda>")
            findings.append(Finding(
                "host-callback", mod.rel, node.lineno,
                f"`{name}` inside traced `{where}` — host callback "
                f"serializes the device stream and breaks the obs "
                f"bit-identity contract; accumulate on device via "
                f"repro.obs.metrics (device_update in the carry, "
                f"drain_device after the run)"))
    return findings
