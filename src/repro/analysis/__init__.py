"""repro.analysis — the static consistency-contract checker.

Run it over the tree::

    PYTHONPATH=src python -m repro.analysis src/ [--strict]

Rule families (``--list-rules`` for the full catalog):

- ``recompile``  — traced-knob control flow / coercion / static_argnums
  hazards inside jitted code (``traced-branch``, ``traced-coerce``,
  ``traced-static-arg``);
- ``rng``        — PRNG keys consumed twice without a split/fold_in
  (``rng-reuse``);
- ``collectives``— mesh-axis hygiene and the PR 6
  masked-before-all-gather churn rule (``axis-unbound``,
  ``collective-outside-shardmap``, ``unmasked-gather``);
- ``pytree``     — registered-dataclass immutability and the
  DATA/META knob-split contract (``pytree-frozen``, ``pytree-mutation``,
  ``knob-split``);
- ``pallas``     — kernel hygiene (``pallas-interpret``,
  ``pallas-blockspec``, ``pallas-ref``);
- ``callbacks``  — host callbacks (``io_callback``/``pure_callback``/
  ``debug.print``/``debug.callback``) inside traced contexts; route
  telemetry through the ``repro.obs`` on-device accumulators instead
  (``host-callback``);
- ``staleness``  — the abstract interpreter + model checker over the
  clock-step contract (``staleness-contract``, ``staleness-extract``).

Suppress a single finding inline with a reasoned ignore::

    x = risky()  # analysis: ignore[rule-id] -- why this one is fine

``--strict`` also rejects ignores without a reason.
"""
from .base import (Finding, RULE_DOCS, analyze_paths,  # noqa: F401
                   load_suppression_file)
from .staleness_check import (BoundModel,  # noqa: F401
                              Counterexample, EnforcementModel,
                              ExtractionError,
                              extract_bound_model,
                              extract_bound_model_from_source,
                              extract_enforcement,
                              extract_enforcement_from_source,
                              model_check)

__all__ = [
    "Finding", "RULE_DOCS", "analyze_paths", "load_suppression_file",
    "BoundModel", "EnforcementModel", "Counterexample", "ExtractionError",
    "extract_bound_model", "extract_bound_model_from_source",
    "extract_enforcement", "extract_enforcement_from_source",
    "model_check",
]
