"""Rule ``rng-reuse``: a PRNG key consumed twice without a split/fold_in.

JAX keys are not stateful: sampling with the same key twice yields
*identical* (perfectly correlated) draws.  PR 2 hit exactly this — the
sweep's per-point delay samples were correlated until every consumer got
its own ``fold_in`` stream — so the discipline is now a checked contract:
between any two consumptions of a key variable there must be an
interleaving ``split``/``fold_in`` rebinding it.

The checker runs a small symbolic walk per function:

- **keys** are parameters named like keys (``rng``, ``key``, ``k_*``,
  ``*_rng``/``*_key``/``*_keys``) and variables assigned from
  ``PRNGKey``/``key``/``split``/``fold_in`` (including tuple-unpack and
  subscript of a ``split``);
- **consumption** is passing the key to any call — samplers consume, and
  so do ``split``/``fold_in`` themselves (deriving from an already-used
  key is the classic decode bug); the derivers' *assignment targets* come
  back fresh;
- packing a key into a tuple/dict/return escapes it (carry idiom) and
  stops tracking rather than guessing;
- ``if``/``else`` branches fork the state and merge (a consumption on
  either live path counts; ``return``/``raise``-terminated branches drop
  out of the merge);
- loop bodies run twice so a consumption of a loop-invariant key is
  caught as cross-iteration reuse; ``for k in split(...)`` targets are
  fresh each iteration.
"""
from __future__ import annotations

import ast
import re

from .base import Finding, checker, dotted

KEY_NAME_RE = re.compile(r"(^|_)(rng|key|keys|prngkey)$|^k_|^rng")
DERIVERS = {"split", "fold_in", "clone", "PRNGKey", "key", "wrap_key_data"}

FRESH, CONSUMED = "fresh", "consumed"

_DOCS = {
    "rng-reuse": "PRNG key consumed twice without an interleaving "
                 "split/fold_in (correlated streams)",
}


def _is_key_name(name: str) -> bool:
    return bool(KEY_NAME_RE.search(name))


RANDOM_MODULES = {"random", "jrandom", "jr"}


def _call_kind(call) -> str | None:
    d = dotted(call.func)
    if not d:
        return None
    parts = d.split(".")
    last = parts[-1]
    if last in DERIVERS:
        # require a jax.random-looking qualifier (or a bare import) so
        # `s.split(",")` / `d.split(".")` string methods don't register
        if len(parts) == 1 or parts[-2] in RANDOM_MODULES \
                or last == "PRNGKey":
            # fold_in mixes data into the stream: `fold_in(rng, i)` per
            # step is the idiomatic multi-stream derivation and does not
            # spend the base key
            return "fold" if last == "fold_in" else "derive"
    return "call"


class _FnState:
    def __init__(self):
        self.keys: dict = {}      # name -> (state, line of last consumption)

    def copy(self):
        s = _FnState()
        s.keys = dict(self.keys)
        return s

    def merge(self, other):
        for name, (st, ln) in other.keys.items():
            cur = self.keys.get(name)
            if cur is None or (st == CONSUMED and cur[0] == FRESH):
                self.keys[name] = (st, ln)


def _terminates(stmts) -> bool:
    return any(isinstance(s, (ast.Return, ast.Raise, ast.Continue,
                              ast.Break)) for s in stmts)


class _Walker:
    def __init__(self, mod, fnode):
        self.mod = mod
        self.fnode = fnode
        self.findings: list = []
        self._seen_lines: set = set()

    def report(self, name, node, prev_line):
        if node.lineno in self._seen_lines:
            return
        self._seen_lines.add(node.lineno)
        self.findings.append(Finding(
            "rng-reuse", self.mod.rel, node.lineno,
            f"PRNG key `{name}` consumed again without an interleaving "
            f"split/fold_in (previous consumption at line {prev_line}) — "
            f"identical streams"))

    # -- expression side: consumption events ---------------------------

    def consume(self, name, node, state):
        cur = state.keys.get(name)
        if cur is None:
            return
        st, ln = cur
        if st == CONSUMED:
            self.report(name, node, ln)
        state.keys[name] = (CONSUMED, node.lineno)

    def eval_expr(self, node, state):
        """Walk an expression, firing consumption on key-args of calls and
        escaping keys packed into containers."""
        for n in ast.walk(node):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            if isinstance(n, ast.Call):
                kind = _call_kind(n)
                for arg in list(n.args) + [kw.value for kw in n.keywords]:
                    if isinstance(arg, ast.Name) \
                            and arg.id in state.keys \
                            and kind in ("call", "derive"):
                        self.consume(arg.id, arg, state)
            elif isinstance(n, (ast.Tuple, ast.List, ast.Dict)):
                parent = getattr(n, "parent", None)
                if isinstance(parent, (ast.Return, ast.Assign, ast.Yield)):
                    for e in ast.walk(n):
                        if isinstance(e, ast.Name) and e.id in state.keys:
                            state.keys.pop(e.id, None)  # escaped via carry

    # -- statement side ------------------------------------------------

    def _rhs_fresh(self, value, state) -> bool:
        if isinstance(value, ast.Call):
            return _call_kind(value) in ("derive", "fold")
        if isinstance(value, ast.Subscript):
            base = value.value
            # indexing a split result / an array-of-keys yields a fresh key
            return ((isinstance(base, ast.Call)
                     and _call_kind(base) in ("derive", "fold"))
                    or (isinstance(base, ast.Name)
                        and base.id in state.keys))
        if isinstance(value, ast.IfExp):
            # `rng = rng if rng is not None else PRNGKey(0)` — fresh when
            # both arms are fresh keys (a fresh alias counts)
            def arm_fresh(arm):
                if isinstance(arm, ast.Name):
                    st = state.keys.get(arm.id)
                    return st is not None and st[0] == FRESH
                return self._rhs_fresh(arm, state)
            return arm_fresh(value.body) and arm_fresh(value.orelse)
        return False

    def assign_targets(self, targets, value, state):
        fresh = self._rhs_fresh(value, state)
        alias = (value.id if isinstance(value, ast.Name)
                 and value.id in state.keys else None)
        names = []
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    names.append(n.id)
        for name in names:
            if fresh:
                state.keys[name] = (FRESH, value.lineno)
            elif alias is not None and len(names) == 1:
                state.keys[name] = state.keys[alias]
            elif name in state.keys:
                # rebound from an untracked expression: stop tracking
                state.keys.pop(name)

    def run_stmts(self, stmts, state):
        for st in stmts:
            self.run_stmt(st, state)

    def run_stmt(self, st, state):
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return
        if isinstance(st, ast.Assign):
            self.eval_expr(st.value, state)
            self.assign_targets(st.targets, st.value, state)
        elif isinstance(st, ast.AugAssign):
            self.eval_expr(st.value, state)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self.eval_expr(st.value, state)
                self.assign_targets([st.target], st.value, state)
        elif isinstance(st, (ast.Expr, ast.Return)):
            if getattr(st, "value", None) is not None:
                self.eval_expr(st.value, state)
        elif isinstance(st, ast.If):
            self.eval_expr(st.test, state)
            s_then, s_else = state.copy(), state.copy()
            self.run_stmts(st.body, s_then)
            self.run_stmts(st.orelse, s_else)
            live = []
            if not _terminates(st.body):
                live.append(s_then)
            if not _terminates(st.orelse):
                live.append(s_else)
            if not live:            # both branches terminate
                live = [s_then]
            state.keys = dict(live[0].keys)
            for s in live[1:]:
                state.merge(s)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self.eval_expr(st.iter, state)
            iter_fresh = (
                (isinstance(st.iter, ast.Call)
                 and _call_kind(st.iter) == "derive")
                or (isinstance(st.iter, ast.Name)
                    and st.iter.id in state.keys))
            body_state = state.copy()
            for _ in range(2):      # second pass catches loop-carried reuse
                if iter_fresh:
                    self.assign_targets([st.target], st.iter, body_state)
                    for n in ast.walk(st.target):
                        if isinstance(n, ast.Name):
                            body_state.keys[n.id] = (FRESH, st.lineno)
                self.run_stmts(st.body, body_state)
            state.merge(body_state)
            self.run_stmts(st.orelse, state)
        elif isinstance(st, ast.While):
            self.eval_expr(st.test, state)
            body_state = state.copy()
            for _ in range(2):
                self.run_stmts(st.body, body_state)
            state.merge(body_state)
            self.run_stmts(st.orelse, state)
        elif isinstance(st, ast.With):
            for item in st.items:
                self.eval_expr(item.context_expr, state)
            self.run_stmts(st.body, state)
        elif isinstance(st, ast.Try):
            self.run_stmts(st.body, state)
            for h in st.handlers:
                s_h = state.copy()
                self.run_stmts(h.body, s_h)
                state.merge(s_h)
            self.run_stmts(st.orelse, state)
            self.run_stmts(st.finalbody, state)
        elif isinstance(st, (ast.Assert, ast.Raise, ast.Delete)):
            pass
        # other statements carry no key flow


@checker(_DOCS)
def check_rng(mod, _ctx):
    findings = []
    for fnode in ast.walk(mod.tree):
        if not isinstance(fnode, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        state = _FnState()
        args = fnode.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if _is_key_name(a.arg):
                state.keys[a.arg] = (FRESH, fnode.lineno)
        w = _Walker(mod, fnode)
        # seed assignments from derivers even for non-key-named targets
        w.run_stmts([s for s in fnode.body
                     if not isinstance(s, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))], state)
        findings.extend(w.findings)
    return findings
