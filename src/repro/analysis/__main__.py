"""CLI: ``python -m repro.analysis [paths...] [--strict]``.

Exit status 0 iff no findings survive suppression — the contract the CI
``analysis`` lane gates on.
"""
from __future__ import annotations

import argparse
import sys

from .base import RULE_DOCS, analyze_paths, load_suppression_file


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-driven consistency-contract checker")
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to scan (default: src/)")
    ap.add_argument("--strict", action="store_true",
                    help="also reject `# analysis: ignore[...]` comments "
                         "written without a reason")
    ap.add_argument("--suppressions", default=None,
                    help="repo-level suppression file (lines of "
                         "`path-glob:rule-id`)")
    ap.add_argument("--no-model-check", action="store_true",
                    help="skip the staleness model checker")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        # importing the driver registers every rule module
        analyze_paths([], model_check=False)
        width = max(len(r) for r in RULE_DOCS)
        for rule_id in sorted(RULE_DOCS):
            print(f"{rule_id:<{width}}  {RULE_DOCS[rule_id]}")
        return 0

    supp = (load_suppression_file(args.suppressions)
            if args.suppressions else None)
    findings = analyze_paths(args.paths or ["src/"], strict=args.strict,
                             suppressions=supp,
                             model_check=not args.no_model_check)
    for f in findings:
        print(f)
    n = len(findings)
    mode = " (strict)" if args.strict else ""
    print(f"repro.analysis{mode}: "
          f"{n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
