"""Rule family ``recompile``: traced-knob hazards inside jitted code.

The PR 1 pytree split exists so that every sweepable consistency knob
(``consistency.DATA_FIELDS``) is a *traced* leaf: one compile covers the
whole (config x seed) grid.  Python-level control flow, ``int()``-style
coercion, or ``hash()`` on such a knob inside a traced context either
fails at trace time (ConcretizationTypeError) or — worse — silently bakes
the knob into the compiled program and recompiles per config point,
destroying the one-compile property the sweep engine is built on.

Rules:

- ``traced-branch``   — ``if`` / ``while`` / ``assert`` on an expression
  tainted by a traced knob, inside a traced context;
- ``traced-coerce``   — ``int()`` / ``bool()`` / ``float()`` / ``hash()``
  / ``range()`` of a tainted expression, inside a traced context;
- ``traced-static-arg`` — a ``jit(..., static_argnames=...)`` /
  ``static_argnums`` marking a config or data knob static (per-value
  recompilation), detected on the jit call and on call sites of
  same-module jit-wrapped aliases.

Taint seeds are ``<cfg>.<knob>`` attribute reads where ``<cfg>`` is a
parameter named ``cfg``/``config`` or annotated ``ConsistencyConfig``,
and ``<knob>`` is a DATA field; taint propagates flow-insensitively
through same-function assignments.  Static META fields
(``cfg.model`` etc.) never taint — branching on them is the supported
per-family specialization.
"""
from __future__ import annotations

import ast

from .base import (Finding, checker, dotted, enclosing_function,
                   statements_of, traced_functions)

CONFIG_NAMES = {"cfg", "config", "cfg_run"}
COERCERS = {"int", "bool", "float", "hash", "range"}

_DOCS = {
    "traced-branch": "Python if/while/assert on a traced consistency knob "
                     "inside jitted code",
    "traced-coerce": "int()/bool()/float()/hash()/range() of a traced "
                     "knob inside jitted code",
    "traced-static-arg": "traced config/knob passed through "
                         "static_argnums/static_argnames",
}


def _is_config_annotation(node) -> bool:
    d = dotted(node) if node is not None else None
    return bool(d) and d.split(".")[-1] == "ConsistencyConfig"


def _config_params(fnode) -> set:
    """Parameter names of ``fnode`` that carry a ConsistencyConfig."""
    out = set()
    args = fnode.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        if a.arg in CONFIG_NAMES or _is_config_annotation(a.annotation):
            out.add(a.arg)
    return out


def _collect_taint(fnode, cfg_names: set, knob_data: set) -> set:
    """Flow-insensitive taint fixpoint over same-function assignments."""
    tainted: set = set()

    def expr_tainted(node) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Attribute) and n.attr in knob_data \
                    and isinstance(n.value, ast.Name) \
                    and n.value.id in cfg_names:
                return True
            if isinstance(n, ast.Name) and n.id in tainted:
                return True
        return False

    stmts = statements_of(fnode)
    changed = True
    while changed:
        changed = False
        for st in stmts:
            targets = []
            if isinstance(st, ast.Assign):
                targets, value = st.targets, st.value
            elif isinstance(st, ast.AugAssign):
                targets, value = [st.target], st.value
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                targets, value = [st.target], st.value
            else:
                continue
            if not expr_tainted(value):
                continue
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name) and n.id not in tainted:
                        tainted.add(n.id)
                        changed = True
    return tainted, expr_tainted


def _owned_by(node, fnode) -> bool:
    return enclosing_function(node) is fnode


@checker(_DOCS)
def check_recompile(mod, ctx):
    findings = []
    traced = traced_functions(mod)
    knob_data = ctx.knob_data

    for fnode in traced:
        if isinstance(fnode, ast.Lambda):
            continue
        cfg_names = _config_params(fnode)
        if not cfg_names:
            continue
        _, expr_tainted = _collect_taint(fnode, cfg_names, knob_data)

        for node in ast.walk(fnode):
            if not _owned_by(node, fnode):
                continue
            if isinstance(node, (ast.If, ast.While)) \
                    and expr_tainted(node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(Finding(
                    "traced-branch", mod.rel, node.lineno,
                    f"Python `{kind}` on a traced consistency knob inside "
                    f"jitted `{fnode.name}` — recompile/concretization "
                    f"hazard; use jnp.where/lax.cond"))
            elif isinstance(node, ast.Assert) \
                    and expr_tainted(node.test):
                findings.append(Finding(
                    "traced-branch", mod.rel, node.lineno,
                    f"assert on a traced consistency knob inside jitted "
                    f"`{fnode.name}` — concretizes the knob at trace time"))
            elif isinstance(node, ast.Call):
                d = dotted(node.func)
                if d in COERCERS and any(expr_tainted(a)
                                         for a in node.args):
                    findings.append(Finding(
                        "traced-coerce", mod.rel, node.lineno,
                        f"`{d}()` of a traced consistency knob inside "
                        f"jitted `{fnode.name}` — bakes the knob into the "
                        f"compiled program (one compile per value)"))

    findings.extend(_check_static_args(mod, ctx))
    return findings


def _jit_static_info(call):
    """(static_names, static_nums) literals of a jit call, else None."""
    d = dotted(call.func)
    if not d or d.split(".")[-1] != "jit":
        return None
    names, nums = set(), set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                names.add(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                for e in kw.value.elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        names.add(e.value)
        elif kw.arg == "static_argnums":
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, int):
                nums.add(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                for e in kw.value.elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, int):
                        nums.add(e.value)
    return names, nums


def _check_static_args(mod, ctx):
    findings = []
    # jit-wrapped aliases in this module: name -> static positions
    wrapped: dict = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        info = _jit_static_info(node)
        if info is None:
            continue
        names, nums = info
        bad = sorted(n for n in names
                     if n in ctx.knob_data or n in CONFIG_NAMES)
        if bad:
            findings.append(Finding(
                "traced-static-arg", mod.rel, node.lineno,
                f"static_argnames marks traced knob(s) {bad} static — "
                f"recompiles per config value; keep data knobs traced "
                f"(consistency.DATA_FIELDS)"))
        parent = getattr(node, "parent", None)
        if nums and isinstance(parent, ast.Assign) \
                and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            wrapped[parent.targets[0].id] = nums
    if wrapped:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in wrapped:
                for pos in wrapped[node.func.id]:
                    if pos >= len(node.args):
                        continue
                    arg = node.args[pos]
                    hit = None
                    for n in ast.walk(arg):
                        if isinstance(n, ast.Attribute) \
                                and n.attr in ctx.knob_data:
                            hit = n.attr
                    if hit is not None:
                        findings.append(Finding(
                            "traced-static-arg", mod.rel, node.lineno,
                            f"call passes traced knob `{hit}` in static "
                            f"position {pos} of jit-wrapped "
                            f"`{node.func.id}` — one compile per value"))
    return findings
