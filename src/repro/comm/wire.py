"""Lossy-wire fault injection + ack/retransmit ARQ for the comm substrate.

PR 5 made the cross-pod wire *cost* real (bits -> seconds); this module
makes its *delivery* real: shipments can be dropped, duplicated, or
delayed per a seeded :class:`WireFaults` schedule (the wire analogue of
`core.delays.ChurnSchedule`), and the substrate answers with a
stop-and-wait ARQ — sequence numbers, idempotent dedup-on-fold, and
ack-driven retransmission with exponential backoff.  Both engines
(``core.ps.simulate`` and the ``psrun``/``pods`` runtimes) call the same
:func:`wire_step` on the same ``[P, ·]`` state leaves, so a seeded faulted
run is bit-identical across all three Trace producers — and a *neutral*
schedule (:func:`no_faults`) is bit-identical to running with no schedule
at all.

Protocol (per producer, evaluated inside the per-clock scan step):

- **ship**: at an aggregation boundary an *idle* producer packs its
  delta (`substrate.pack` semantics unchanged) into a pending shipment
  ``pend`` tagged with the next sequence number, and transmits.  A *busy*
  producer (previous shipment unacked) skips the boundary — stop-and-wait
  — and its accumulator simply keeps accumulating; the skipped content
  rides the next shipment.
- **transmit**: attempt at clock ``t`` is dropped iff ``drop[t, p]``;
  otherwise it lands in the single in-flight lane with arrival clock
  ``t + delay[t, p]`` (``delay == 0`` arrives the same clock — the
  lossless wire's timing), superseding any older in-flight copy (a lossy
  wire may reorder; the newest copy wins).  ``dup[t, p]`` tags the copy
  so its arrival schedules a duplicate *echo* one clock later.
- **fold (ack)**: an arrival folds into the wire ring iff its sequence
  number matches the pending shipment and exceeds the receiver's
  ``recv_seq`` — the idempotence guard.  Folding acks the shipment
  (clears ``pend``) and advances ``wire_tip``, the highest producer
  clock whose content has actually arrived; duplicate echoes fail the
  guard and only tick ``n_duprej``.
- **retransmit**: an unacked shipment retransmits when ``c >= retry_at``
  with exponential backoff (``rto0 * 2^(attempts-1)``), at most
  ``max_retries`` retries; every attempt charges the shipment's
  bits-on-wire into ``Trace.ship_floats`` again, so retries cost real
  seconds through `core.timemodel.TimeModel` / ``bandwidth_xpod``.
- **give-up (self-healing)**: after the last retry's backoff expires with
  nothing in flight — which can only mean *every* attempt was dropped —
  the pending mass folds back into the error-feedback residual ``res``
  and re-ships with the next delta.  ``res`` and ``pend`` come from the
  same pack with disjoint coordinate supports, so the fold is *exact* in
  f32: ``acc + res + pend + arrived == accumulated`` holds bitwise under
  arbitrary fault masks (the mass-conservation invariant,
  ``tests/test_wire.py``).  ``heal=False`` discards the mass instead —
  the "no self-healing" contrast arm of ``benchmarks/faults_bench.py``.

Staleness contract: cross-pod visibility is capped by ``wire_tip`` (a
reader may only see what has arrived), and under *conforming* fault
traces — every shipment acked within ``flight_budget = rto0 *
(2^max_retries - 1) + max_delay`` clocks — the two-tier bound widens by
:func:`retry_budget` ``= 2 * flight_budget`` (two flight windows stack:
one holding the tip back, one holding the *next* shipment's content
back, because stop-and-wait skips boundaries while busy).  The widened
bound is exactly tight and model-checked by
``analysis/staleness_check.py`` (which refutes an off-by-one widening).
Non-conforming traces (any shipment given up) may exceed any finite
bound — there the guarantee is mass conservation, not staleness.

Ring-lifetime constraint: a pending shipment of boundary ``b`` resolves
(ack or give-up) within :func:`max_lifetime` clocks, which must be
``<= W - 1`` so arrivals always land before their wire-ring slot
recycles; and the ring window must keep content visible until every
reader's ``cview`` passes it, so faulted runs need ``W >=
s + s_xpod + (agg_clocks - 1) + retry_budget + 2``
(:func:`required_window`).  Both are checked at trace time
(:func:`validate_faults`) — the static fields make them Python-level.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# retry_at sentinel for "no retry scheduled" (idle / just acked): far
# enough that `c >= retry_at` never fires within any run.
_NEVER = np.int32(2**30)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class WireFaults:
    """Per-clock, per-producer wire faults, indexed by absolute clock.

    ``drop[t, p]`` drops any transmission producer ``p`` makes at clock
    ``t``; ``dup[t, p]`` duplicates it (the copy echoes one clock after
    arrival and is deduped on fold); ``delay[t, p]`` clocks of delivery
    delay (0 = the lossless wire's same-clock arrival).  Clocks past the
    schedule's horizon clamp to the last row (like `ChurnSchedule`).
    The mask arrays are traced jit arguments — same-shape schedules share
    one compiled program; the ARQ knobs (``rto0``, ``max_retries``,
    ``max_delay``, ``heal``) are static: they shape the staleness
    contract and the give-up condition.
    """

    drop: jax.Array                 # [T, P] bool: transmission dropped
    dup: jax.Array                  # [T, P] bool: transmission duplicated
    delay: jax.Array                # [T, P] i32 delivery delay in clocks
    rto0: int = field(default=1, metadata=dict(static=True))
    max_retries: int = field(default=0, metadata=dict(static=True))
    max_delay: int = field(default=0, metadata=dict(static=True))
    heal: bool = field(default=True, metadata=dict(static=True))

    @property
    def n_clocks(self) -> int:
        return self.drop.shape[0]

    @property
    def n_workers(self) -> int:
        return self.drop.shape[1]

    @property
    def flight_budget(self) -> int:
        """Max clocks a *conforming* shipment stays unacked: last retry at
        ``rto0 * (2^max_retries - 1)`` past the ship clock, plus its
        delivery delay."""
        return self.rto0 * (2 ** self.max_retries - 1) + self.max_delay

    @property
    def retry_budget(self) -> int:
        """Clocks the cross-pod staleness bound widens by (see module
        doc): two conforming flight windows stack under stop-and-wait.
        0 for a neutral schedule — the widened bound collapses to the
        lossless one, which is what keeps :func:`no_faults` bit-identical
        to no schedule at all."""
        return 2 * self.flight_budget

    @property
    def max_lifetime(self) -> int:
        """Max clocks from ship to resolution (ack *or* give-up): give-up
        waits out the full backoff ladder ``rto0 * (2^(max_retries+1) -
        1)``; a conforming ack lands within ``flight_budget``."""
        return max(self.rto0 * (2 ** (self.max_retries + 1) - 1),
                   self.flight_budget)


def no_faults(n_clocks: int, P: int) -> WireFaults:
    """The neutral schedule: nothing drops, duplicates, or delays, and a
    zero retry budget.  Running with it is bit-identical to running with
    no ``faults`` at all (pinned by ``tests/test_wire.py``)."""
    z = jnp.zeros((n_clocks, P), bool)
    return WireFaults(drop=z, dup=z, delay=jnp.zeros((n_clocks, P),
                                                     jnp.int32))


def make_faults(n_clocks: int, P: int, *, seed: int = 0,
                drop_rate: float = 0.0, dup_rate: float = 0.0,
                delay_rate: float = 0.0, max_delay: int = 0,
                bursts=(), rto0: int = 1, max_retries: int = 3,
                heal: bool = True) -> WireFaults:
    """Build a seeded `WireFaults` from scenario primitives.

    - ``drop_rate`` / ``dup_rate``: i.i.d. per-(clock, producer) fault
      probabilities;
    - ``delay_rate`` + ``max_delay``: with probability ``delay_rate`` a
      transmission is delayed uniformly in ``[1, max_delay]`` clocks
      (``max_delay`` also bounds the conforming-arrival contract);
    - ``bursts``: ``(t0, t1, rate)`` burst-loss regimes — the drop
      probability is overridden by ``rate`` on clocks ``[t0, t1)``
      (correlated loss, the regime the residual + retransmit must ride
      out);
    - ``rto0`` / ``max_retries``: the backoff ladder (first retry after
      ``rto0`` clocks, doubling);
    - ``heal=False`` disables give-up-to-residual (dropped-beyond-retry
      mass is *discarded*) — the contrast arm proving the residual is
      what makes unretransmitted drops self-healing.
    """
    rng = np.random.default_rng(seed)
    p_drop = np.full((n_clocks, P), float(drop_rate))
    for t0, t1, rate in bursts:
        p_drop[t0:t1, :] = float(rate)
    drop = rng.random((n_clocks, P)) < p_drop
    dup = rng.random((n_clocks, P)) < float(dup_rate)
    delay = np.zeros((n_clocks, P), np.int32)
    if max_delay > 0 and delay_rate > 0.0:
        delayed = rng.random((n_clocks, P)) < float(delay_rate)
        delay = np.where(delayed,
                         rng.integers(1, max_delay + 1, (n_clocks, P)),
                         0).astype(np.int32)
    return WireFaults(drop=jnp.asarray(drop), dup=jnp.asarray(dup),
                      delay=jnp.asarray(delay), rto0=int(rto0),
                      max_retries=int(max_retries),
                      max_delay=int(max_delay), heal=bool(heal))


def faults_key(faults: WireFaults | None):
    """The fault *structure* a compiled program is specialized on (the
    `_churn_key` analogue): presence plus the static ARQ knobs.  Mask
    values stay jit-traced."""
    if faults is None:
        return None
    return (faults.rto0, faults.max_retries, faults.max_delay, faults.heal)


def required_window(cfg, faults: WireFaults) -> int:
    """Minimum ring window for a faulted run: the lossless requirement
    ``s + s_xpod + (agg_clocks - 1) + 2`` plus the retry budget (content
    must stay visible in the ring until every conforming reader's bound
    catches up), and at least ``max_lifetime + 1`` (arrivals must land
    before their slot recycles)."""
    base = (int(cfg.staleness) + int(cfg.s_xpod) + (int(cfg.agg_clocks) - 1)
            + faults.retry_budget + 2)
    return max(base, faults.max_lifetime + 1)


def validate_faults(faults: WireFaults, cfg, P: int, W: int):
    """Raise unless ``faults`` is well-formed for this (cfg, P, W).

    Faults ride the comm substrate's shipment machinery, so they require
    ``cfg.comm_active``; the static checks (window, lifetime) run at
    trace time because the ARQ knobs are static fields.
    """
    if not cfg.comm_active:
        raise ValueError(
            "WireFaults model the compressed cross-pod wire; they require "
            "cfg.comm_active (ssp/essp/async with n_pods >= 2 — see "
            "consistency.compressed)")
    if faults.drop.shape != faults.dup.shape or \
            faults.drop.shape != faults.delay.shape:
        raise ValueError(
            f"fault masks disagree: drop {faults.drop.shape}, dup "
            f"{faults.dup.shape}, delay {faults.delay.shape}")
    if faults.n_workers != P:
        raise ValueError(f"faults cover {faults.n_workers} producers, "
                         f"app has {P}")
    if faults.rto0 < 1 or faults.max_retries < 0 or faults.max_delay < 0:
        raise ValueError(
            f"need rto0 >= 1, max_retries >= 0, max_delay >= 0; got "
            f"({faults.rto0}, {faults.max_retries}, {faults.max_delay})")
    if faults.max_lifetime > W - 1:
        raise ValueError(
            f"a pending shipment can outlive its ring slot: max_lifetime="
            f"{faults.max_lifetime} > window - 1 = {W - 1}; set "
            f"cfg.window >= wire.required_window(cfg, faults)")
    try:
        req = required_window(cfg, faults)
    except TypeError:
        return  # traced staleness knobs: sweeps validate per-config
    if W < req:
        raise ValueError(
            f"ring window {W} too small for the faulted staleness "
            f"contract (retry_budget={faults.retry_budget}): need "
            f"W >= {req}; set cfg.window = wire.required_window(cfg, "
            f"faults)")


# ----------------------------------------------------------- wire state


def init_wire_state(P: int, dcols: int) -> dict:
    """Zero ARQ state leaves, merged into the substrate's comm dict.

    ``dcols`` is the payload width this engine sees (``d`` in the
    simulator, the local model shard ``dl`` in the runtimes — the ARQ is
    elementwise on the payload axis, so the leaves shard like ``acc``).
    Layout (all leading-``P``, one lane per producer — stop-and-wait):

    - ``pend [P, dcols]`` pending (unacked) shipment payload;
      ``pend_clock``/``pend_seq``/``pend_floats`` its boundary clock,
      sequence number, and bits-weighted wire floats (re-charged per
      retransmission); ``attempts`` transmissions so far; ``retry_at``
      next backoff expiry;
    - ``arr_at``/``arr_seq``/``arr_dup`` the single in-flight lane:
      scheduled arrival clock (-1 = empty), copy's sequence number, and
      whether arrival schedules a duplicate echo;
    - ``echo_at``/``echo_seq`` the pending duplicate echo (arrives one
      clock after the original, rejected by the seq guard);
    - ``recv_seq`` highest folded sequence number (the dedup guard);
      ``wire_tip`` highest arrived producer clock (caps cross-pod
      visibility); ``seq_next`` next sequence number to assign;
    - counters ``n_retx``/``n_giveup``/``n_duprej``.
    """
    i32, f32 = jnp.int32, jnp.float32
    zi = jnp.zeros((P,), i32)
    return dict(
        pend=jnp.zeros((P, dcols), f32),
        pend_clock=jnp.full((P,), -1, i32),
        pend_seq=zi, pend_floats=jnp.zeros((P,), f32),
        attempts=zi, retry_at=jnp.full((P,), _NEVER, i32),
        arr_at=jnp.full((P,), -1, i32), arr_seq=zi,
        arr_dup=jnp.zeros((P,), bool),
        echo_at=jnp.full((P,), -1, i32), echo_seq=zi,
        recv_seq=zi, wire_tip=jnp.full((P,), -1, i32),
        seq_next=jnp.full((P,), 1, i32),
        n_retx=zi, n_giveup=zi, n_duprej=zi)


WIRE_KEYS = tuple(init_wire_state(1, 1).keys())


def idle(cst: dict) -> jax.Array:
    """[P] bool: producers with no unacked shipment (free to ship)."""
    return cst["pend_clock"] < 0


def drop_pending(cst: dict, keep) -> dict:
    """Drop-in-flight churn policy for the wire: a dying producer's
    pending shipment, in-flight copy, and echo vanish with it (its
    ``res``/``acc`` rows are zeroed by the caller).  Receiver-side state
    (``recv_seq``/``wire_tip``/``seq_next``) survives — already-arrived
    content stays arrived."""
    kb = keep[:, None]
    return dict(cst,
                pend=jnp.where(kb, cst["pend"], 0.0),
                pend_clock=jnp.where(keep, cst["pend_clock"], -1),
                pend_seq=jnp.where(keep, cst["pend_seq"], 0),
                pend_floats=jnp.where(keep, cst["pend_floats"], 0.0),
                attempts=jnp.where(keep, cst["attempts"], 0),
                retry_at=jnp.where(keep, cst["retry_at"], _NEVER),
                arr_at=jnp.where(keep, cst["arr_at"], -1),
                arr_seq=jnp.where(keep, cst["arr_seq"], 0),
                arr_dup=jnp.where(keep, cst["arr_dup"], False),
                echo_at=jnp.where(keep, cst["echo_at"], -1),
                echo_seq=jnp.where(keep, cst["echo_seq"], 0))


# ------------------------------------------------------------- wire step


def _arrive(cst: dict, c) -> dict:
    """Process due arrivals (in-flight copies with ``arr_at <= c`` and
    duplicate echoes) through the fold guard; ack what folds."""
    pend, pclk = cst["pend"], cst["pend_clock"]
    pseq, recv = cst["pend_seq"], cst["recv_seq"]
    lane = cst["arr_at"]
    due = (lane >= 0) & (lane <= c)
    # fold guard: the copy's seq must match the pending shipment (payload
    # binding) and exceed recv_seq (idempotence) — a stale or duplicate
    # copy is rejected here, never re-folded
    fresh = due & (cst["arr_seq"] == pseq) & (pseq > recv) & (pclk >= 0)
    W = cst["xring"].shape[0]
    P = pend.shape[0]
    rows = jnp.arange(P)
    slots = jnp.where(fresh, jnp.mod(pclk, W), 0)
    vals = jnp.where(fresh[:, None], pend, cst["xring"][slots, rows])
    xring = cst["xring"].at[slots, rows].set(vals)
    # duplicate copies echo one clock after the original arrival; the
    # echo re-runs the guard above (seq <= recv_seq by then: rejected)
    dup_new = fresh & cst["arr_dup"]
    echo_due = (cst["echo_at"] >= 0) & (cst["echo_at"] <= c)
    echo_rej = echo_due & ~((cst["echo_seq"] == pseq)
                            & (cst["echo_seq"] > recv))
    echo_at = jnp.where(echo_due, -1, cst["echo_at"])
    echo_at = jnp.where(dup_new, c + 1, echo_at)
    echo_seq = jnp.where(dup_new, pseq, cst["echo_seq"])
    return dict(
        cst, xring=xring,
        recv_seq=jnp.where(fresh, pseq, recv),
        wire_tip=jnp.where(fresh, pclk, cst["wire_tip"]),
        pend=jnp.where(fresh[:, None], 0.0, pend),
        pend_clock=jnp.where(fresh, -1, pclk),
        pend_seq=jnp.where(fresh, 0, pseq),
        pend_floats=jnp.where(fresh, 0.0, cst["pend_floats"]),
        attempts=jnp.where(fresh, 0, cst["attempts"]),
        retry_at=jnp.where(fresh, _NEVER, cst["retry_at"]),
        arr_at=jnp.where(due, -1, lane),
        echo_at=echo_at, echo_seq=echo_seq,
        n_duprej=cst["n_duprej"] + echo_rej.astype(jnp.int32))


def wire_step(cst: dict, wire_u, floats, ship, c, faults: WireFaults,
              live=None):
    """One clock of the faulted wire (both engines' section 4b tail).

    ``cst`` is the comm dict with the :func:`init_wire_state` leaves and
    this clock's acc/res/xring already updated by the caller under the
    ``ship`` mask (``ship`` must already include boundary x liveness x
    :func:`idle` — stop-and-wait gates shipping on start-of-clock
    idleness, so a producer acked *this* clock ships next boundary).
    ``wire_u [P, dcols]`` / ``floats [P]`` are this clock's packed
    shipment and its bits-on-wire; ``live`` (``[P]`` bool or None) gates
    transmissions under churn — a dead producer neither retransmits nor
    gives up (drain policy: its pending mass waits for rejoin; an
    already in-flight copy still arrives).

    Returns ``(cst', ship_floats)`` where ``ship_floats [P]`` charges
    every transmission (first attempt and retries) made this clock.
    """
    P = wire_u.shape[0]
    i32 = jnp.int32
    T = faults.drop.shape[0]
    t = jnp.clip(c, 0, T - 1)
    drop_r, dup_r, delay_r = faults.drop[t], faults.dup[t], faults.delay[t]
    tx_ok = jnp.ones((P,), bool) if live is None else live

    # (a) arrivals due from earlier clocks (delayed copies, echoes)
    st = _arrive(cst, c)

    # (b) give-up: the backoff ladder ran out with nothing in flight —
    # every attempt was dropped (any surviving copy would have acked or
    # still sit in the lane).  Self-heal: the mass folds back into the
    # error-feedback residual (disjoint support from res — exact in f32)
    # and rides the next shipment; heal=False discards it instead.
    busy = st["pend_clock"] >= 0
    gup = (busy & tx_ok & (c >= st["retry_at"])
           & (st["attempts"] > faults.max_retries) & (st["arr_at"] < 0))
    res = st["res"]
    if faults.heal:
        res = res + jnp.where(gup[:, None], st["pend"], 0.0)
    pend = jnp.where(gup[:, None], 0.0, st["pend"])
    pclk = jnp.where(gup, -1, st["pend_clock"])
    pseq = jnp.where(gup, 0, st["pend_seq"])
    pfl = jnp.where(gup, 0.0, st["pend_floats"])
    att = jnp.where(gup, 0, st["attempts"])
    rat = jnp.where(gup, _NEVER, st["retry_at"])

    # (c) retransmission due (backoff expired, retries left)
    rtx = ((pclk >= 0) & tx_ok & (c >= rat)
           & (att <= faults.max_retries))

    # (d) new shipments (ship mask decided by the caller off
    # start-of-clock idleness)
    new = ship
    pend = jnp.where(new[:, None], wire_u, pend)
    pclk = jnp.where(new, c, pclk)
    pseq = jnp.where(new, st["seq_next"], pseq)
    seq_next = jnp.where(new, st["seq_next"] + 1, st["seq_next"])
    pfl = jnp.where(new, floats, pfl)
    att = jnp.where(new, 0, att)

    # (e) transmit (first attempts + retries) through this clock's fault
    # row: dropped copies vanish; surviving copies take the in-flight
    # lane (superseding older copies — newest wins) with arrival
    # c + delay; dup-tagged copies will echo.
    tx = new | rtx
    att = att + tx.astype(i32)
    backoff = faults.rto0 * jnp.left_shift(
        jnp.ones((), i32), jnp.maximum(att - 1, 0))
    rat = jnp.where(tx, c + backoff, rat)
    sent = tx & ~drop_r
    arr_at = jnp.where(sent, c + delay_r, st["arr_at"])
    arr_seq = jnp.where(sent, pseq, st["arr_seq"])
    arr_dup = jnp.where(sent, dup_r, st["arr_dup"])
    ship_floats = jnp.where(tx, pfl, jnp.zeros((P,), jnp.float32))

    st = dict(st, res=res, pend=pend, pend_clock=pclk, pend_seq=pseq,
              pend_floats=pfl, attempts=att, retry_at=rat,
              seq_next=seq_next, arr_at=arr_at, arr_seq=arr_seq,
              arr_dup=arr_dup,
              n_retx=st["n_retx"] + rtx.astype(i32),
              n_giveup=st["n_giveup"] + gup.astype(i32))

    # (f) instant (delay-0) arrivals land this clock — end-of-clock
    # delivery, exactly the lossless wire's timing (what makes a neutral
    # schedule bit-identical to no faults).
    st = _arrive(st, c)
    return st, ship_floats
