"""comm — the bandwidth-faithful cross-pod communication substrate.

Every cross-pod push/reconcile path routes through this layer when
``cfg.comm_active`` (see `core.consistency.compressed`): k-clock delta
aggregation, significance-filtered sparse shipment with an error-feedback
residual, and int8/bf16 wire quantization — with the bits actually shipped
recorded per clock (``Trace.ship_floats``) so the "eager wins" claims are
measured against bytes on the wire, not free deliveries.  The substrate
math lives in `comm.substrate` and is shared verbatim by the simulator
(``core.ps.simulate``) and the executable runtimes (``repro.psrun``,
``repro.pods``) — the oracle contract covers the compressed path too.
"""
from ..core.consistency import compressed
from .substrate import (dense_ship_floats, fold_pods, init_state, pack,
                        quant_scale, reader_base, row_threshold,
                        selected_count, ship_now, shipped_end,
                        shipped_through, wire_floats)

__all__ = ["compressed", "dense_ship_floats", "fold_pods", "init_state",
           "pack", "quant_scale", "reader_base", "row_threshold",
           "selected_count", "ship_now", "shipped_end", "shipped_through",
           "wire_floats"]
