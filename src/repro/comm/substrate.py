"""The bandwidth-faithful cross-pod communication substrate.

PR 4's hierarchical PS reconciled pod replicas by all-gathering the full
dense ``[P, d]`` fresh delta every clock — semantically right, but its
"eager wins" numbers ignored the bytes on the wire.  This module is the
layer both engines (``core.ps.simulate`` and the ``psrun``/``pods``
runtimes) route cross-pod shipment through instead:

- **k-clock delta aggregation** (``cfg.agg_clocks``): each producer
  accumulates its raw updates locally (``acc``) and ships one *summed*
  delta every ``agg_clocks`` clocks.  Cross-pod visibility clocks advance
  only to shipment boundaries (:func:`shipped_end` /
  :func:`shipped_through`), and the two-tier staleness contract widens to
  ``s + s_xpod + agg_clocks - 1`` (``core.delays.staleness_bound_matrix``).
- **significance-filtered sparse shipment** (``cfg.topk_frac``): only the
  ``ceil(topk_frac * d)`` largest-magnitude coordinates of the aggregated
  delta cross the wire — the magnitude threshold (:func:`row_threshold`)
  is VAP's significance criterion reused as a sparsifier.  Dropped mass
  stays in an **error-feedback residual** (``res``) that joins the next
  shipment, so nothing is lost, only delayed: ``wire + residual ==
  acc + res`` exactly in the f32 path (`kernels.ref.delta_pack`).
- **value quantization** (``cfg.quant``): f32 / bf16 / int8 (per-producer
  absmax scale, :func:`quant_scale`) wire formats; the dequantization
  error also lands in the residual.

State layout (both engines; the runtime shards the ``d`` axis over
"model" exactly like the raw ring):

- ``acc [P, d]``    raw updates accumulated since the last shipment;
- ``res [P, d]``    error-feedback residual (unshipped mass);
- ``xring [W, P, d]`` the *wire ring*: slot ``c % W`` holds producer
  shipments of clock ``c`` (zeros on non-boundary clocks).  Cross-pod
  readers materialize their view from this ring; intra-pod readers keep
  reading the raw ring;
- ``base_pod [G, d]`` / ``xbase_pod [G, d]``: per-producer-pod folds of
  recycled raw / wire ring slots.  A reader in pod ``g`` sees ``x0 +
  base_pod[g] + Σ_{g' != g} xbase_pod[g']`` (:func:`reader_base`) — its
  own pod's updates exactly, every other pod's through the compressed
  stream.

Bytes accounting: every shipment's bits-weighted float count
(:func:`wire_floats` — quantized values plus 32-bit indices when sparse)
is recorded per clock in ``Trace.ship_floats``, which
``pods.reconcile.reconcile_stats`` turns into measured floats-on-wire and
`core.timemodel.TimeModel` turns into modeled seconds over the per-tier
bandwidth.

Everything here is traced jnp over the *data* knobs (``agg_clocks``,
``topk_frac`` batch in sweeps like any other knob); only ``quant`` and
the substrate's presence (``cfg.comm_active``) are static.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.consistency import QUANT_BITS
from ..kernels import ops

# --------------------------------------------------------------- schedule


def ship_now(c, agg_clocks):
    """Does a shipment happen at the END of clock ``c``?  (bool, traced)"""
    return jnp.mod(c + 1, agg_clocks) == 0


def shipped_end(c, agg_clocks):
    """Latest shipped producer clock after the end of clock ``c`` — the
    cross-pod delivery target (== ``c`` when ``agg_clocks == 1``)."""
    return ((c + 1) // agg_clocks) * agg_clocks - 1


def shipped_through(c, agg_clocks):
    """Latest shipped producer clock at READ time of clock ``c`` — the
    cross-pod forced-refresh target (== ``c - 1`` when ``agg_clocks ==
    1``).  Always ``>= c - agg_clocks``, which is what keeps the widened
    bound ``s + s_xpod + agg_clocks - 1`` satisfiable."""
    return (c // agg_clocks) * agg_clocks - 1


# ------------------------------------------------------------ compression


def row_threshold(delta, topk_frac):
    """Per-row magnitude threshold selecting the top ``ceil(topk_frac*d)``
    coordinates of each ``[P, d]`` row (ties may admit more — bytes
    accounting counts the actual selection).  ``topk_frac`` may be traced.

    Both engines must call this on the *full* ``d``-coordinate rows (the
    runtime all-gathers its model shards first) so the threshold — and
    with it every shipped bit — is bit-identical across engines."""
    P, d = delta.shape
    mag = jnp.abs(delta)
    k = jnp.clip(jnp.ceil(topk_frac * d).astype(jnp.int32), 1, d)
    srt = jnp.sort(mag, axis=-1)                       # ascending
    idx = jnp.broadcast_to(jnp.asarray(d - k, jnp.int32), (P, 1))
    return jnp.take_along_axis(srt, idx, axis=-1)[:, 0]


def quant_scale(delta, quant: str):
    """Per-row int8 dequant scale (absmax / 127); ones for f32/bf16."""
    P = delta.shape[0]
    if quant != "int8":
        return jnp.ones((P,), jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=-1)
    return jnp.maximum(absmax / 127.0, 1e-12).astype(jnp.float32)


def pack(delta, topk_frac, quant: str):
    """One-stop shipment pack on full rows: ``(wire, residual, nnz)``.

    ``nnz [P]`` is the per-producer count of selected coordinates (f32).
    The runtimes call the pieces separately — thresholds/counts on the
    gathered full rows, `ops.delta_pack` on the local shard — which lands
    on exactly the same floats (the pack is elementwise)."""
    thresh = row_threshold(delta, topk_frac)
    scale = quant_scale(delta, quant)
    wire, residual = ops.delta_pack(delta, thresh, scale, quant)
    nnz = selected_count(delta, thresh)
    return wire, residual, nnz


def selected_count(delta, thresh):
    """Per-row selected-coordinate count [P] (f32), from full rows."""
    return jnp.sum(jnp.abs(delta) >= thresh[:, None], axis=-1,
                   dtype=jnp.int32).astype(jnp.float32)


def wire_floats(nnz, d: int, quant: str):
    """Bits-weighted float32-equivalents on the wire for one shipment.

    ``nnz`` quantized values at ``QUANT_BITS[quant]`` bits each, plus one
    32-bit coordinate index per value whenever the shipment is actually
    sparse (a dense shipment needs no indices)."""
    vals = nnz * (QUANT_BITS[quant] / 32.0)
    idx = jnp.where(nnz < d, nnz, 0.0)
    return vals + idx


def dense_ship_floats(model: str, P: int, d: int):
    """Per-clock ``Trace.ship_floats`` rows of the *dense* (substrate-off)
    path: every push-model producer ships its full ``d``-float delta each
    clock; pull-based SSP ships nothing (its reconciliation cost is the
    forced pulls, accounted separately)."""
    if model == "ssp":
        return jnp.zeros((P,), jnp.float32)
    return jnp.full((P,), float(d), jnp.float32)


# ------------------------------------------------------------ state/views


def init_state(W: int, P: int, d: int, n_pods: int) -> dict:
    """Zero comm state (see module doc for the layout)."""
    z = jnp.zeros
    return dict(acc=z((P, d), jnp.float32), res=z((P, d), jnp.float32),
                xring=z((W, P, d), jnp.float32),
                base_pod=z((n_pods, d), jnp.float32),
                xbase_pod=z((n_pods, d), jnp.float32))


def reader_base(x0, base_pod, xbase_pod, reader_pods):
    """Per-reader folded base ``x0 + base_pod[own] + Σ_{other} xbase_pod``.

    ``x0 [d]``, ``base_pod``/``xbase_pod [G, d]``, ``reader_pods [Pl]``
    (pod id of each reader row).  The other-pod sum is a masked einsum
    (never a subtraction from the total), so both engines produce the
    same float association."""
    G = base_pod.shape[0]
    own = base_pod[reader_pods]                          # [Pl, d]
    other = (jnp.arange(G)[:, None] != reader_pods[None, :]
             ).astype(jnp.float32)                       # [G, Pl]
    xother = jnp.einsum("gp,gd->pd", other, xbase_pod)
    return (x0[None, :] + own) + xother


def fold_pods(ring_slot, n_pods: int):
    """Fold one recycled ring slot ``[P, d]`` into per-producer-pod sums
    ``[G, d]`` (contiguous pod blocks, same reduction order in both
    engines)."""
    P, d = ring_slot.shape
    return ring_slot.reshape(n_pods, P // n_pods, d).sum(axis=1)
