"""Deterministic synthetic data (no datasets ship offline).

Token streams have learnable structure: each document draws a hidden affine
rule ``next = (a * cur + b) mod V_eff`` plus noise, so per-token loss drops
well below uniform entropy within a few hundred steps — enough to validate
end-to-end training and the consistency-model comparisons on real gradients.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TokenGenConfig:
    vocab_size: int
    seq_len: int
    batch: int
    v_eff: int = 256        # active vocabulary slice
    noise: float = 0.05     # per-token corruption probability
    seed: int = 0


def token_batch(cfg: TokenGenConfig, step: int):
    """One [batch, seq_len] int32 batch, deterministic in (seed, step)."""
    rng = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k_a, k_b, k_s, k_n, k_m = jax.random.split(rng, 5)
    v = min(cfg.v_eff, cfg.vocab_size)
    B, S = cfg.batch, cfg.seq_len
    a = 2 * jax.random.randint(k_a, (B, 1), 1, v // 2) + 1   # odd multiplier
    b = jax.random.randint(k_b, (B, 1), 0, v)
    x0 = jax.random.randint(k_s, (B, 1), 0, v)

    def step_fn(x, _):
        nxt = (a[:, 0] * x + b[:, 0]) % v
        return nxt, nxt

    _, seq = jax.lax.scan(step_fn, x0[:, 0], None, length=S - 1)
    toks = jnp.concatenate([x0, seq.T], axis=1)
    noise = jax.random.bernoulli(k_n, cfg.noise, (B, S))
    rand = jax.random.randint(k_m, (B, S), 0, v)
    return jnp.where(noise, rand, toks).astype(jnp.int32)


def token_batches(cfg: TokenGenConfig, n_steps: int | None = None,
                  extra: dict | None = None):
    """Iterator of training batches ({"tokens": ...} + modality stubs)."""
    gen = jax.jit(lambda s: token_batch(cfg, s))
    step = 0
    while n_steps is None or step < n_steps:
        batch = {"tokens": gen(jnp.int32(step))}
        if extra:
            batch.update(extra)
        yield batch
        step += 1


def modality_stub(cfg_model, batch: int, dtype=jnp.float32, seed: int = 7):
    """Frame/patch embeddings for audio/vlm families (assignment carve-out)."""
    rng = jax.random.PRNGKey(seed)
    if cfg_model.family == "audio":
        shape = (batch, cfg_model.encoder.n_ctx, cfg_model.d_model)
        return {"frames": 0.1 * jax.random.normal(rng, shape, dtype)}
    if cfg_model.family == "vlm":
        shape = (batch, cfg_model.vision.n_image_tokens, cfg_model.d_model)
        return {"image_embeds": 0.1 * jax.random.normal(rng, shape, dtype)}
    return {}
