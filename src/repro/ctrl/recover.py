"""Detect -> act: a recovery controller over the observability stream.

The ROADMAP's churn follow-up asks for an adaptive controller that
consumes ``worker_down``/``pod_down`` verdicts and windowed
``slo_violation`` events *keyed on the stream's schema version* — not
new hooks inside the engines.  This module is that loop's "act" half:

  stream  --monitor_stream-->  verdicts + violations  --plan_recovery-->
  typed ``recovery_action`` events (schema v1.2)

Action catalog
--------------
``refresh_burst``
    A worker rejoined (``worker_up`` verdict).  Force
    ``policy.refresh_clocks`` clocks of full-prefix refresh for that
    worker so it rereads the global prefix instead of trusting stale
    cached views (the engines already force-refresh rejoiners for one
    clock; the burst widens that to cover comm-substrate lag).
``pod_restore``
    A pod went dark (``pod_down`` verdict).  Route the pod through the
    checkpoint restore path — ``pods.elastic.run_with_pod_rejoin``
    restores the pod-local replica from the latest `checkpoint.io`
    snapshot and splices its comm rows back in.
``degrade_comm``
    An SLO kind stayed in violation for ``policy.sustained_windows``
    consecutive monitor windows (bandwidth collapse / sustained wire
    loss).  Escalates: first steps down the quantization ladder
    (f32 -> bf16 -> int8), then multiplies ``agg_clocks`` by
    ``policy.agg_step`` (capped at ``policy.max_agg``) so fewer, smaller
    shipments cross the lossy wire.

Actions are *derived purely from verdicts and violations*: a neutral
stream (no churn, no faults, no SLO breach) provably yields zero
actions — there is no unconditional code path that emits one.

numpy/stdlib only (this backs the ``repro.obs`` CLI; no jax at import).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..obs.events import check_version
from ..obs.monitor import DetectorParams, SLOParams, monitor_stream


@dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for `plan_recovery` (see module doc for the action catalog).

    ``quant_ladder`` orders wire formats from heaviest to lightest; each
    sustained-violation streak advances one rung, and once the ladder is
    exhausted further streaks multiply ``agg_clocks`` by ``agg_step``
    up to ``max_agg``.
    """

    sustained_windows: int = 2        # consecutive violating windows
    quant_ladder: tuple = ("f32", "bf16", "int8")
    agg_step: int = 2                 # agg_clocks multiplier per rung
    max_agg: int = 8                  # agg_clocks ceiling
    refresh_clocks: int = 2           # forced-refresh burst length

    def __post_init__(self):
        if self.sustained_windows < 1:
            raise ValueError("sustained_windows must be >= 1")
        if len(self.quant_ladder) < 1:
            raise ValueError("quant_ladder must be non-empty")


def _action(t, ts, action, **extra) -> dict:
    ev = {"type": "recovery_action", "t": int(t), "ts": float(ts),
          "action": str(action)}
    ev.update({k: v for k, v in extra.items() if v is not None})
    return ev


def plan_recovery(events, detector: DetectorParams | None = None,
                  slo: SLOParams | None = None,
                  policy: RecoveryPolicy | None = None):
    """Map one event stream to the recovery actions it warrants.

    Checks the stream's schema version, runs the failure detector + SLO
    monitors (`repro.obs.monitor.monitor_stream`), and translates their
    verdicts/violations through ``policy`` into ``recovery_action``
    event dicts (sorted by clock).  Returns ``(actions, result)`` where
    ``result`` is the underlying `MonitorResult` — callers that already
    have one can use `plan_from_result` instead.
    """
    events = list(events)
    check_version(events)        # keyed on the stream schema version
    result = monitor_stream(events, detector=detector, slo=slo)
    return plan_from_result(result, policy=policy), result


def plan_from_result(result, policy: RecoveryPolicy | None = None) -> list:
    """`plan_recovery` without re-running the monitors: map an existing
    `MonitorResult`'s verdicts + violations to recovery actions."""
    policy = policy or RecoveryPolicy()
    actions = []

    for v in result.verdicts:
        if v.get("kind") == "worker_up":
            actions.append(_action(
                v["t"], v["ts"], "refresh_burst", worker=v.get("worker"),
                clocks=policy.refresh_clocks, reason="worker rejoined"))
        elif v.get("kind") == "pod_down":
            actions.append(_action(
                v["t"], v["ts"], "pod_restore", pod=v.get("pod"),
                reason="pod down: restore from checkpoint via "
                       "pods.elastic.run_with_pod_rejoin"))

    # sustained-violation streaks, per SLO kind: a streak of
    # >= policy.sustained_windows *consecutive* violating windows
    # (window-closing clocks exactly one SLO window apart) escalates
    # one degradation rung; the streak resets after each emission.
    window = None
    for viol in result.violations:
        window = viol.get("window", window)
    streak: dict[str, list] = {}
    rung = 0
    n_quant = len(policy.quant_ladder)
    for viol in sorted(result.violations, key=lambda e: e["t"]):
        kind = viol.get("slo", "?")
        run = streak.setdefault(kind, [])
        w = viol.get("window", window) or 1
        if run and viol["t"] - run[-1]["t"] > w:
            run.clear()              # gap: not consecutive windows
        run.append(viol)
        if len(run) < policy.sustained_windows:
            continue
        rung += 1
        extra = {"reason": f"sustained {kind} violation "
                           f"({len(run)} windows)"}
        if rung < n_quant:
            extra["quant"] = policy.quant_ladder[rung]
        else:
            extra["quant"] = policy.quant_ladder[-1]
            mult = policy.agg_step ** (rung - n_quant + 1)
            extra["agg_clocks"] = min(mult, policy.max_agg)
        actions.append(_action(viol["t"], viol["ts"], "degrade_comm",
                               **extra))
        run.clear()                  # streak resets after emission
    actions.sort(key=lambda a: (a["t"], a["ts"]))
    return actions


def apply_actions(cfg, actions):
    """Fold ``degrade_comm`` actions into a `ConsistencyConfig`.

    Returns ``cfg`` rebuilt with the last action's quantization and its
    ``agg_clocks`` multiplier applied (capped by the multiplier value
    itself — `RecoveryPolicy.max_agg` already bounded it).  Non-comm
    actions (``refresh_burst``/``pod_restore``) don't change the config;
    they route through the engines' existing forced-refresh and
    `pods.elastic` checkpoint paths.
    """
    quant, mult = None, 1
    for a in actions:
        if a.get("action") != "degrade_comm":
            continue
        quant = a.get("quant", quant)
        mult = max(mult, int(a.get("agg_clocks", 1)))
    if quant is None and mult == 1:
        return cfg
    kw = {}
    if quant is not None:
        kw["quant"] = quant
    if mult > 1:
        kw["agg_clocks"] = max(cfg.agg_clocks, 1) * mult
    return cfg.replace(**kw)


def unrecovered_violations(violations, actions) -> list:
    """Violations no action answered: every ``slo_violation`` whose
    clock is later than the last recovery action's clock (or all of
    them, when the controller never fired).  The CLI's ``--actions``
    mode exits nonzero when this is non-empty."""
    last_t = max((a["t"] for a in actions), default=None)
    if last_t is None:
        return list(violations)
    return [v for v in violations if v["t"] > last_t]


def attach_actions(events, actions) -> list:
    """Splice ``recovery_action`` events into a stream at their clocks
    (after any same-clock events, before ``run_end``), keeping the
    result a valid schema-v1.x stream for replay/audit."""
    events = list(events)
    out, pending = [], sorted(actions, key=lambda a: (a["t"], a["ts"]))
    for ev in events:
        if ev.get("type") == "run_end":
            out.extend(pending)
            pending = []
        while pending and "t" in ev and ev.get("type") != "run_start" \
                and pending[0]["t"] < ev["t"]:
            out.append(pending.pop(0))
        out.append(ev)
    out.extend(pending)
    return out
