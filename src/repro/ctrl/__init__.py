"""Closed-loop control: consumers of the obs stream that act on it.

`repro.ctrl.recover` is the detect→act half of the ROADMAP's adaptive
controller: it turns `repro.obs.monitor` verdicts and SLO violations
into typed recovery actions, emitted back into the stream as schema-v1.2
events.  Everything here is numpy/stdlib only — controllers consume
streams, they never grow hooks inside the engines.
"""
from .recover import (RecoveryPolicy, apply_actions, attach_actions,
                      plan_recovery, unrecovered_violations)

__all__ = ["RecoveryPolicy", "plan_recovery", "apply_actions",
           "attach_actions", "unrecovered_violations"]
