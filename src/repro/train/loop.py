"""Host-side training loop with logging and checkpointing."""
from __future__ import annotations

import time
from typing import Callable, Iterable

import jax
import numpy as np


def train(train_step, state, batches: Iterable, n_steps: int,
          log_every: int = 10, checkpoint_fn: Callable | None = None,
          checkpoint_every: int = 0, log_fn=print):
    """Run the compiled train step over a batch iterator."""
    step_fn = jax.jit(train_step) if not hasattr(train_step, "lower") else train_step
    history = []
    t0 = time.time()
    tokens_seen = 0
    for i, batch in enumerate(batches):
        if i >= n_steps:
            break
        state, metrics = step_fn(state, batch)
        tok = int(np.prod(np.asarray(batch["tokens"]).shape))
        tokens_seen += tok
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            m.update(step=i + 1, wall_s=round(dt, 2),
                     tok_per_s=round(tokens_seen / max(dt, 1e-9)))
            history.append(m)
            log_fn(f"step {i+1:5d}  loss {m['loss']:.4f}  "
                   f"tok/s {m['tok_per_s']:.0f}  wall {m['wall_s']:.1f}s")
        if checkpoint_fn and checkpoint_every and (i + 1) % checkpoint_every == 0:
            checkpoint_fn(state, i + 1)
    return state, history
