"""Training losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels, z_loss: float = 1e-4):
    """Mean next-token cross entropy with optional z-loss regularizer.

    logits [B, S, V] (any float dtype), labels [B, S] int32.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def shift_labels(tokens):
    """Next-token prediction targets: labels[t] = tokens[t+1], last = pad."""
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    return labels
