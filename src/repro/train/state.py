"""Train state and step construction (consistency-aware)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..models.registry import Model
from ..optim.optimizers import Optimizer, apply_updates
from ..psdist.grad_sync import GradSync, init_fifo, sync_gradients
from .losses import shift_labels, softmax_xent


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TrainState:
    params: Any
    opt_state: Any
    fifo: Any            # SSP gradient FIFO (None for BSP/ESSP s=0)
    step: jax.Array


def init_state(model: Model, opt: Optimizer, sync: GradSync, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt_state=opt.init(params),
                      fifo=init_fifo(sync, params),
                      step=jnp.zeros((), jnp.int32))


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        labels = batch["labels"] if "labels" in batch else shift_labels(
            batch["tokens"])
        return softmax_xent(logits, labels) + aux
    return loss_fn


def make_train_step(model: Model, opt: Optimizer,
                    sync: GradSync = GradSync(), data_axes=()):
    """Build the jit-able train step.

    ``data_axes=()`` for pjit (collectives implicit via sharding);
    ``("data",)`` etc. when wrapped in shard_map (explicit psums, where the
    ESSP bucketed schedule is visible in the HLO).
    """
    loss_fn = make_loss_fn(model)

    def train_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        grads, fifo, scale = sync_gradients(sync, grads, state.fifo, data_axes)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        # SSP warm-up: FIFO not yet full -> apply nothing this step
        updates = jax.tree.map(lambda u: u * scale, updates)
        params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm, "apply_scale": scale}
        return TrainState(params=params, opt_state=opt_state, fifo=fifo,
                          step=state.step + 1), metrics

    return train_step


def make_accum_train_step(model: Model, opt: Optimizer,
                          sync: GradSync = GradSync(), accum: int = 1,
                          data_axes=(), accum_dtype=jnp.float32):
    """Gradient-accumulation variant: batch leaves have a leading microbatch
    axis [accum, ...].  This is the paper's "update coalescing" (INCs are
    summed locally before hitting the server).

    ``accum_dtype=bfloat16`` halves the accumulator footprint — used for the
    398B config where the f32 accumulator alone is 6.3 GB/chip."""
    if accum == 1:
        return make_train_step(model, opt, sync, data_axes)
    loss_fn = make_loss_fn(model)

    def train_step(state: TrainState, batch):
        def micro(carry, mb):
            loss_acc, grads_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype) / accum,
                grads_acc, grads)
            return (loss_acc + loss / accum, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                             state.params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), batch)
        grads, fifo, scale = sync_gradients(sync, grads, state.fifo, data_axes)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        updates = jax.tree.map(lambda u: u * scale, updates)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "apply_scale": scale}
        return TrainState(params=params, opt_state=opt_state, fifo=fifo,
                          step=state.step + 1), metrics

    return train_step
