"""The executable PS runtime: one `shard_map` clock step on a 2-D mesh.

Layout (mesh axes ``("data", "model")``, built by `launch.mesh.make_ps_mesh`):

- the flat parameter vector (dim ``d``, zero-padded to divide the model
  axis) is sharded over ``"model"``: each model shard *owns* a contiguous
  coordinate block of the table — the server side;
- the ``P`` workers are partitioned over ``"data"`` (``P`` must divide by
  the axis size); each data shard holds its workers' local state, the
  reader rows of the per-channel clock matrix ``cview[r, q]``, and (with
  the model axis) its block of every producer's in-transit update ring —
  the client cache;
- the update ring ``uring[W, P, d_block]`` is replicated over ``"data"``
  and sharded over ``"model"``: every reader can see every producer's
  updates for the coordinates its column owns, which is exactly the cache
  layout of ESSPTable clients subscribed to all table rows.

Per clock, inside ``shard_map`` (collectives annotated):

1. consistency enforcement advances the local reader rows of ``cview``
   (blocking fetches; VAP needs the global suffix-aggregate inf-norms —
   one ``pmax`` over ``"model"``);
2. views materialize shard-locally through ``kernels.ops.ring_view``
   (readers × owned coordinates — the Pallas path on TPU), then assemble
   per-reader full views with an ``all_gather`` over ``"model"``;
3. each worker runs ``app.worker_update`` on its own data shard;
4. updates are pushed to the owning shards: ``all_gather`` over ``"data"``
   then keep the owned coordinate block (a host-mesh stand-in for the
   per-shard all-to-all a network PS would do), written into the ring;
   the oldest ring slot folds into the shard's base;
5. the end-of-clock delivery matrix (the synthetic network model shared
   with the simulator — `core.delays`) advances ``cview`` eagerly for
   ESSP/async/VAP; SSP ignores pushes (pull-based).

RNG and arithmetic mirror ``core.ps.simulate`` *exactly* (same key splits,
same per-coordinate reduction orders), which is what makes the simulator an
executable oracle: a seeded BSP run matches bit for bit, and the numeric
knobs of `ConsistencyConfig` stay jit *arguments* (pytree data), so
re-running with different staleness/push_prob/straggler knobs reuses the
compiled program — one compile per config family, like ``core.sweep``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P_

from ..core.consistency import ConsistencyConfig
from ..core.delays import delivery_matrix
from ..core.ps import PSApp, Trace
from ..kernels import ops
from ..kernels.ref import RING_EMPTY, RING_INVALID
from ..launch.mesh import make_ps_mesh

# Ticks once per (re)trace of the runtime body, i.e. once per compiled
# program — the same compile-count evidence `core.sweep` keeps.  Numeric
# knob changes must NOT tick it (one compile per config family).
_TRACE_COUNTER = {"count": 0}


def trace_count() -> int:
    return _TRACE_COUNTER["count"]


def default_mesh(n_workers: int, devices=None):
    """The widest ``("data","model")`` mesh for ``n_workers`` that stays in
    the bit-identity regime: the data axis is the largest divisor of the
    device count that divides the worker count while keeping >= 2 workers
    per shard; an even leftover becomes 2 model-shard columns."""
    if devices is None:
        devices = jax.devices()
    n = len(devices)
    data = 1
    for cand in range(min(n, n_workers // 2), 0, -1):
        if n_workers % cand == 0 and n % cand == 0:
            data = cand
            break
    rest = n // data
    model = 2 if (rest > 1 and rest % 2 == 0) else 1
    return make_ps_mesh(data=data, model=model, devices=devices)


def _layout(app: PSApp, mesh):
    """Validate the (app, mesh) pairing and derive the shard geometry."""
    assert set(("data", "model")) <= set(mesh.axis_names), mesh.axis_names
    DP, M = mesh.shape["data"], mesh.shape["model"]
    P, d = app.n_workers, app.dim
    if P % DP:
        raise ValueError(
            f"n_workers={P} must divide by the data axis ({DP}); "
            f"build a smaller mesh with launch.mesh.make_ps_mesh")
    dpad = -(-d // M) * M
    return DP, M, P // DP, dpad, dpad // M


def make_run_fn(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                mesh=None, record_views: bool = False):
    """Build the jitted runtime for one config *family* on ``mesh``.

    Returns ``fn(seed, cfg) -> Trace``.  ``cfg``'s numeric knobs are traced
    jit arguments — calling with different staleness/push_prob/straggler
    values (same model, same ring window) reuses the compiled program.  The
    ``cfg`` given here only fixes the static structure (model, window,
    read_my_writes).
    """
    mesh = make_ps_mesh() if mesh is None else mesh
    _DP, _M, Pl, dpad, dl = _layout(app, mesh)
    P, d = app.n_workers, app.dim
    W = cfg.effective_window
    f32 = jnp.float32

    def body(cfg, base, uring, uclock, cview, local, rng):
        # local shards: base [dl], uring [W, P, dl], uclock [W] (replicated),
        # cview [Pl, P], local leaves [Pl, ...], rng replicated.
        _TRACE_COUNTER["count"] += 1          # fires once per trace/compile
        di = jax.lax.axis_index("data")
        mi = jax.lax.axis_index("model")
        rows0 = (di * Pl).astype(jnp.int32)
        worker_ids = rows0 + jnp.arange(Pl, dtype=jnp.int32)
        producer_ids = jnp.arange(P, dtype=jnp.int32)
        eye_l = worker_ids[:, None] == producer_ids[None, :]   # local eye rows
        s = cfg.staleness

        vmapped_update = jax.vmap(app.worker_update,
                                  in_axes=(0, 0, 0, None, 0))

        def enforce_vap(c, cview, norms):
            # identical math to ps.simulate.enforce_vap, on local reader rows
            v_t = cfg.v0 / jnp.sqrt(c.astype(f32) + 1.0)
            ok = norms <= v_t                                  # [W+1, P]
            ok = ok.at[0].set(True)
            kcur = jnp.clip(c - 1 - cview, 0, W)               # [Pl, P]
            ks = jnp.arange(W + 1, dtype=jnp.int32)[:, None, None]
            cond = ok[:, None, :] & (ks <= kcur[None, :, :])
            kbest = jnp.max(jnp.where(cond, ks, -1), axis=0)   # [Pl, P]
            required = c - 1 - kbest
            forced = cview < required
            return jnp.maximum(cview, required), forced

        def step(carry, c):
            base, uring, uclock, cview, local, rng = carry
            rng, k_upd, k_net = jax.random.split(rng, 3)

            # global per-producer suffix-aggregate inf-norms: local block
            # norms, max-reduced over the owning shards.
            norms = jax.lax.pmax(
                ops.vap_suffix_norms(uring, uclock, c), "model")  # [W+1, P]

            # --- 1. pre-read consistency enforcement (blocking fetches) ---
            if cfg.model == "bsp":
                forced = cview < (c - 1)
                cview = jnp.full_like(cview, c - 1)
            elif cfg.model in ("ssp", "essp"):
                forced = cview < (c - s - 1)
                cview = jnp.where(forced, c - 1, cview)
            elif cfg.model == "vap":
                cview, forced = enforce_vap(c, cview, norms)
            else:  # async
                forced = jnp.zeros_like(cview, dtype=bool)

            if cfg.read_my_writes:
                cview = jnp.where(eye_l, c - 1, cview)

            staleness = cview - c                              # [Pl, P]

            kcur = jnp.clip(c - 1 - cview, 0, W)               # [Pl, P]
            intransit_inf = jax.lax.pmax(
                jnp.max(norms[kcur, producer_ids[None, :]]), "data")

            # --- 2. materialize views: shard-local, then assemble ---------
            views_l = ops.ring_view(base, uring, uclock, cview)  # [Pl, dl]
            views = jax.lax.all_gather(views_l, "model", axis=1,
                                       tiled=True)[:, :d]        # [Pl, d]

            # --- 3. worker computation (this shard's workers only) --------
            upd_keys = jax.lax.dynamic_slice_in_dim(
                jax.random.split(k_upd, P), rows0, Pl)
            u_l, local = vmapped_update(views, local, worker_ids, c, upd_keys)
            u_l = u_l.astype(f32)                              # [Pl, d]

            # --- 4. push to owning shards; fold oldest slot ---------------
            u_all = jax.lax.all_gather(u_l, "data", axis=0, tiled=True)
            # norm on the gathered [P, d] — the oracle's operand shape, so
            # XLA emits the same reduction and the floats match bit-for-bit
            u_l2 = jnp.linalg.norm(u_all, axis=-1)
            u_all = jnp.pad(u_all, ((0, 0), (0, dpad - d)))
            u_blk = jax.lax.dynamic_slice(u_all, (0, mi * dl), (P, dl))
            slot = jnp.mod(c, W)
            old_valid = uclock[slot] > RING_INVALID
            base = base + jnp.where(old_valid, 1.0, 0.0) * jnp.sum(
                uring[slot], axis=0)
            uring = uring.at[slot].set(u_blk)
            uclock = uclock.at[slot].set(c)

            # --- 5. end-of-clock delivery (affects reads at c+1) ----------
            if cfg.model == "bsp":
                delivered = jnp.ones((Pl, P), bool)
                cview = jnp.full_like(cview, c)
            elif cfg.model == "ssp":
                delivered = jnp.zeros((Pl, P), bool)
            else:  # essp / async / vap: delay-driven eager delivery
                delivered = jax.lax.dynamic_slice_in_dim(
                    delivery_matrix(k_net, cfg, P), rows0, Pl)
                cview = jnp.where(delivered, c, cview)

            # --- 6. record (gathered so losses match the oracle exactly) --
            x_ref = base + jnp.sum(
                uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
            x_ref = jax.lax.all_gather(x_ref, "model", tiled=True)[:d]
            locals_all = jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, "data", axis=0, tiled=True),
                local)
            views_all = jax.lax.all_gather(views, "data", axis=0, tiled=True)
            out = dict(loss_ref=app.loss(x_ref, locals_all),
                       loss_view=app.loss(views_all[0], locals_all),
                       staleness=staleness, forced=forced,
                       delivered=delivered,
                       u_l2=u_l2, intransit_inf=intransit_inf)
            if record_views:
                out["views0"] = views_all[0]
            return (base, uring, uclock, cview, local, rng), out

        carry0 = (base, uring, uclock, cview, local, rng)
        (base, uring, uclock, _, local, _), ys = jax.lax.scan(
            step, carry0, jnp.arange(n_clocks, dtype=jnp.int32))
        x_final = base + jnp.sum(
            uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
        return {"ys": ys, "x_final": x_final, "locals_final": local}

    local_spec = jax.tree_util.tree_map(lambda _: P_("data"), app.local0)
    ys_specs = {"loss_ref": P_(), "loss_view": P_(),
                "staleness": P_(None, "data", None),
                "forced": P_(None, "data", None),
                "delivered": P_(None, "data", None),
                "u_l2": P_(), "intransit_inf": P_()}
    if record_views:
        ys_specs["views0"] = P_()
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P_(), P_("model"), P_(None, None, "model"), P_(),
                  P_("data", None), local_spec, P_()),
        out_specs={"ys": ys_specs, "x_final": P_("model"),
                   "locals_final": local_spec},
        check_rep=False)

    def run(seed, cfg):
        base0 = jnp.pad(app.x0.astype(f32), (0, dpad - d))
        uring0 = jnp.zeros((W, P, dpad), f32)
        uclock0 = jnp.full((W,), RING_EMPTY, jnp.int32)
        cview0 = jnp.full((P, P), -1, jnp.int32)
        rng0 = jax.random.PRNGKey(seed)
        out = sharded(cfg, base0, uring0, uclock0, cview0, app.local0, rng0)
        ys = out["ys"]
        return Trace(loss_ref=ys["loss_ref"], loss_view=ys["loss_view"],
                     staleness=ys["staleness"], forced=ys["forced"],
                     delivered=ys["delivered"], u_l2=ys["u_l2"],
                     intransit_inf=ys["intransit_inf"],
                     views0=ys.get("views0"),
                     x_final=out["x_final"][:d],
                     locals_final=out["locals_final"])

    jitted = jax.jit(run)

    def fn(seed, cfg_run: ConsistencyConfig | None = None):
        c = cfg if cfg_run is None else cfg_run
        if c.effective_window != W:
            raise ValueError(
                f"runtime compiled for ring window {W}, got "
                f"{c.effective_window}; set cfg.window explicitly or build "
                f"a new run fn")
        # normalize the static window so every same-family call shares one
        # pytree treedef (and therefore one jit cache entry)
        return jitted(jnp.asarray(seed, jnp.uint32), c.replace(window=W))

    return fn


class PSRuntime:
    """Executable sharded PS: ``PSRuntime(mesh).run(app, cfg, n_clocks)``.

    Produces the same `core.ps.Trace` schema as ``core.ps.simulate`` (the
    *Trace-producer contract*: identical fields, leading clock axis, same
    RNG stream), executed over the mesh instead of vectorized on one
    device.  Compiled programs are cached per (app, config family, ring
    window, n_clocks) — numeric knob changes re-use them.
    """

    def __init__(self, mesh=None):
        self.mesh = make_ps_mesh() if mesh is None else mesh
        self._cache: dict = {}

    def run_fn(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
               record_views: bool = False):
        """The cached jitted ``fn(seed, cfg) -> Trace`` for this family."""
        key = (id(app), cfg.family, cfg.effective_window, n_clocks,
               record_views)
        fn = self._cache.get(key)
        if fn is None:
            fn = make_run_fn(app, cfg, n_clocks, mesh=self.mesh,
                             record_views=record_views)
            self._cache[key] = fn
        return fn

    def run(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
            seed=0, record_views: bool = False) -> Trace:
        """Run ``n_clocks`` of the app under ``cfg`` on the mesh."""
        return self.run_fn(app, cfg, n_clocks, record_views)(seed, cfg)
