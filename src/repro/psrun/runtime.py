"""The executable PS runtime: one `shard_map` clock step on a device mesh.

Layout (mesh axes ``("data", "model")``, built by `launch.mesh.make_ps_mesh`;
the hierarchical runtime in ``repro.pods`` reuses this module with worker
axes ``("pod", "data")`` on a 3-D mesh from `launch.mesh.make_pods_mesh`):

- the flat parameter vector (dim ``d``, zero-padded to divide the model
  axis) is sharded over ``"model"``: each model shard *owns* a contiguous
  coordinate block of the table — the server side;
- the ``P`` workers are partitioned over the *worker axes* (``"data"``, or
  ``("pod","data")`` pod-major — ``P`` must divide by the product of their
  sizes); each worker shard holds its workers' local state, the reader rows
  of the per-channel clock matrix ``cview[r, q]``, and (with the model
  axis) its block of every producer's in-transit update ring — the client
  cache;
- the update ring ``uring[W, P, d_block]`` is replicated over the worker
  axes and sharded over ``"model"``: every reader can see every producer's
  updates for the coordinates its column owns, which is exactly the cache
  layout of ESSPTable clients subscribed to all table rows.  Under the pod
  axis this replication *is* the per-pod parameter-shard replica: each pod
  holds a full copy of the table, and the per-clock all-gather of fresh
  updates over the worker axes is the eager delta channel that keeps the
  replicas' contents reconciled (only the newest clock's updates — one
  ``[P, d]`` delta, not the ``[W, P, d]`` replica — cross the pod
  boundary), while ``cview`` decides what each reader may *see* of them
  (two-tier staleness: `core.delays.staleness_bound_matrix`).

Per clock, inside ``shard_map`` (collectives annotated):

1. consistency enforcement advances the local reader rows of ``cview``
   (blocking fetches; VAP needs the global suffix-aggregate inf-norms —
   one ``pmax`` over ``"model"``);
2. views materialize shard-locally through ``kernels.ops.ring_view``
   (readers × owned coordinates — the Pallas path on TPU), then assemble
   per-reader full views with an ``all_gather`` over ``"model"``;
3. each worker runs ``app.worker_update`` on its own worker shard;
4. updates are pushed to the owning shards: ``all_gather`` over the worker
   axes then keep the owned coordinate block (a host-mesh stand-in for the
   per-shard all-to-all a network PS would do), written into the ring;
   the oldest ring slot folds into the shard's base (the delta-compressed
   fold: ``P`` producer updates collapse into one ``[d_block]`` vector);
5. the end-of-clock delivery matrix (the synthetic network model shared
   with the simulator — `core.delays`, two-tier under ``cfg.n_pods > 1``)
   advances ``cview`` eagerly for ESSP/async/VAP; SSP ignores pushes
   (pull-based).

RNG and arithmetic mirror ``core.ps.simulate`` *exactly* (same key splits,
same per-coordinate reduction orders), which is what makes the simulator an
executable oracle: a seeded BSP run matches bit for bit, and the numeric
knobs of `ConsistencyConfig` stay jit *arguments* (pytree data), so
re-running with different staleness/push_prob/straggler knobs reuses the
compiled program — one compile per config family, like ``core.sweep``.

Mid-run state
-------------
The compiled step carries an explicit `PSState` (clock, base, ring, cview,
worker locals, RNG key), exposed through ``init_state`` / ``run_from``:
``run_from(state, n)`` returns the per-clock `Trace` plus the advanced
state, and resuming from a saved state reproduces the uninterrupted run
bit for bit (``checkpoint.io.save_runtime`` round-trips it through disk —
`tests/test_pods.py` pins the determinism).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P_

from ..comm import substrate as comm
from ..comm import wire
from ..core.consistency import ConsistencyConfig
from ..core.delays import ChurnSchedule, churn_live, churn_rates, \
    delivery_matrix, pod_of, staleness_bound_matrix
from ..core.ps import PSApp, Trace, enforce_vap
from ..kernels import ops
from ..kernels.ref import RING_EMPTY, RING_INVALID
from ..launch.mesh import make_ps_mesh
from ..obs import metrics as obsm

# Ticks once per (re)trace of the runtime body, i.e. once per compiled
# program — the same compile-count evidence `core.sweep` keeps.  Numeric
# knob changes must NOT tick it (one compile per config family).
_TRACE_COUNTER = {"count": 0}


def trace_count() -> int:
    return _TRACE_COUNTER["count"]


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PSState:
    """Mid-run runtime state (everything the clock step carries).

    ``base``/``uring`` are in the runtime's padded coordinate layout
    (``dpad`` divides the model axis); ``clock`` is the next clock to
    execute.  A `PSState` is an ordinary pytree of arrays, so
    ``checkpoint.io.save`` / ``restore`` round-trip it unchanged.
    """

    clock: jax.Array           # [] i32 — next clock to execute
    base: jax.Array            # [dpad] folded (globally visible) updates
    #                            (under the comm substrate: constant x0 —
    #                            folds go to comm["base_pod"] per pod)
    uring: jax.Array           # [W, P, dpad] in-transit update ring
    uclock: jax.Array          # [W] clock stored in each ring slot
    cview: jax.Array           # [P, P] per-channel visibility clocks
    local: Any                 # worker-local state (leaves lead with P)
    rng: jax.Array             # PRNG key (the simulator's key stream)
    comm: Any = None           # comm-substrate state (repro.comm: acc,
    #                            res, xring, base_pod, xbase_pod) when
    #                            cfg.comm_active; None on the dense path


def default_mesh(n_workers: int, devices=None):
    """The widest ``("data","model")`` mesh for ``n_workers`` that stays in
    the bit-identity regime: the data axis is the largest divisor of the
    device count that divides the worker count while keeping >= 2 workers
    per shard; an even leftover becomes 2 model-shard columns."""
    if devices is None:
        devices = jax.devices()
    n = len(devices)
    data = 1
    for cand in range(min(n, n_workers // 2), 0, -1):
        if n_workers % cand == 0 and n % cand == 0:
            data = cand
            break
    rest = n // data
    model = 2 if (rest > 1 and rest % 2 == 0) else 1
    return make_ps_mesh(data=data, model=model, devices=devices)


def _layout(app: PSApp, mesh, worker_axes):
    """Validate the (app, mesh) pairing and derive the shard geometry."""
    assert set(worker_axes) | {"model"} <= set(mesh.axis_names), \
        (mesh.axis_names, worker_axes)
    DP = 1
    for ax in worker_axes:
        DP *= mesh.shape[ax]
    M = mesh.shape["model"]
    P, d = app.n_workers, app.dim
    if P % DP:
        raise ValueError(
            f"n_workers={P} must divide by the worker axes "
            f"{tuple(worker_axes)} of total size {DP}; build a smaller "
            f"mesh with launch.mesh.make_ps_mesh/make_pods_mesh")
    dpad = -(-d // M) * M
    return DP, M, P // DP, dpad, dpad // M


def make_run_fn(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                mesh=None, record_views: bool = False,
                worker_axes: tuple = ("data",),
                schedule: ChurnSchedule | None = None,
                obs: obsm.ObsSpec | None = None,
                faults: wire.WireFaults | None = None):
    """Build the jitted runtime for one config *family* on ``mesh``.

    Returns a callable ``fn(seed, cfg, schedule=None) -> Trace``.
    ``cfg``'s numeric knobs are traced jit arguments — calling with
    different staleness/push_prob/straggler values (same model, same ring
    window) reuses the compiled program.  The ``cfg`` given here only
    fixes the static structure (model, window, read_my_writes, n_pods).
    Likewise ``schedule`` here only fixes the churn *structure* (present
    or not, which optional arrays it carries, the in-flight policy): the
    actual liveness/regime arrays are traced jit arguments too, so
    same-shape schedules share one compile.

    The callable also exposes the state-carrying entry points
    ``fn.init_state(seed) -> PSState`` and ``fn.run_from(state, cfg,
    schedule) -> (Trace, PSState)``; ``fn(seed, cfg)`` is exactly
    ``fn.run_from(fn.init_state(seed), cfg)[0]``.  Schedules index by
    *absolute* clock, so a resumed segment reads the same slice the
    uninterrupted run would.

    ``worker_axes`` names the mesh axes that partition the workers
    (``("data",)`` for the flat runtime, ``("pod", "data")`` for
    `repro.pods` — pod-major, matching `core.delays.pod_of`).

    ``obs`` (static, `repro.obs.ObsSpec`) threads telemetry accumulators
    through the scan — each worker shard folds its own reader rows, one
    ``psum``/``pmax`` per leaf after the scan merges them, and the result
    lands in ``Trace.obs``.  ``None`` (default) compiles the exact
    pre-obs program.

    ``faults`` (`repro.comm.wire.WireFaults`) makes the cross-pod wire
    lossy: seeded drop/duplicate/delay masks drive the stop-and-wait
    ack/retransmit protocol of ``wire.wire_step``, bit-identical to the
    simulator oracle.  Like the churn schedule, only the *structure*
    (presence + the static rto0/max_retries/max_delay/heal knobs) is
    compiled in; the mask arrays are traced jit arguments.  Requires
    ``cfg.comm_active``.
    """
    mesh = make_ps_mesh() if mesh is None else mesh
    worker_axes = tuple(worker_axes)
    _DP, _M, Pl, dpad, dl = _layout(app, mesh, worker_axes)
    P, d = app.n_workers, app.dim
    W = cfg.effective_window
    if cfg.n_pods > 1 and P % cfg.n_pods:
        raise ValueError(f"n_workers={P} must divide by n_pods={cfg.n_pods}")
    f32 = jnp.float32
    # Static: route cross-pod shipment through the comm substrate — the
    # same compressed state machine as core.ps.simulate's wired mode, so
    # the oracle contract covers the compressed path too.
    wired = cfg.comm_active
    quant0, G = cfg.quant, cfg.n_pods
    obs_enabled = obsm.obs_on(obs)
    churned = schedule is not None
    if churned and schedule.live.shape[1] != P:
        raise ValueError(f"schedule has {schedule.live.shape[1]} workers, "
                         f"app has {P}")
    faulted = faults is not None
    if faulted:
        wire.validate_faults(faults, cfg, P, W)

    def body(cfg, clock0, base, uring, uclock, cview, local, rng,
             *extra):
        _i = 0
        cst = flt = sched = None
        if wired:
            cst, _i = extra[_i], _i + 1
        if faulted:
            flt, _i = extra[_i], _i + 1
        if churned:
            sched = extra[_i]
        # local shards: base [dl], uring [W, P, dl], uclock [W] (replicated),
        # cview [Pl, P], local leaves [Pl, ...], rng/clock0 replicated;
        # comm state (wired only): acc/res [P, dl], xring [W, P, dl],
        # base_pod/xbase_pod [G, dl] — all sharded over "model" like uring.
        _TRACE_COUNTER["count"] += 1          # fires once per trace/compile
        di = jax.lax.axis_index(worker_axes)
        mi = jax.lax.axis_index("model")
        rows0 = (di * Pl).astype(jnp.int32)
        worker_ids = rows0 + jnp.arange(Pl, dtype=jnp.int32)
        producer_ids = jnp.arange(P, dtype=jnp.int32)
        eye_l = worker_ids[:, None] == producer_ids[None, :]   # local eye rows
        # Two-tier staleness bound on the local reader rows (`s` intra-pod,
        # `s + s_xpod` cross-pod, `+ agg_clocks - 1` under the substrate;
        # one-tier and exactly `s` when n_pods=1).  The lossy-wire trigger
        # stays *unwidened* — refresh targets are capped on `wire_tip`, so
        # eager firing is safe; only the declared contract carries the
        # `+ retry_budget` widening (oracle mirror).
        s_eff = staleness_bound_matrix(cfg, worker_ids, P)       # [Pl, P]
        if wired:
            pods_all = pod_of(P, G)                            # [P]
            reader_pods = pods_all[worker_ids]                 # [Pl]
            in_pod = reader_pods[:, None] == pods_all[None, :]  # [Pl, P]
            zeros_dl = jnp.zeros((dl,), f32)
        if obs_enabled:
            # channel-tier mask on the local reader rows for the
            # forced-refresh split (all-True when G == 1)
            if wired:
                in_pod_obs = in_pod
            else:
                pods_o = pod_of(P, G)
                in_pod_obs = pods_o[worker_ids][:, None] == pods_o[None, :]

        vmapped_update = jax.vmap(app.worker_update,
                                  in_axes=(0, 0, 0, None, 0))

        def step(carry, c):
            if obs_enabled:
                *carry, oacc = carry
            if wired:
                base, uring, uclock, cview, local, rng, cst = carry
            else:
                base, uring, uclock, cview, local, rng = carry
            rng, k_upd, k_net = jax.random.split(rng, 3)

            if churned:
                live_now, died = churn_live(sched, c)     # [P], [P]
                live_l = jax.lax.dynamic_slice_in_dim(
                    live_now, rows0, Pl)                  # local reader rows
                rates = churn_rates(cfg, sched, P, c)
                if sched.drop_inflight:
                    # drop policy: mirror the oracle — a dying worker's
                    # in-flight ring rows (and unshipped comm rows) zero
                    # out the clock it dies.
                    keep = ~died
                    uring = jnp.where(keep[None, :, None], uring, 0.0)
                    if wired:
                        cst = dict(cst,
                                   acc=jnp.where(keep[:, None],
                                                 cst["acc"], 0.0),
                                   res=jnp.where(keep[:, None],
                                                 cst["res"], 0.0),
                                   xring=jnp.where(keep[None, :, None],
                                                   cst["xring"], 0.0))
                    if faulted:
                        # a dying producer's unacked shipment and lane
                        # copies vanish with it (oracle mirror)
                        cst = wire.drop_pending(cst, keep)
                cview_pre = cview
            else:
                rates = None

            # global per-producer suffix-aggregate inf-norms: local block
            # norms, max-reduced over the owning shards.
            norms = jax.lax.pmax(
                ops.vap_suffix_norms(uring, uclock, c), "model")  # [W+1, P]

            # --- 1. pre-read consistency enforcement (blocking fetches) ---
            if cfg.model == "bsp":
                forced = cview < (c - 1)
                cview = jnp.full_like(cview, c - 1)
            elif cfg.model in ("ssp", "essp"):
                forced = cview < (c - s_eff - 1)
                if wired and faulted:
                    # a faulted cross-pod refresh can only fetch what has
                    # actually *arrived*: wire_tip caps the shipped
                    # boundary (oracle mirror)
                    tgt = jnp.where(in_pod, c - 1,
                                    jnp.minimum(
                                        comm.shipped_through(
                                            c, cfg.agg_clocks),
                                        cst["wire_tip"][None, :]))
                    cview = jnp.where(forced, tgt, cview)
                elif wired:
                    # cross-pod refreshes fetch what has *shipped* (through
                    # the last aggregation boundary), mirroring the oracle
                    tgt = jnp.where(in_pod, c - 1,
                                    comm.shipped_through(c, cfg.agg_clocks))
                    cview = jnp.where(forced, tgt, cview)
                else:
                    cview = jnp.where(forced, c - 1, cview)
            elif cfg.model == "vap":
                cview, forced = enforce_vap(cfg, c, cview, norms, W)
            else:  # async
                forced = jnp.zeros_like(cview, dtype=bool)

            if cfg.read_my_writes:
                cview = jnp.where(eye_l, c - 1, cview)

            if churned:
                # dead readers neither fetch nor advance (oracle mirror)
                forced = forced & live_l[:, None]
                cview = jnp.where(live_l[:, None], cview, cview_pre)

            staleness = cview - c                              # [Pl, P]

            kcur = jnp.clip(c - 1 - cview, 0, W)               # [Pl, P]
            intransit_inf = jax.lax.pmax(
                jnp.max(norms[kcur, producer_ids[None, :]]), worker_axes)

            # --- 2. materialize views: shard-local, then assemble ---------
            if wired:
                # intra-pod producers read raw, cross-pod producers read
                # the shipped wire ring; folded bases assemble per reader
                # pod — the same three-term sum as the oracle.
                cv_intra = jnp.where(in_pod, cview, RING_EMPTY)
                cv_xpod = jnp.where(in_pod, RING_EMPTY, cview)
                rb = comm.reader_base(base, cst["base_pod"],
                                      cst["xbase_pod"], reader_pods)
                views_l = (rb
                           + ops.ring_view(zeros_dl, uring, uclock,
                                           cv_intra)
                           + ops.ring_view(zeros_dl, cst["xring"], uclock,
                                           cv_xpod))              # [Pl, dl]
            else:
                views_l = ops.ring_view(base, uring, uclock, cview)
            views = jax.lax.all_gather(views_l, "model", axis=1,
                                       tiled=True)[:, :d]        # [Pl, d]

            # --- 3. worker computation (this shard's workers only) --------
            upd_keys = jax.lax.dynamic_slice_in_dim(
                jax.random.split(k_upd, P), rows0, Pl)
            u_l, local_new = vmapped_update(views, local, worker_ids, c,
                                            upd_keys)
            u_l = u_l.astype(f32)                              # [Pl, d]
            if churned:
                # mask dead workers' pushes BEFORE the all-gather so the
                # gathered [P, d] (and u_l2 on it) matches the oracle's
                # masked operand bit for bit; freeze their local state.
                u_l = jnp.where(live_l[:, None], u_l, 0.0)
                local = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(
                        live_l.reshape((Pl,) + (1,) * (new.ndim - 1)),
                        new, old),
                    local_new, local)
            else:
                local = local_new

            # --- 4. push to owning shards; fold oldest slot ---------------
            # The all-gather over the worker axes is the data plane: under a
            # pod axis it is the eager cross-pod delta channel (one fresh
            # [P, d] update set per clock keeps every pod replica's ring
            # reconciled; visibility stays gated by cview above).
            u_all = jax.lax.all_gather(u_l, worker_axes, axis=0, tiled=True)
            # norm on the gathered [P, d] — the oracle's operand shape, so
            # XLA emits the same reduction and the floats match bit-for-bit
            u_l2 = jnp.linalg.norm(u_all, axis=-1)
            u_all = jnp.pad(u_all, ((0, 0), (0, dpad - d)))
            u_blk = jax.lax.dynamic_slice(u_all, (0, mi * dl), (P, dl))
            slot = jnp.mod(c, W)
            old_valid = uclock[slot] > RING_INVALID
            if wired:
                w_old = jnp.where(old_valid, 1.0, 0.0)
                cst = dict(cst,
                           base_pod=cst["base_pod"]
                           + w_old * comm.fold_pods(uring[slot], G),
                           xbase_pod=cst["xbase_pod"]
                           + w_old * comm.fold_pods(cst["xring"][slot], G))
            else:
                base = base + jnp.where(old_valid, 1.0, 0.0) * jnp.sum(
                    uring[slot], axis=0)
            uring = uring.at[slot].set(u_blk)
            uclock = uclock.at[slot].set(c)
            if wired:
                # --- 4b. comm substrate: accumulate; ship on boundary ----
                # thresholds/scales/counts come from the *gathered* full
                # rows (bit-identical to the oracle's [P, d] sort); the
                # pack itself is elementwise on the local shard.
                acc = cst["acc"] + u_blk
                delta = acc + cst["res"]                     # [P, dl]
                delta_full = jax.lax.all_gather(
                    delta, "model", axis=1, tiled=True)[:, :d]
                thresh = comm.row_threshold(delta_full, cfg.topk_frac)
                scale = comm.quant_scale(delta_full, cfg.quant)
                wire_u, resid = ops.delta_pack(delta, thresh, scale,
                                               cfg.quant)
                nnz = comm.selected_count(delta_full, thresh)
                ship = comm.ship_now(c, cfg.agg_clocks)
                if churned:
                    # dead producers hold their shipment (drain policy:
                    # acc/res keep the mass until the first boundary
                    # after rejoin) — oracle mirror.
                    ship = ship & live_now                 # [P]
                if faulted:
                    # stop-and-wait ARQ: a busy producer (previous
                    # shipment unacked) skips the boundary — acc keeps
                    # accumulating and the skipped content rides the
                    # next shipment (oracle mirror).
                    ship = ship & wire.idle(cst)           # [P]
                ship_b = ship[:, None] if (churned or faulted) else ship
                wire_u = jnp.where(ship_b, wire_u, jnp.zeros_like(wire_u))
                floats = comm.wire_floats(nnz, d, cfg.quant)
                if faulted:
                    # shipments enter the wire ring only when they
                    # *arrive*, via the seq-guarded fold in wire_step
                    # (which also runs retransmits, give-up healing and
                    # instant arrivals, and charges every transmission —
                    # retries included — into ship_floats).
                    cst = dict(cst,
                               acc=jnp.where(ship_b, jnp.zeros_like(acc),
                                             acc),
                               res=jnp.where(ship_b, resid, cst["res"]),
                               xring=cst["xring"].at[slot].set(
                                   jnp.zeros_like(wire_u)))
                    cst, ship_floats = wire.wire_step(
                        cst, wire_u, floats, ship, c, flt,
                        live=live_now if churned else None)
                else:
                    cst = dict(cst,
                               acc=jnp.where(ship_b, jnp.zeros_like(acc),
                                             acc),
                               res=jnp.where(ship_b, resid, cst["res"]),
                               xring=cst["xring"].at[slot].set(wire_u))
                    ship_floats = jnp.where(
                        ship, floats, jnp.zeros((P,), f32))
            else:
                ship_floats = comm.dense_ship_floats(cfg.model, P, d)
                if churned:
                    ship_floats = jnp.where(live_now, ship_floats, 0.0)

            # --- 5. end-of-clock delivery (affects reads at c+1) ----------
            if cfg.model == "bsp":
                delivered = jnp.ones((Pl, P), bool)
                if churned:
                    delivered = delivered & live_l[:, None]
                    cview = jnp.where(live_l[:, None],
                                      jnp.full_like(cview, c), cview)
                else:
                    cview = jnp.full_like(cview, c)
            elif cfg.model == "ssp":
                delivered = jnp.zeros((Pl, P), bool)
            else:  # essp / async / vap: delay-driven eager delivery
                delivered = jax.lax.dynamic_slice_in_dim(
                    delivery_matrix(k_net, cfg, P, rates), rows0, Pl)
                if churned:
                    delivered = delivered & live_l[:, None]
                if wired and faulted:
                    # deliveries carry the latest *arrived* shipment:
                    # boundary target capped by wire_tip (oracle mirror)
                    tgt = jnp.where(in_pod, c,
                                    jnp.minimum(
                                        comm.shipped_end(
                                            c, cfg.agg_clocks),
                                        cst["wire_tip"][None, :]))
                    cview = jnp.where(delivered, jnp.maximum(cview, tgt),
                                      cview)
                elif wired:
                    tgt = jnp.where(in_pod, c,
                                    comm.shipped_end(c, cfg.agg_clocks))
                    cview = jnp.where(delivered, jnp.maximum(cview, tgt),
                                      cview)
                else:
                    cview = jnp.where(delivered, c, cview)

            # --- 6. record (gathered so losses match the oracle exactly) --
            if wired:
                x_ref = (base + jnp.sum(cst["base_pod"], axis=0)) + jnp.sum(
                    uring * (uclock[:, None, None] > RING_INVALID),
                    axis=(0, 1))
            else:
                x_ref = base + jnp.sum(
                    uring * (uclock[:, None, None] > RING_INVALID),
                    axis=(0, 1))
            x_ref = jax.lax.all_gather(x_ref, "model", tiled=True)[:d]
            locals_all = jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, worker_axes, axis=0,
                                             tiled=True),
                local)
            views_all = jax.lax.all_gather(  # analysis: ignore[unmasked-gather] -- record-side gather of reader *views* for trace metrics, not a producer reduction; dead readers' rows are inert (their cview froze) and the oracle gathers identically
                views, worker_axes, axis=0, tiled=True)
            out = dict(loss_ref=app.loss(x_ref, locals_all),
                       loss_view=app.loss(views_all[0], locals_all),
                       staleness=staleness, forced=forced,
                       delivered=delivered,
                       u_l2=u_l2, intransit_inf=intransit_inf,
                       ship_floats=ship_floats,
                       live=live_now if churned
                       else jnp.ones((P,), bool))
            if record_views:
                out["views0"] = views_all[0]
            if obs_enabled:
                # shard-local fold of this clock's step values; shards
                # merge once after the scan (device_reduce), not per clock
                oacc = obsm.device_update(
                    oacc, staleness=staleness, forced=forced,
                    delivered=delivered, ship_floats=ship_floats,
                    live=out["live"],
                    live_rows=live_l if churned
                    else jnp.ones((Pl,), bool),
                    in_pod=in_pod_obs)
            new_carry = ((base, uring, uclock, cview, local, rng, cst)
                         if wired else
                         (base, uring, uclock, cview, local, rng))
            if obs_enabled:
                new_carry = (*new_carry, oacc)
            return new_carry, out

        clocks = clock0 + jnp.arange(n_clocks, dtype=jnp.int32)
        carry0 = ((base, uring, uclock, cview, local, rng, cst)
                  if wired else
                  (base, uring, uclock, cview, local, rng))
        if obs_enabled:
            carry0 = (*carry0, obsm.device_init(P, obs.n_buckets))
        carryT, ys = jax.lax.scan(step, carry0, clocks)
        base, uring, uclock, cview, local, rng = carryT[:6]
        if wired:
            cst = carryT[6]
            x_final = (base + jnp.sum(cst["base_pod"], axis=0)) + jnp.sum(
                uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
        else:
            x_final = base + jnp.sum(
                uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
        state = dict(clock=clock0 + n_clocks, base=base,
                     uring=uring, uclock=uclock, cview=cview,
                     local=local, rng=rng,
                     comm=cst if wired else None)
        ret = {"ys": ys, "x_final": x_final, "state": state}
        if obs_enabled:
            # merge the per-shard accumulators: one psum/pmax per reduced
            # leaf for the whole run (replicated leaves pass through)
            ret["obs"] = obsm.device_reduce(carryT[-1], worker_axes)
        return ret

    local_spec = jax.tree_util.tree_map(lambda _: P_(worker_axes), app.local0)
    ys_specs = {"loss_ref": P_(), "loss_view": P_(),
                "staleness": P_(None, worker_axes, None),
                "forced": P_(None, worker_axes, None),
                "delivered": P_(None, worker_axes, None),
                "u_l2": P_(), "intransit_inf": P_(), "ship_floats": P_(),
                "live": P_()}
    if record_views:
        ys_specs["views0"] = P_()
    comm_specs = None
    if wired:
        comm_specs = dict(acc=P_(None, "model"), res=P_(None, "model"),
                          xring=P_(None, None, "model"),
                          base_pod=P_(None, "model"),
                          xbase_pod=P_(None, "model"))
        if faulted:
            # ARQ leaves: the pending payload shards like acc; the per-
            # producer scalars ([P]) are replicated (every shard runs the
            # same protocol decisions off the replicated fault masks)
            comm_specs.update({
                k: P_(None, "model") if k == "pend" else P_()
                for k in wire.WIRE_KEYS})
    state_specs = dict(clock=P_(), base=P_("model"),
                       uring=P_(None, None, "model"), uclock=P_(),
                       cview=P_(worker_axes, None), local=local_spec,
                       rng=P_(), comm=comm_specs)
    in_specs = [P_(), P_(), P_("model"), P_(None, None, "model"), P_(),
                P_(worker_axes, None), local_spec, P_()]
    if wired:
        in_specs.append(comm_specs)
    if faulted:
        # fault masks are replicated: every shard needs all P producers'
        # fault rows (like the churn schedule)
        in_specs.append(jax.tree_util.tree_map(lambda _: P_(), faults))
    if churned:
        # the schedule is replicated: every shard reads the full per-clock
        # liveness rows (it needs producer liveness for all P)
        in_specs.append(jax.tree_util.tree_map(lambda _: P_(), schedule))
    out_specs = {"ys": ys_specs, "x_final": P_("model"),
                 "state": state_specs}
    if obs_enabled:
        # post-reduce the accumulators are replicated on every shard
        out_specs["obs"] = jax.tree_util.tree_map(
            lambda _: P_(), obsm.device_init(P, obs.n_buckets))
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=out_specs,
        check_rep=False)

    def run(state: PSState, cfg, sched, flt):
        args = (cfg, state.clock, state.base, state.uring,
                state.uclock, state.cview, state.local, state.rng)
        if wired:
            args += (state.comm,)
        if faulted:
            args += (flt,)
        if churned:
            args += (sched,)
        out = sharded(*args)
        ys = out["ys"]
        trace = Trace(loss_ref=ys["loss_ref"], loss_view=ys["loss_view"],
                      staleness=ys["staleness"], forced=ys["forced"],
                      delivered=ys["delivered"], u_l2=ys["u_l2"],
                      intransit_inf=ys["intransit_inf"],
                      ship_floats=ys["ship_floats"], live=ys["live"],
                      views0=ys.get("views0"),
                      x_final=out["x_final"][:d],
                      locals_final=out["state"]["local"],
                      obs=out.get("obs"))
        return trace, PSState(**out["state"])

    jitted = jax.jit(run)

    def init_state(seed) -> PSState:
        """Clock-0 state for ``seed`` (the simulator's initial conditions,
        in the runtime's padded layout)."""
        return PSState(
            clock=jnp.zeros((), jnp.int32),
            base=jnp.pad(app.x0.astype(f32), (0, dpad - d)),
            uring=jnp.zeros((W, P, dpad), f32),
            uclock=jnp.full((W,), RING_EMPTY, jnp.int32),
            cview=jnp.full((P, P), -1, jnp.int32),
            local=app.local0,
            rng=jax.random.PRNGKey(seed),
            comm=({**comm.init_state(W, P, dpad, G),
                   **wire.init_wire_state(P, dpad)} if faulted
                  else comm.init_state(W, P, dpad, G)) if wired else None)

    def _norm_cfg(cfg_run: ConsistencyConfig | None) -> ConsistencyConfig:
        c = cfg if cfg_run is None else cfg_run
        if c.effective_window != W:
            raise ValueError(
                f"runtime compiled for ring window {W}, got "
                f"{c.effective_window}; set cfg.window explicitly or build "
                f"a new run fn")
        if c.comm_active != wired or (wired and c.quant != quant0):
            raise ValueError(
                f"runtime compiled with comm_active={wired} "
                f"(quant={quant0!r}); got comm_active={c.comm_active} "
                f"(quant={c.quant!r}) — build a new run fn for a "
                f"different comm structure")
        # normalize the static window/wire flag so every same-family call
        # shares one pytree treedef (and therefore one jit cache entry)
        return c.replace(window=W, wire=wired)

    def _norm_sched(sched):
        s = schedule if sched is None else sched
        if (s is not None) != churned:
            raise ValueError(
                f"runtime compiled with churn={'on' if churned else 'off'}; "
                f"build a new run fn to change the churn structure")
        if s is not None and s.live.shape[1] != P:
            raise ValueError(f"schedule has {s.live.shape[1]} workers, "
                             f"app has {P}")
        return s

    def _norm_faults(flt):
        f = faults if flt is None else flt
        if (f is not None) != faulted:
            raise ValueError(
                f"runtime compiled with faults="
                f"{'on' if faulted else 'off'}; build a new run fn to "
                f"change the fault structure")
        if f is not None and wire.faults_key(f) != wire.faults_key(faults):
            raise ValueError(
                f"runtime compiled with ARQ knobs "
                f"{wire.faults_key(faults)}, got {wire.faults_key(f)}; "
                f"the knobs are static — build a new run fn")
        return f

    def run_from(state: PSState, cfg_run: ConsistencyConfig | None = None,
                 schedule: ChurnSchedule | None = None,
                 faults: wire.WireFaults | None = None):
        """Advance ``state`` by ``n_clocks``; returns ``(Trace, PSState)``.
        Bit-identical to running the clocks uninterrupted."""
        return jitted(state, _norm_cfg(cfg_run), _norm_sched(schedule),
                      _norm_faults(faults))

    def fn(seed, cfg_run: ConsistencyConfig | None = None,
           schedule: ChurnSchedule | None = None,
           faults: wire.WireFaults | None = None) -> Trace:
        return jitted(init_state(seed), _norm_cfg(cfg_run),
                      _norm_sched(schedule), _norm_faults(faults))[0]

    fn.init_state = init_state
    fn.run_from = run_from
    return fn


def _churn_key(schedule: ChurnSchedule | None):
    """The churn *structure* a compiled program is specialized on: presence,
    which optional arrays the schedule carries, and the in-flight policy.
    Array shapes/values stay jit-traced (jit retraces on new shapes)."""
    if schedule is None:
        return None
    return (schedule.drop_inflight,
            schedule.straggler_workers is not None,
            schedule.bw_scale is not None)


class PSRuntime:
    """Executable sharded PS: ``PSRuntime(mesh).run(app, cfg, n_clocks)``.

    Produces the same `core.ps.Trace` schema as ``core.ps.simulate`` (the
    *Trace-producer contract*: identical fields, leading clock axis, same
    RNG stream), executed over the mesh instead of vectorized on one
    device.  Compiled programs are cached per (app, config family, ring
    window, n_clocks, churn structure) — numeric knob changes (and
    same-structure churn schedules) re-use them.

    ``init_state`` / ``run_from`` expose the mid-run `PSState` for
    checkpointing: ``run_from`` resumed from a saved state reproduces the
    uninterrupted trace bit for bit — with or without a churn schedule
    (schedules index by absolute clock, so segments line up exactly; see
    `pods.elastic` for the pod-rejoin recipe built on this).
    """

    worker_axes: tuple = ("data",)

    def __init__(self, mesh=None):
        self.mesh = self._default_mesh() if mesh is None else mesh
        self._cache: dict = {}

    def _default_mesh(self):
        return make_ps_mesh()

    def run_fn(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
               record_views: bool = False,
               schedule: ChurnSchedule | None = None,
               obs: obsm.ObsSpec | None = None,
               faults: wire.WireFaults | None = None):
        """The cached jitted ``fn(seed, cfg) -> Trace`` for this family."""
        obs = obs if obsm.obs_on(obs) else None   # one cache entry for off
        key = (id(app), cfg.family, cfg.effective_window, n_clocks,
               record_views, _churn_key(schedule), obs,
               wire.faults_key(faults))
        fn = self._cache.get(key)
        if fn is None:
            fn = make_run_fn(app, cfg, n_clocks, mesh=self.mesh,
                             record_views=record_views,
                             worker_axes=self.worker_axes,
                             schedule=schedule, obs=obs, faults=faults)
            self._cache[key] = fn
        return fn

    def run(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
            seed=0, record_views: bool = False,
            schedule: ChurnSchedule | None = None,
            obs: obsm.ObsSpec | None = None,
            faults: wire.WireFaults | None = None) -> Trace:
        """Run ``n_clocks`` of the app under ``cfg`` on the mesh."""
        return self.run_fn(app, cfg, n_clocks, record_views,
                           schedule, obs, faults)(seed, cfg, schedule,
                                                  faults)

    def init_state(self, app: PSApp, cfg: ConsistencyConfig, seed=0,
                   n_clocks: int = 1,
                   faults: wire.WireFaults | None = None) -> PSState:
        """Clock-0 `PSState` (``n_clocks`` only selects the compiled fn)."""
        return self.run_fn(app, cfg, n_clocks,
                           faults=faults).init_state(seed)

    def run_from(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                 state: PSState, record_views: bool = False,
                 schedule: ChurnSchedule | None = None,
                 obs: obsm.ObsSpec | None = None,
                 faults: wire.WireFaults | None = None):
        """Advance ``state`` by ``n_clocks`` -> ``(Trace, PSState)``."""
        return self.run_fn(app, cfg, n_clocks, record_views,
                           schedule, obs, faults).run_from(
                               state, cfg, schedule, faults)
