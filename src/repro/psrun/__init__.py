"""psrun — an *executable* sharded parameter server on the device mesh.

Where ``core.ps.simulate`` reproduces SSPTable/ESSPTable semantics inside a
single vectorized ``lax.scan`` (one device, global knowledge), this package
*runs* them: parameter shards live on the ``"model"`` mesh axis, the ``P``
workers are partitioned over the ``"data"`` axis, and every clock executes
as a ``shard_map`` step in which workers materialize views against their
device-resident caches, compute updates locally, push them to the owning
shard, and advance their per-channel ``cview`` clocks lazily (SSP) or
eagerly on push (ESSP) under the bounded-staleness gate.

The simulator is the *oracle*: both produce the same ``core.ps.Trace``
schema, a seeded BSP run is bit-identical between the two (the network
model is deterministic there, so every float must match), and SSP/ESSP/VAP
runs must satisfy the staleness / value-bound invariants checked by
``core.theory`` / ``core.valuebound``.  See ``psrun.validate`` for the
cross-validation entry points and ``tests/test_psrun.py`` for the contract.
"""
from .runtime import PSRuntime, PSState, default_mesh, make_run_fn
from .validate import cross_validate, trace_max_diff, trace_max_ulp

__all__ = ["PSRuntime", "PSState", "default_mesh", "make_run_fn",
           "cross_validate", "trace_max_diff", "trace_max_ulp"]
