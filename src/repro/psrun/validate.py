"""Cross-validation of the executable runtime against the simulator oracle.

Three levels of contract, matched to what each consistency model promises:

- **bsp** — the network model is deterministic (full barrier), so a seeded
  run must be *bit-identical* to ``core.ps.simulate``: every `Trace` field,
  every float.  (With the shared synthetic delay model this actually holds
  for every model — the runtime replays the simulator's RNG stream — but
  only BSP's equality is part of the contract; the rest is gravy that the
  tests pin down opportunistically.)
- **ssp / essp** — the bounded-staleness invariant: at read time every
  channel satisfies ``-(s+1) <= cview[r,q] - c <= -1``.
- **vap** — the value-bound condition of paper eq. 1, via
  ``core.valuebound.check_condition``.

Bit-identity caveats (both are fusion artifacts, not semantic drift, and
both are pinned by ``tests/test_psrun.py``): it holds whenever each data
shard carries >1 worker (a batch-of-1 vmapped worker step can compile to
different fused arithmetic than the oracle's batch-of-P — 1 ulp), and VAP's
enforcement ops likewise perturb XLA's fusion of the ring-view contraction
(traces agree to ~1e-6, decisions — staleness/forced/delivered — exactly).
"""
from __future__ import annotations

import numpy as np

from ..core import valuebound
from ..core.consistency import ConsistencyConfig
from ..core.ps import PSApp, Trace, simulate
from .runtime import PSRuntime

TRACE_FIELDS = ("loss_ref", "loss_view", "staleness", "forced", "delivered",
                "u_l2", "intransit_inf", "x_final")


def trace_max_diff(got: Trace, want: Trace) -> dict:
    """Max absolute difference per `Trace` field (0.0 everywhere == exact)."""
    out = {}
    for name in TRACE_FIELDS:
        a = np.asarray(getattr(got, name)).astype(np.float64)
        b = np.asarray(getattr(want, name)).astype(np.float64)
        out[name] = float(np.abs(a - b).max()) if a.size else 0.0
    return out


def check_staleness_bound(trace: Trace, cfg: ConsistencyConfig) -> dict:
    """SSP/ESSP invariant: every read is at most ``s+1`` clocks stale and
    never fresher than the barrier (``-1``)."""
    st = np.asarray(trace.staleness)
    s = int(cfg.staleness)
    viol_old = int((st < -(s + 1)).sum())
    viol_fresh = int((st > -1).sum())
    return {"violations": viol_old + viol_fresh,
            "min": int(st.min()), "max": int(st.max()), "bound": -(s + 1)}


def cross_validate(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                   runtime: PSRuntime | None = None, seed=0) -> dict:
    """Run both engines and check the model-appropriate oracle contract.

    Returns a dict with ``ok`` plus the per-model evidence.  BSP compares
    bit-for-bit against ``simulate``; SSP/ESSP check the staleness bound;
    VAP checks the value bound.
    """
    runtime = runtime or PSRuntime()
    tr = runtime.run(app, cfg, n_clocks, seed=seed)
    out: dict = {"model": cfg.model}
    if cfg.model == "bsp":
        import jax
        want = jax.jit(lambda sd: simulate(app, cfg, n_clocks, seed=sd))(
            np.uint32(seed))
        diffs = trace_max_diff(tr, want)
        out["max_diff"] = diffs
        out["ok"] = all(v == 0.0 for v in diffs.values())
    elif cfg.model in ("ssp", "essp"):
        chk = check_staleness_bound(tr, cfg)
        out.update(chk)
        out["ok"] = chk["violations"] == 0
    elif cfg.model == "vap":
        chk = valuebound.check_condition(tr, float(cfg.v0))
        out.update(chk)
        out["ok"] = chk["violations"] == 0
    else:  # async has no bound to check; just require finite traces
        out["ok"] = bool(np.isfinite(np.asarray(tr.loss_ref)).all())
    return out
