"""Cross-validation of the executable runtime against the simulator oracle.

Levels of contract, matched to what each consistency model promises:

- **bsp** — the network model is deterministic (full barrier), so a seeded
  run must be *bit-identical* to ``core.ps.simulate``: every `Trace` field,
  every float.
- **ssp / essp** — *also bit-asserted* (promoted from "holds in practice"
  in PR 4): the runtime replays the simulator's RNG stream through the
  shared synthetic delay model, so every float must match, and the
  bounded-staleness invariant must hold — at read time every channel
  satisfies ``-(s_eff+1) <= cview[r,q] - c <= -1`` where ``s_eff`` is the
  per-channel (two-tier, when ``cfg.n_pods > 1``) bound.
- **vap** — the value-bound condition of paper eq. 1, via
  ``core.valuebound.check_condition``, with integer decisions
  (staleness/forced/delivered) exactly equal to the oracle and floats
  within a strict ulp budget (``trace_max_ulp``).

Bit-identity caveats (pinned by ``tests/test_psrun.py`` /
``tests/test_sweep.py``): it holds whenever each worker shard carries >1
worker (a batch-of-1 vmapped worker step can compile to different fused
arithmetic than the oracle's batch-of-P — 1 ulp; the mesh factories keep
the >1 regime).  VAP floats can drift a few ulp/value under *multi-device*
compilation: XLA's backend instruction-selects the scan body differently
when the enforcement graph is present (measured: a replay of the worker
update on bit-identical recorded inputs reproduces the plain-jit value,
and optimization barriers around every stage leave the drift
byte-identical — backend codegen, not semantic divergence; MF/LDA are
exactly stable, and decisions are always exact).
"""
from __future__ import annotations

import numpy as np

from ..core import valuebound
from ..core.consistency import ConsistencyConfig
from ..core.delays import staleness_bound_matrix
from ..core.ps import PSApp, Trace, simulate
from .runtime import PSRuntime

TRACE_FIELDS = ("loss_ref", "loss_view", "staleness", "forced", "delivered",
                "u_l2", "intransit_inf", "ship_floats", "live", "x_final")

# Float drift budget for VAP under multi-device compilation (see module
# doc), asserted in ulp units so it stays scale-free.  Measured drift on
# the contract tests compounds ~ulp/clock: <= 14 ulp over 40 flat clocks
# (P=4), <= 64 over 20 hierarchical clocks (P=8).  128 gives slack without
# ever admitting a semantic bug — the old rtol=1e-5/atol<1e-4 pins admitted
# thousands of ulp on the same traces (MF/LDA need none of this: they are
# bit-exact, asserted separately).
VAP_ULP_BUDGET = 128.0


def trace_max_diff(got: Trace, want: Trace) -> dict:
    """Max absolute difference per `Trace` field (0.0 everywhere == exact)."""
    out = {}
    for name in TRACE_FIELDS:
        a = np.asarray(getattr(got, name)).astype(np.float64)
        b = np.asarray(getattr(want, name)).astype(np.float64)
        out[name] = float(np.abs(a - b).max()) if a.size else 0.0
    return out


def trace_max_ulp(got: Trace, want: Trace) -> dict:
    """Max drift per field, in float32 ulp *of the field's scale*.

    The scale-free version of :func:`trace_max_diff`: ``max|a-b| /
    spacing(max|want|)`` per field, so "a few ulp" means the same thing
    for a loss of 1e-3 and a loss of 1e3.  Measured against the field's
    largest magnitude (not elementwise) because the drift is absolute
    round-off accumulated while values were large — elementwise ulp would
    diverge spuriously as a converging field approaches zero.
    """
    out = {}
    for name in TRACE_FIELDS:
        a = np.asarray(getattr(got, name)).astype(np.float64)
        b = np.asarray(getattr(want, name)).astype(np.float64)
        if not a.size:
            out[name] = 0.0
            continue
        scale = np.float32(max(np.abs(b).max(), np.abs(a).max(), 1e-30))
        out[name] = float(np.abs(a - b).max() / np.spacing(scale))
    return out


def check_staleness_bound(trace: Trace, cfg: ConsistencyConfig,
                          retry_budget: int = 0) -> dict:
    """SSP/ESSP invariant: every read is at most ``s_eff+1`` clocks stale
    and never fresher than the barrier (``-1``).

    ``s_eff`` is per-channel: ``staleness`` intra-pod, ``staleness +
    s_xpod`` across pods (`core.delays.staleness_bound_matrix`) — the
    two-tier contract collapses to the flat one at ``n_pods=1``.
    ``retry_budget`` widens the cross-pod tier for lossy-wire runs whose
    fault trace is *conforming* (`comm.wire.WireFaults.retry_budget`);
    non-conforming traces (a shipment gave up) can exceed any finite
    bound and should not be asserted here.

    Under churn the contract is re-derived over the *live* set: a dead
    worker runs no read, so its frozen rows are excluded via
    ``Trace.live``, and the bound is asserted for every read a live
    worker actually performs — including the rejoin read, which the
    enforcement step repairs with a forced burst before the worker
    computes.  ``live_frac`` reports how much of the matrix the check
    covered (1.0 without churn).
    """
    st = np.asarray(trace.staleness)
    P = st.shape[-1]
    readers = np.arange(st.shape[-2])  # Pl reader rows (= P in the oracle)
    s_eff = np.asarray(staleness_bound_matrix(cfg, readers, P,
                                              retry_budget=retry_budget))
    live = np.asarray(trace.live) if trace.live is not None else None
    if live is not None and live.shape[-1] == st.shape[-2]:
        live_r = live[:, :, None]                   # mask dead reader rows
    else:  # hand-made traces without the field: check everything
        live_r = np.ones_like(st, dtype=bool)
    viol_old = int(((st < -(s_eff + 1)) & live_r).sum())
    viol_fresh = int(((st > -1) & live_r).sum())
    st_live = st[np.broadcast_to(live_r, st.shape)]
    return {"violations": viol_old + viol_fresh,
            "min": int(st_live.min()), "max": int(st_live.max()),
            "bound": -(int(np.max(s_eff)) + 1),
            "live_frac": float(np.broadcast_to(live_r, st.shape).mean())}


def cross_validate(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                   runtime: PSRuntime | None = None, seed=0,
                   return_trace: bool = False, schedule=None,
                   faults=None) -> dict:
    """Run both engines and check the model-appropriate oracle contract.

    Returns a dict with ``ok`` plus the per-model evidence.  BSP/SSP/ESSP
    compare bit-for-bit against ``simulate`` (SSP/ESSP additionally check
    the (two-tier) staleness bound); VAP checks the value bound, exact
    decisions, and the ulp drift budget.  ``return_trace=True`` adds the
    runtime's `Trace` under ``"trace"`` so callers layering further checks
    (``pods.validate``) don't re-execute the run.  ``schedule`` (a
    `core.delays.ChurnSchedule`) runs *both* engines under the same fleet
    churn — the bit-identity contract covers the survivor set too.
    ``faults`` (a `comm.wire.WireFaults`) runs both engines over the same
    lossy wire; bit-identity is still asserted, but the staleness bound is
    *not* (an arbitrary fault mask may be non-conforming — give-ups void
    any finite bound; `tests/test_wire.py` asserts the widened bound on
    conforming schedules separately).
    """
    runtime = runtime or PSRuntime()
    tr = runtime.run(app, cfg, n_clocks, seed=seed, schedule=schedule,
                     faults=faults)
    out: dict = {"model": cfg.model}

    def _oracle():
        import jax
        return jax.jit(
            lambda sd: simulate(app, cfg, n_clocks, seed=sd,
                                schedule=schedule,
                                faults=faults))(np.uint32(seed))

    if cfg.model in ("bsp", "ssp", "essp"):
        want = _oracle()
        diffs = trace_max_diff(tr, want)
        out["max_diff"] = diffs
        out["ok"] = all(v == 0.0 for v in diffs.values())
        if cfg.model in ("ssp", "essp") and faults is None:
            chk = check_staleness_bound(tr, cfg)
            out.update(chk)
            out["ok"] = out["ok"] and chk["violations"] == 0
    elif cfg.model == "vap":
        chk = valuebound.check_condition(tr, float(cfg.v0))
        out.update(chk)
        want = _oracle()
        decisions_ok = all(
            np.array_equal(np.asarray(getattr(tr, name)),
                           np.asarray(getattr(want, name)))
            for name in ("staleness", "forced", "delivered"))
        ulps = trace_max_ulp(tr, want)
        out["decisions_exact"] = decisions_ok
        out["max_ulp"] = ulps
        out["ok"] = (chk["violations"] == 0 and decisions_ok
                     and max(ulps.values()) <= VAP_ULP_BUDGET)
    else:  # async has no bound to check; just require finite traces
        out["ok"] = bool(np.isfinite(np.asarray(tr.loss_ref)).all())
    if return_trace:
        out["trace"] = tr
    return out
