"""pods — the hierarchical multi-pod parameter server, run for real.

`repro.psrun` runs the PS on a flat ``("data","model")`` mesh: one network
tier, one copy of the parameter shards.  This package lifts it one level:
on a 3-D ``("pod","data","model")`` mesh (`launch.mesh.make_pods_mesh`, or
`make_production_mesh(multi_pod=True)` at v5e scale) each pod holds a
**full replica** of the parameter shards serving its own workers at
intra-pod latency, and a *cross-pod reconciliation channel* keeps the
replicas within a second, configurable staleness bound:

- **eager** for ESSP/async/VAP — fresh update deltas cross the pod
  boundary every clock (the per-clock all-gather over the worker axes is
  the data plane; the two-tier delivery model of `core.delays` gates when
  a reader may *see* them at ``t_net_xpod`` latency);
- **clock-gated** for BSP/SSP — BSP's barrier drains both tiers; SSP pulls
  a cross-pod channel only when its ``s + s_xpod`` bound trips.

The bounded-async invariant (Wei et al., arXiv:1312.7869): per-channel
staleness never exceeds ``s_intra + s_xpod``, and replica divergence — how
far two pods' visible prefixes of one producer drift apart — obeys the
same bound (`pods.reconcile`).

``core.ps.simulate`` with ``cfg.n_pods > 1`` is the executable *oracle*
for all of it (the hierarchical mode of the Trace-producer contract):
seeded BSP/SSP/ESSP runs are bit-identical between `PodsRuntime` and the
simulator, VAP agrees to a strict ulp budget with exactly-equal decisions
— `pods.validate.cross_validate_pods`, enforced by ``tests/test_pods.py``
under the CI 16-device lane.
"""
from .elastic import concat_traces, run_with_pod_rejoin, splice_rejoin_state
from .reconcile import (reconcile_stats, replica_clock, replica_divergence,
                        replica_value_divergence, xpod_channel_mask)
from .runtime import PodsRuntime, default_pods_mesh
from .validate import cross_validate_pods

__all__ = ["PodsRuntime", "default_pods_mesh", "cross_validate_pods",
           "replica_clock", "replica_divergence",
           "replica_value_divergence", "reconcile_stats",
           "xpod_channel_mask",
           "run_with_pod_rejoin", "splice_rejoin_state", "concat_traces"]
