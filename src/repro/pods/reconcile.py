"""Replica-level analysis of the cross-pod reconciliation channel.

In the hierarchical PS each pod's *replica state* with respect to producer
``q`` is the prefix of ``q``'s updates its readers may see.  We summarize
it by the replica clock

    rep[g, q] = min_{r in pod g} cview[r, q]

(the weakest reader defines what the replica guarantees), and measure the
reconciliation channel by two quantities derived from any `Trace`:

- **replica divergence** ``max_g rep[g, q] - min_g rep[g, q]`` — how far
  two pods' visible prefixes of one producer drift apart.  Under the
  two-tier SSP/ESSP bound every reader satisfies ``c - s_eff - 1 <=
  cview[r, q] <= c - 1`` with ``s_eff <= s + s_xpod``, so divergence is
  bounded by ``s_intra + s_xpod`` — the reconciliation invariant
  (`tests/test_pods.py` holds it as a hypothesis property);
- **reconciliation traffic** — cross-pod deliveries are *delta* shipments
  (one producer-clock of updates per delivery, ``d`` floats), cross-pod
  forced fetches are clock-gated pulls of up to the whole in-transit
  suffix.  `reconcile_stats` counts both and reports the delta-compression
  ratio against the naive alternative of shipping a full replica
  (``W x P x d``) per reconciliation.
"""
from __future__ import annotations

import numpy as np

from ..core.consistency import ConsistencyConfig
from ..core.delays import pod_of, same_pod_mask
from ..core.ps import Trace


def xpod_channel_mask(cfg: ConsistencyConfig, P: int) -> np.ndarray:
    """[reader, producer] bool: True where the channel crosses pods."""
    return ~np.asarray(same_pod_mask(P, cfg.n_pods))


def replica_clock(trace: Trace, cfg: ConsistencyConfig) -> np.ndarray:
    """Per-clock replica clocks ``rep[t, g, q]`` relative to the barrier.

    Derived from ``Trace.staleness = cview - c``: ``rep[t, g, q]`` is the
    staleness of pod ``g``'s weakest reader of producer ``q`` (so ``-1``
    means "replica g has everything through the barrier from q").
    """
    st = np.asarray(trace.staleness)                    # [T, P, P]
    P = st.shape[-1]
    pods = np.asarray(pod_of(P, cfg.n_pods))
    G = cfg.n_pods
    return np.stack([st[:, pods == g, :].min(axis=1) for g in range(G)],
                    axis=1)                             # [T, G, P]


def replica_divergence(trace: Trace, cfg: ConsistencyConfig) -> dict:
    """Max drift between pods' visible prefixes, against the two-tier bound.

    Returns ``{max, bound, ok, per_clock}``; ``bound`` is ``s_intra +
    s_xpod`` and applies to the bounded models (SSP/ESSP; BSP is 0-bounded
    by the barrier).  For async/VAP there is no clock bound — callers get
    the measured divergence with ``ok=None``.
    """
    rep = replica_clock(trace, cfg)                     # [T, G, P]
    div = rep.max(axis=1) - rep.min(axis=1)             # [T, P]
    out = {"max": int(div.max()) if div.size else 0,
           "per_clock": div.max(axis=-1)}
    if cfg.model == "bsp":
        out["bound"] = 0
    elif cfg.model in ("ssp", "essp"):
        out["bound"] = int(cfg.staleness) + int(cfg.s_xpod)
    else:
        out["bound"] = None
    out["ok"] = None if out["bound"] is None else out["max"] <= out["bound"]
    return out


def reconcile_stats(trace: Trace, cfg: ConsistencyConfig,
                    dim: int | None = None) -> dict:
    """Cross-pod reconciliation traffic of one run.

    Counts eager delta deliveries and clock-gated forced pulls on cross-pod
    channels, and — when ``dim`` (the app's parameter dimension) is given —
    the delta-compression ratio: floats actually shipped per reconciled
    channel-clock (one ``d`` delta) vs a full-replica transfer
    (``W x P x d``) per reconciliation event.
    """
    delivered = np.asarray(trace.delivered)             # [T, P, P]
    forced = np.asarray(trace.forced)
    P = delivered.shape[-1]
    x = xpod_channel_mask(cfg, P)
    n_clocks = delivered.shape[0]
    eager = int(delivered[:, x].sum())
    gated = int(forced[:, x].sum())
    out = {"xpod_channels": int(x.sum()),
           "n_clocks": n_clocks,
           "eager_deliveries": eager,
           "gated_pulls": gated,
           "eager_per_clock": eager / max(n_clocks, 1),
           "gated_per_clock": gated / max(n_clocks, 1)}
    if dim is not None:
        W = cfg.effective_window
        events = eager + gated
        delta_floats = events * dim
        replica_floats = events * W * P * dim
        out["delta_floats"] = delta_floats
        out["delta_compression"] = (replica_floats / delta_floats
                                    if delta_floats else None)
    return out
