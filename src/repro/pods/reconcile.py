"""Replica-level analysis of the cross-pod reconciliation channel.

In the hierarchical PS each pod's *replica state* with respect to producer
``q`` is the prefix of ``q``'s updates its readers may see.  We summarize
it by the replica clock

    rep[g, q] = min_{r in pod g} cview[r, q]

(the weakest reader defines what the replica guarantees), and measure the
reconciliation channel by two quantities derived from any `Trace`:

- **replica divergence** ``max_g rep[g, q] - min_g rep[g, q]`` — how far
  two pods' visible prefixes of one producer drift apart.  Under the
  two-tier SSP/ESSP bound every reader satisfies ``c - s_eff - 1 <=
  cview[r, q] <= c - 1`` with ``s_eff <= s + s_xpod``, so divergence is
  bounded by ``s_intra + s_xpod`` — the reconciliation invariant
  (`tests/test_pods.py` holds it as a hypothesis property);
- **reconciliation traffic** — `reconcile_stats` counts eager deliveries
  and clock-gated pulls, and reports floats-on-wire two ways: the
  *dense-equivalent* accounting of PR 4 (one ``d``-float delta per
  delivery/pull event, vs a full ``W x P x d`` replica transfer —
  ``dense_equiv_compression``) and the *true bits-weighted* accounting
  under the comm substrate (``Trace.ship_floats``: what each shipment
  actually put on the wire after k-clock aggregation, top-k sparsity, and
  value quantization — ``wire_floats`` / ``wire_compression``);
- **replica value divergence** (`replica_value_divergence`) — for the
  *unbounded-clock* models (async/VAP) the clock bound above is ``None``,
  but the trace still supports a checked **value**-bound analogue: two
  pods' visible prefixes of one producer differ by a sub-range of some
  reader's in-transit aggregate, so the divergence envelope ``2 x
  max-in-transit-inf-norm`` is bounded by ``2 v_t`` whenever VAP's
  condition (paper eq. 1) holds.  ``pods.validate`` checks it per clock.
"""
from __future__ import annotations

import numpy as np

from ..core.consistency import ConsistencyConfig
from ..core.delays import pod_of, same_pod_mask
from ..core.ps import Trace
from ..core.valuebound import v_schedule


def xpod_channel_mask(cfg: ConsistencyConfig, P: int) -> np.ndarray:
    """[reader, producer] bool: True where the channel crosses pods."""
    return ~np.asarray(same_pod_mask(P, cfg.n_pods))


REPLICA_DEAD = np.iinfo(np.int64).max
"""Sentinel `replica_clock` value for a pod with no live reader at a clock
(its frozen rows say nothing about the replica's guarantees)."""


def replica_clock(trace: Trace, cfg: ConsistencyConfig) -> np.ndarray:
    """Per-clock replica clocks ``rep[t, g, q]`` relative to the barrier.

    Derived from ``Trace.staleness = cview - c``: ``rep[t, g, q]`` is the
    staleness of pod ``g``'s weakest *live* reader of producer ``q`` (so
    ``-1`` means "replica g has everything through the barrier from q").
    Dead readers (``Trace.live``) are excluded — their rows are frozen at
    death and describe no read; a pod with no live reader at a clock gets
    the `REPLICA_DEAD` sentinel.  Without churn every reader is live and
    this is exactly the historical min.
    """
    st = np.asarray(trace.staleness).astype(np.int64)   # [T, P, P]
    P = st.shape[-1]
    pods = np.asarray(pod_of(P, cfg.n_pods))
    G = cfg.n_pods
    live = (np.asarray(trace.live) if trace.live is not None
            else np.ones(st.shape[:2], bool))           # [T, P(r)]
    stm = np.where(live[:, :, None], st, REPLICA_DEAD)
    return np.stack([stm[:, pods == g, :].min(axis=1) for g in range(G)],
                    axis=1)                             # [T, G, P]


def replica_divergence(trace: Trace, cfg: ConsistencyConfig) -> dict:
    """Max drift between pods' visible prefixes, against the two-tier bound.

    Returns ``{max, bound, ok, per_clock}``; ``bound`` is ``s_intra +
    s_xpod`` and applies to the bounded models (SSP/ESSP; BSP is 0-bounded
    by the barrier).  For async/VAP there is no clock bound — callers get
    the measured divergence with ``ok=None``.
    """
    rep = replica_clock(trace, cfg)                     # [T, G, P]
    valid = rep != REPLICA_DEAD                         # pod had live readers
    # divergence only where >= 2 pods have live readers: a dead pod's
    # frozen prefix is not a replica anyone reads from
    rmax = np.where(valid, rep, np.iinfo(np.int64).min).max(axis=1)
    rmin = np.where(valid, rep, REPLICA_DEAD).min(axis=1)
    div = np.where(valid.sum(axis=1) >= 2, rmax - rmin, 0)   # [T, P]
    out = {"max": int(div.max()) if div.size else 0,
           "per_clock": div.max(axis=-1)}
    if cfg.model == "bsp":
        out["bound"] = 0
    elif cfg.model in ("ssp", "essp"):
        out["bound"] = int(cfg.staleness) + int(cfg.s_xpod)
        if cfg.comm_active:
            # k-clock aggregation holds shipped content back up to
            # agg_clocks - 1 extra clocks (the widened contract)
            out["bound"] += int(cfg.agg_clocks) - 1
    else:
        out["bound"] = None
    out["ok"] = None if out["bound"] is None else out["max"] <= out["bound"]
    return out


def reconcile_stats(trace: Trace, cfg: ConsistencyConfig,
                    dim: int | None = None) -> dict:
    """Cross-pod reconciliation traffic of one run.

    Counts eager delta deliveries and clock-gated forced pulls on cross-pod
    channels, and — when ``dim`` (the app's parameter dimension) is given —
    two floats-on-wire accountings:

    - **dense-equivalent** (PR 4's): one dense ``d``-float delta per
      delivery/pull event (``delta_floats``), against a full-replica
      transfer ``W x P x d`` per event (``dense_equiv_compression``);
    - **true bits-weighted** (the comm substrate's): per cross-pod
      channel, the sum of ``Trace.ship_floats`` over every shipment that
      became visible to that channel — whether a background delivery or a
      forced pull carried it, the content crosses once — giving
      ``wire_floats`` (dense pull-based SSP, which ships nothing, counts
      one ``d``-float delta per gated pull instead).  ``wire_compression``
      is the dense accounting of the *same visibility trajectory* divided
      by it: >1 means aggregation/sparsity/quantization genuinely cut the
      bytes a dense-eager run would have moved to reach the same replica
      state.
    """
    delivered = np.asarray(trace.delivered)             # [T, P, P]
    forced = np.asarray(trace.forced)
    st = np.asarray(trace.staleness)
    T, _, P = delivered.shape
    x = xpod_channel_mask(cfg, P)
    eager = int(delivered[:, x].sum())
    gated = int(forced[:, x].sum())
    out = {"xpod_channels": int(x.sum()),
           "n_clocks": T,
           "eager_deliveries": eager,
           "gated_pulls": gated,
           "eager_per_clock": eager / max(T, 1),
           "gated_per_clock": gated / max(T, 1)}
    if dim is not None:
        W = cfg.effective_window
        events = eager + gated
        delta_floats = events * dim
        replica_floats = events * W * P * dim
        out["delta_floats"] = delta_floats
        out["dense_equiv_compression"] = (replica_floats / delta_floats
                                          if delta_floats else None)
        if x.any():
            # True floats-on-wire: each shipment of producer q crosses a
            # cross-pod channel (r, q) exactly once, when it becomes
            # visible there (whether a background delivery or a forced
            # pull carried it); the channel's final visible prefix (from
            # the last recorded read) tells which shipments those were.
            ship = np.asarray(trace.ship_floats)        # [T, P]
            cum = np.concatenate([np.zeros((1, P), ship.dtype),
                                  np.cumsum(ship, axis=0)])  # [T+1, P]
            v_final = st[-1] + (T - 1)                  # [P, P] visible clk
            vis = np.clip(v_final + 1, 0, T)            # shipments seen
            per_chan = cum[vis, np.arange(P)[None, :]]  # [P(r), P(q)]
            if cfg.model == "ssp" and not cfg.comm_active:
                # dense pull-based: nothing ships; each clock-gated pull
                # moves one delta-compressed d-float suffix (PR 4's story)
                wire = dense = float(gated * dim)
            else:
                wire = float(per_chan[x].sum())
                # the dense-eager counterfactual of the same visibility
                # trajectory: every visible clock carried a d-float delta
                dense = float(vis[x].sum() * dim)
            out["wire_floats"] = wire
            out["dense_floats"] = dense
            out["wire_compression"] = dense / wire if wire else None
    return out


def replica_value_divergence(trace: Trace, cfg: ConsistencyConfig) -> dict:
    """Checked *value*-bound analogue of `replica_divergence` for the
    unbounded-clock models (async/VAP) — ROADMAP follow-up (b).

    Two pods' visible prefixes of producer ``q`` differ by the updates in
    the clock range ``(rep_min, rep_max]``; that range is the difference
    of two in-transit suffixes of the weakest reader, so its aggregate
    inf-norm is at most twice the largest in-transit aggregate
    (triangle inequality on suffix differences).  The trace records that
    maximum per clock (``intransit_inf``), giving a measured divergence
    *envelope* ``2 x intransit_inf``; under VAP the enforcement bounds
    every in-transit aggregate by ``v_t = v0/sqrt(t+1)`` (paper eq. 1),
    so the envelope is checked against ``2 v_t``.  For async there is no
    bound — callers get the measured envelope with ``ok=None`` (the same
    contract shape as the clock-bound dict).
    """
    envelope = 2.0 * np.asarray(trace.intransit_inf)    # [T]
    out = {"max_envelope": float(envelope.max()) if envelope.size else 0.0,
           "per_clock": envelope}
    if cfg.model == "vap":
        sched = v_schedule(float(cfg.v0))
        # reads at clock c check in-transit accumulated through c-1, so
        # envelope[t] compares against the enforcement bound at t-1 (the
        # same offset core.valuebound.check_condition uses).
        vt = np.array([2.0 * sched(t) for t in range(len(envelope))])
        viol = envelope[1:] > vt[:-1] + 1e-6
        out["bound_final"] = float(vt[-1]) if len(vt) else None
        out["violations"] = int(viol.sum())
        out["ok"] = bool(viol.sum() == 0)
    else:
        out["bound_final"] = None
        out["violations"] = None
        out["ok"] = None
    return out
