"""Elastic pod membership: drop a pod mid-run, rejoin it from a checkpoint.

The churn tentpole makes worker/pod liveness a traced axis of the engines
(`core.delays.ChurnSchedule`); this module runs the *operational* story on
top of it — what a real deployment does when a pod dies and later comes
back:

1. run to ``drop_clock`` and snapshot the `PSState` via
   ``checkpoint.io.save_runtime`` (the pod's last consistent state);
2. run the outage window ``[drop_clock, rejoin_clock)`` on the survivor
   set (the schedule marks the pod dead: its workers push nothing, their
   reader rows freeze, their queued comm shipments drain per policy);
3. at ``rejoin_clock``, restore the checkpoint and **splice** the dead
   pod's frozen leaves — its ``cview`` reader rows, its workers' local
   state, and (drain policy, wired) its producers' unshipped ``acc``/
   ``res`` mass — into the survivors' live state, then continue.

The correctness claim is sharp: the engines froze *exactly* what the
checkpoint captured, so the spliced state equals the live state **bit for
bit** (asserted leaf by leaf), the concatenated three-segment trace equals
the uninterrupted churned run (schedules index by absolute clock), and the
rejoined pod catches up through the normal machinery — its first read
trips the two-tier staleness bound, the enforcement step answers with a
forced-refresh burst (charged in seconds by `core.timemodel.TimeModel`
through the tiered fetch rates), and under the comm substrate its held
mass ships at the first aggregation boundary after rejoin.  What does
*not* come from the checkpoint is equally deliberate: ring slots of dead
producers keep advancing (overwritten with zeroed pushes), so ``uring``/
``xring``/``base``/``rng``/``clock`` always come from the live survivors.

`tests/test_churn.py` pins all of it; `benchmarks/robustness.py` measures
the recovery cost per consistency family.
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np

from ..checkpoint import io as ckpt_io
from ..core.consistency import ConsistencyConfig
from ..core.delays import ChurnSchedule, make_churn, pod_of
from ..core.ps import PSApp, Trace
from ..psrun.validate import check_staleness_bound
from .runtime import PodsRuntime

# Time-axis Trace fields, in dataclass order (views0 handled separately).
_TIME_FIELDS = ("loss_ref", "loss_view", "staleness", "forced", "delivered",
                "u_l2", "intransit_inf", "ship_floats", "live")


def concat_traces(traces) -> Trace:
    """Concatenate per-segment `Trace`s along the clock axis.

    Final-state fields (``x_final``, ``locals_final``) come from the last
    segment; ``views0`` concatenates when every segment recorded it.
    """
    traces = list(traces)
    fields = {name: np.concatenate(
        [np.asarray(getattr(t, name)) for t in traces], axis=0)
        for name in _TIME_FIELDS}
    views0 = None
    if all(t.views0 is not None for t in traces):
        views0 = np.concatenate([np.asarray(t.views0) for t in traces],
                                axis=0)
    last = traces[-1]
    return Trace(views0=views0, x_final=np.asarray(last.x_final),
                 locals_final=jax.tree_util.tree_map(np.asarray,
                                                     last.locals_final),
                 **fields)


def _pod_rows(P: int, n_pods: int, pod: int) -> np.ndarray:
    """Boolean [P] mask of the workers living in ``pod``."""
    return np.asarray(pod_of(P, n_pods)) == pod


def splice_rejoin_state(live_state, ckpt_state, cfg: ConsistencyConfig,
                        pod: int, drop_inflight: bool = False):
    """Rebuild the post-outage state from survivors + the pod's checkpoint.

    Takes the dead pod's frozen leaves from ``ckpt_state`` — its ``cview``
    reader rows, its workers' ``local`` rows, and (drain policy, wired)
    its producers' unshipped ``acc``/``res`` — and everything else
    (advancing ring/base/rng/clock, survivor rows) from ``live_state``.
    Returns ``(spliced_state, max_abs_diff)`` where the diff compares the
    spliced state against ``live_state`` leaf-for-leaf: the engines froze
    exactly these leaves during the outage, so it must be 0.0 — the
    checkpoint restores the pod to precisely the state the continuous
    churned run says it is in.
    """
    P = live_state.cview.shape[0]
    rows = _pod_rows(P, cfg.n_pods, pod)                 # [P] bool

    def rowwise(live_leaf, ckpt_leaf, mask):
        m = np.asarray(mask).reshape((P,) + (1,) * (live_leaf.ndim - 1))
        return np.where(m, np.asarray(ckpt_leaf), np.asarray(live_leaf))

    cview = rowwise(np.asarray(live_state.cview),
                    np.asarray(ckpt_state.cview), rows)
    local = jax.tree_util.tree_map(
        lambda lv, ck: rowwise(np.asarray(lv), np.asarray(ck), rows),
        live_state.local, ckpt_state.local)
    comm = live_state.comm
    if comm is not None and not drop_inflight:
        # drain policy: the pod's unshipped aggregation mass was held at
        # death and is still sitting in acc/res — identical in both states
        comm = dict(comm,
                    acc=rowwise(np.asarray(comm["acc"]),
                                np.asarray(ckpt_state.comm["acc"]), rows),
                    res=rowwise(np.asarray(comm["res"]),
                                np.asarray(ckpt_state.comm["res"]), rows))
    spliced = live_state.__class__(
        clock=live_state.clock, base=live_state.base,
        uring=live_state.uring, uclock=live_state.uclock,
        cview=cview, local=local, rng=live_state.rng, comm=comm)
    diffs = {}
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(spliced)[0],
            jax.tree_util.tree_flatten_with_path(live_state)[0], strict=True):
        name = jax.tree_util.keystr(pa)
        a, b = np.asarray(a), np.asarray(b)
        diffs[name] = float(np.abs(a.astype(np.float64)
                                   - b.astype(np.float64)).max())
    return spliced, diffs


def run_with_pod_rejoin(runtime: PodsRuntime, app: PSApp,
                        cfg: ConsistencyConfig, n_clocks: int, *,
                        pod: int, drop_clock: int, rejoin_clock: int,
                        seed=0, ckpt_path: str | None = None,
                        drop_inflight: bool = False,
                        schedule: ChurnSchedule | None = None) -> dict:
    """Drop ``pod`` at ``drop_clock``, rejoin it from checkpoint at
    ``rejoin_clock``, and prove the recovery exact.

    Runs three ``run_from`` segments under one absolute-clock
    `ChurnSchedule` (built from the outage unless given), checkpointing at
    the drop and splicing the restored pod state back at the rejoin.
    Returns::

        {"trace":            the full concatenated Trace,
         "state":            final PSState,
         "splice_max_diff":  per-leaf |spliced - live|  (all 0.0),
         "splice_exact":     bool — checkpoint rejoin is bit-exact,
         "staleness_post":   check_staleness_bound on the post-rejoin
                             segment (ssp/essp; None otherwise),
         "ckpt_path":        where the pod's snapshot lives,
         "schedule":         the ChurnSchedule used}

    The equality claim is strict by design: if any engine leaked state
    into a dead pod's frozen leaves, ``splice_exact`` trips — this is the
    executable proof that checkpoint-restore + catch-up-through-the-wire
    reproduces the continuous churned run bit for bit.
    """
    if not (0 < drop_clock < rejoin_clock <= n_clocks):
        raise ValueError(f"need 0 < drop_clock({drop_clock}) < "
                         f"rejoin_clock({rejoin_clock}) <= {n_clocks}")
    if schedule is None:
        schedule = make_churn(n_clocks, app.n_workers, n_pods=cfg.n_pods,
                              pod_outages=((pod, drop_clock, rejoin_clock),),
                              drop_inflight=drop_inflight)
    if ckpt_path is None:
        ckpt_path = os.path.join(tempfile.mkdtemp(prefix="repro_rejoin_"),
                                 f"pod{pod}_clock{drop_clock}.npz")

    # segment 1: healthy fleet -> drop_clock; snapshot the state the dying
    # pod will restore from
    state = runtime.init_state(app, cfg, seed=seed, n_clocks=drop_clock)
    tr1, state = runtime.run_from(app, cfg, drop_clock, state,
                                  schedule=schedule)
    ckpt_io.save_runtime(ckpt_path, state)

    # segment 2: the outage window — survivors only (the schedule masks
    # the pod; its frozen leaves ride along untouched)
    tr2, state = runtime.run_from(app, cfg, rejoin_clock - drop_clock,
                                  state, schedule=schedule)

    # segment 3: restore + splice + continue.  The restored checkpoint is
    # the rejoining pod's entire local knowledge; the splice must land on
    # exactly the live state (the freeze/checkpoint agreement).
    restored = ckpt_io.restore_runtime(
        ckpt_path, runtime.init_state(app, cfg, seed=seed,
                                      n_clocks=drop_clock))
    spliced, diffs = splice_rejoin_state(state, restored, cfg, pod,
                                         drop_inflight=drop_inflight)
    splice_exact = all(v == 0.0 for v in diffs.values())
    spliced = jax.tree_util.tree_map(
        lambda ref, arr: jax.numpy.asarray(
            arr, dtype=getattr(ref, "dtype", None)),
        state, spliced)
    tr3, state = runtime.run_from(app, cfg, n_clocks - rejoin_clock,
                                  spliced, schedule=schedule)

    post = None
    if cfg.model in ("ssp", "essp"):
        post = check_staleness_bound(tr3, cfg)
    return {"trace": concat_traces((tr1, tr2, tr3)), "state": state,
            "splice_max_diff": diffs, "splice_exact": splice_exact,
            "staleness_post": post, "ckpt_path": ckpt_path,
            "schedule": schedule}
