"""Oracle contract for the hierarchical runtime.

``core.ps.simulate`` with the *same* hierarchical config is the oracle —
the pods runtime must match it exactly like psrun matches the flat mode
(``psrun.validate.cross_validate`` does the per-model comparison; its
staleness check is already two-tier via
``core.delays.staleness_bound_matrix``).  On top of that the hierarchical
contract adds the replica layer: pods' visible prefixes must stay within
the reconciliation bound (`pods.reconcile.replica_divergence`).
"""
from __future__ import annotations

from ..core.consistency import ConsistencyConfig
from ..core.ps import PSApp
from ..psrun.validate import cross_validate
from .reconcile import reconcile_stats, replica_divergence
from .runtime import PodsRuntime


def cross_validate_pods(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                        runtime: PodsRuntime | None = None, seed=0) -> dict:
    """Run both engines and check the hierarchical oracle contract.

    BSP/SSP/ESSP: bit-identical traces (+ two-tier staleness bound for
    SSP/ESSP).  VAP: value bound, exact decisions, strict ulp budget.
    All bounded models: replica divergence within ``s_intra + s_xpod``.
    Returns the evidence dict with an overall ``ok``.
    """
    runtime = runtime or PodsRuntime()
    out = cross_validate(app, cfg, n_clocks, runtime=runtime, seed=seed,
                         return_trace=True)
    tr = out.pop("trace")          # reuse — don't re-execute the run
    div = replica_divergence(tr, cfg)
    out["replica_divergence"] = {k: v for k, v in div.items()
                                 if k != "per_clock"}
    if div["ok"] is not None:
        out["ok"] = out["ok"] and div["ok"]
    out["reconcile"] = reconcile_stats(tr, cfg, dim=app.dim)
    return out
