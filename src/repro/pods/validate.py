"""Oracle contract for the hierarchical runtime.

``core.ps.simulate`` with the *same* hierarchical config is the oracle —
the pods runtime must match it exactly like psrun matches the flat mode
(``psrun.validate.cross_validate`` does the per-model comparison; its
staleness check is already two-tier via
``core.delays.staleness_bound_matrix``, and widens by ``agg_clocks - 1``
under the comm substrate).  On top of that the hierarchical contract adds
the replica layer: pods' visible prefixes must stay within the
reconciliation bound (`pods.reconcile.replica_divergence`) — and for the
models with *no* clock bound (async/VAP), within the **value**-bound
analogue (`pods.reconcile.replica_value_divergence`, wired through
``core.valuebound``): the replica-divergence envelope stays under
``2 v_t`` for VAP, and is reported measured-only for async.
"""
from __future__ import annotations

from ..core.consistency import ConsistencyConfig
from ..core.ps import PSApp
from ..psrun.validate import cross_validate
from .reconcile import (reconcile_stats, replica_divergence,
                        replica_value_divergence)
from .runtime import PodsRuntime


def cross_validate_pods(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                        runtime: PodsRuntime | None = None, seed=0,
                        schedule=None, faults=None) -> dict:
    """Run both engines and check the hierarchical oracle contract.

    BSP/SSP/ESSP: bit-identical traces (+ two-tier staleness bound for
    SSP/ESSP — widened by ``agg_clocks - 1`` when the comm substrate is
    active).  VAP: value bound, exact decisions, strict ulp budget.
    Bounded models: replica divergence within ``s_intra + s_xpod``
    (+ ``agg_clocks - 1``); unbounded models (async/VAP): the replica
    value-divergence envelope, checked against ``2 v_t`` for VAP (clock
    bound stays ``None``).  Returns the evidence dict with an overall
    ``ok``.  Under a ``schedule`` (fleet churn) every layer re-derives
    over the live set: the staleness check masks dead readers, and the
    replica layer drops pods with no live reader at a clock.
    """
    runtime = runtime or PodsRuntime()
    out = cross_validate(app, cfg, n_clocks, runtime=runtime, seed=seed,
                         return_trace=True, schedule=schedule,
                         faults=faults)
    tr = out.pop("trace")          # reuse — don't re-execute the run
    if faults is not None:
        # lossy wire: bit-identity (checked above) is the contract; the
        # clock-divergence layers assume every shipment lands on time,
        # which an arbitrary fault mask need not honor
        return out
    div = replica_divergence(tr, cfg)
    out["replica_divergence"] = {k: v for k, v in div.items()
                                 if k != "per_clock"}
    if div["ok"] is not None:
        out["ok"] = out["ok"] and div["ok"]
    if cfg.model in ("async", "vap"):
        vdiv = replica_value_divergence(tr, cfg)
        out["replica_value_divergence"] = {k: v for k, v in vdiv.items()
                                           if k != "per_clock"}
        if vdiv["ok"] is not None:
            out["ok"] = out["ok"] and vdiv["ok"]
    out["reconcile"] = reconcile_stats(tr, cfg, dim=app.dim)
    return out
