"""PodsRuntime: the psrun clock step on a 3-D ``("pod","data","model")``
mesh.

The whole shard-local view/push machinery is shared with `repro.psrun`
(``psrun.runtime.make_run_fn`` generalizes over *worker axes*); this module
only fixes the axes to ``("pod", "data")`` — the ``P`` workers partition
pod-major, so the mesh's pod blocks coincide with ``core.delays.pod_of`` —
and validates that the config's ``n_pods`` matches the physical pod axis:
on this runtime the pod partition is *placement*, not just channel
classification.

What the mesh layout means hierarchically (see ``psrun.runtime`` for the
per-clock step):

- ``base``/``uring`` are sharded over "model" and replicated over
  ``("pod","data")`` — the per-pod replica of the parameter shards;
- the per-clock ``all_gather`` of fresh updates over ``("pod","data")`` is
  the eager reconciliation channel: one ``[P, d]`` delta per clock crosses
  the pod boundary (never the ``[W, P, d]`` replica), and the oldest ring
  slot folds ``P`` producer updates into one ``[d_block]`` vector of the
  replica's base — the delta-compressed fold;
- ``cview`` rows live with their pod's workers and gate what each reader
  *sees* of the reconciled ring under the two-tier staleness bound.
"""
from __future__ import annotations

import jax

from ..core.consistency import ConsistencyConfig
from ..core.ps import PSApp
from ..launch.mesh import make_pods_mesh
from ..psrun.runtime import PSRuntime

# re-exported for parity with psrun.runtime.trace_count (same counter: the
# pods runtime runs the same compiled body)
from ..psrun.runtime import trace_count  # noqa: F401


def default_pods_mesh(n_workers: int, n_pods: int = 2, devices=None):
    """The widest ``("pod","data","model")`` mesh for ``n_workers`` over
    ``n_pods`` that stays in the bit-identity regime: per pod, the data
    axis is the largest divisor of the pod's device count that divides the
    pod's worker count while keeping >= 2 workers per shard; an even
    leftover becomes 2 model-shard columns.  (16 devices, 16 workers,
    2 pods -> the CI lane's 2x4x2.)
    """
    if devices is None:
        devices = jax.devices()
    n = len(devices)
    if n % n_pods:
        raise ValueError(f"n_pods={n_pods} does not divide the {n} visible "
                         f"devices")
    if n_workers % n_pods:
        raise ValueError(f"n_workers={n_workers} must divide by "
                         f"n_pods={n_pods}")
    per_pod_w = n_workers // n_pods
    per_pod_dev = n // n_pods
    data = 1
    for cand in range(min(per_pod_dev, per_pod_w // 2), 0, -1):
        if per_pod_w % cand == 0 and per_pod_dev % cand == 0:
            data = cand
            break
    rest = per_pod_dev // data
    model = 2 if (rest > 1 and rest % 2 == 0) else 1
    return make_pods_mesh(pods=n_pods, data=data, model=model,
                          devices=devices)


class PodsRuntime(PSRuntime):
    """Hierarchical PS: ``PodsRuntime(mesh).run(app, cfg, n_clocks)``.

    ``cfg.n_pods`` must equal the mesh's pod-axis size (the config's pod
    partition *is* the placement here), and the app's workers must divide
    by ``pod x data``.  Everything else — Trace schema, compile caching,
    ``init_state``/``run_from`` checkpointing — is inherited from
    `psrun.runtime.PSRuntime`; the simulator's hierarchical mode
    (``core.ps.simulate`` with the same config) is the oracle
    (`pods.validate.cross_validate_pods`).
    """

    worker_axes = ("pod", "data")

    def _default_mesh(self):
        return make_pods_mesh()

    def run_fn(self, app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
               record_views: bool = False, schedule=None, obs=None,
               faults=None):
        n_pods = self.mesh.shape["pod"]
        if cfg.n_pods != n_pods:
            raise ValueError(
                f"cfg.n_pods={cfg.n_pods} must match the mesh pod axis "
                f"({n_pods}): on PodsRuntime the pod partition is physical "
                f"placement — use consistency.podded(cfg, {n_pods}) or a "
                f"matching make_pods_mesh")
        return super().run_fn(app, cfg, n_clocks, record_views, schedule,
                              obs, faults)
