"""On-device metrics accumulators + the host-side metrics registry.

The telemetry substrate has two halves with one hard boundary between
them:

- **device half** — a plain pytree of accumulator arrays
  (:func:`device_init`) threaded through the engines' ``lax.scan`` carry
  and folded once per clock (:func:`device_update`) from values the clock
  step already computes (staleness-at-read, forced refreshes, deliveries,
  wire floats, liveness).  *Zero host callbacks*: nothing crosses the
  host boundary until the run returns, which is what keeps the hot path
  hot (and is machine-checked by the ``host-callback`` analysis rule).
  Inside ``shard_map`` each worker shard accumulates its own reader rows;
  :func:`device_reduce` folds the shards with one ``psum``/``pmax`` per
  leaf *after* the scan — one collective per run, not per clock.
- **host half** — a :class:`MetricsRegistry` of counters / gauges /
  histograms that :func:`drain_device` fills from the returned
  accumulator pytree (``Trace.obs``), plus whatever host-side evidence
  callers fold in (compile counts via :func:`record_compiles`, modeled
  seconds from `TimeModel`).  ``repro.obs.events`` snapshots the registry
  into the JSONL event stream and ``repro.obs.report`` renders it.

Everything here is observability-only: with ``obs=None`` (every engine's
default) no accumulator exists and the compiled programs are unchanged —
`Trace` output is bit-identical to a build without this module.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

# Default bucket count of the staleness-at-read lag histogram: lag k lands
# in bucket k, the last bucket is ">= n_buckets - 1" (open-ended tail).
DEFAULT_LAG_BUCKETS = 16


@dataclass(frozen=True)
class ObsSpec:
    """Static observability switch for the engines (``obs=`` argument).

    A plain hashable dataclass, *not* a pytree: whether telemetry is
    collected selects code structure (an extra accumulator in the scan
    carry), so it is compile-time static like ``cfg.model``.  ``None`` /
    ``enabled=False`` is the default everywhere and compiles the exact
    pre-obs program.
    """

    enabled: bool = True
    n_buckets: int = DEFAULT_LAG_BUCKETS

    def __post_init__(self):
        if self.n_buckets < 2:
            raise ValueError("n_buckets must be >= 2 (one lag bucket plus "
                             "the open tail)")


def obs_on(obs: ObsSpec | None) -> bool:
    """The static predicate the engines branch on."""
    return obs is not None and obs.enabled


# --------------------------------------------------------------------------
# device half: the accumulator pytree threaded through the scan
# --------------------------------------------------------------------------

# accumulator leaves reduced over the worker axes after the scan (the
# reader-row quantities each shard accumulates locally) -> reduction op.
# Every other leaf is derived from globally replicated inputs (full-P
# liveness, gathered ship_floats) and needs no reduction.
_REDUCE = {"lag_hist": "sum", "lag_max": "max", "forced_intra": "sum",
           "forced_xpod": "sum", "delivered": "sum"}


def device_init(P: int, n_buckets: int = DEFAULT_LAG_BUCKETS) -> dict:
    """Zeroed accumulators for a run over ``P`` workers (one pytree)."""
    i32 = jnp.int32
    return {
        "clocks": jnp.zeros((), i32),             # clocks accumulated
        "lag_hist": jnp.zeros((n_buckets,), i32), # staleness-at-read lags
        "lag_max": jnp.zeros((), i32),            # worst read lag seen
        "forced_intra": jnp.zeros((), i32),       # blocking fetches, intra
        "forced_xpod": jnp.zeros((), i32),        # blocking fetches, xpod
        "delivered": jnp.zeros((), i32),          # background deliveries
        "ship_floats": jnp.zeros((P,), jnp.float32),  # per-producer wire
        "dead_worker_clocks": jnp.zeros((), i32), # worker-clocks lost
    }


def device_update(acc: dict, *, staleness, forced, delivered, ship_floats,
                  live, live_rows, in_pod) -> dict:
    """Fold one clock's already-computed step values into ``acc``.

    Pure arithmetic on values the clock step materializes anyway — no new
    RNG draws, no callbacks, no reductions beyond the shard-local rows:

    - ``staleness``/``forced``/``delivered``: the ``[R, P]`` reader rows
      this program holds (``R = P`` in the simulator, the shard's ``Pl``
      rows under ``shard_map``);
    - ``ship_floats``: the clock's ``[P]`` bits-weighted wire floats
      (replicated across worker shards in the runtimes);
    - ``live`` (``[P]``, all producers) and ``live_rows`` (``[R]``, this
      program's readers): the liveness masks — dead readers perform no
      read, so their rows are excluded from the read-lag statistics;
    - ``in_pod``: the ``[R, P]`` channel-tier mask (all-True when
      ``n_pods == 1``).
    """
    i32 = jnp.int32
    n_buckets = acc["lag_hist"].shape[0]
    # read lag in clocks: staleness is cview - c in [-(bound+1), -1], so
    # the number of in-transit clocks at read time is -1 - staleness >= 0.
    lag = (-1 - staleness).astype(i32)                       # [R, P]
    w = live_rows[:, None]                                   # live readers
    lagc = jnp.clip(lag, 0, n_buckets - 1)
    onehot = (lagc[:, :, None] == jnp.arange(n_buckets, dtype=i32)) \
        & w[:, :, None]                                      # [R, P, NB]
    f = forced & w
    return {
        "clocks": acc["clocks"] + 1,
        "lag_hist": acc["lag_hist"] + onehot.sum(axis=(0, 1)).astype(i32),
        "lag_max": jnp.maximum(acc["lag_max"],
                               jnp.max(jnp.where(w, lag, 0))),
        "forced_intra": acc["forced_intra"]
        + (f & in_pod).sum().astype(i32),
        "forced_xpod": acc["forced_xpod"]
        + (f & ~in_pod).sum().astype(i32),
        "delivered": acc["delivered"]
        + (delivered & w).sum().astype(i32),
        "ship_floats": acc["ship_floats"] + ship_floats,
        "dead_worker_clocks": acc["dead_worker_clocks"]
        + (live.shape[0] - live.sum()).astype(i32),
    }


def device_reduce(acc: dict, worker_axes) -> dict:
    """Fold per-shard accumulators over the mesh worker axes (one
    collective per reduced leaf, after the scan).  The simulator holds
    the full reader matrix and never calls this."""
    out = dict(acc)
    for k, op in _REDUCE.items():
        out[k] = (jax.lax.psum(acc[k], worker_axes) if op == "sum"
                  else jax.lax.pmax(acc[k], worker_axes))
    return out


# --------------------------------------------------------------------------
# host half: the registry
# --------------------------------------------------------------------------

class MetricsRegistry:
    """Counters, gauges, and histograms on the host side of the boundary.

    Conventions: metric names are ``/``-separated paths
    (``ps/forced_xpod``, ``compiles/sweep``); counters accumulate across
    ``counter_add`` calls (draining two runs sums them), gauges keep the
    last value, histograms keep integer bucket counts with labeled
    buckets.  ``flat()`` flattens everything into the
    ``BENCH_*.json``-style metric dict the perf-trajectory gate diffs.
    """

    def __init__(self):
        self.counters: dict = {}
        self.gauges: dict = {}
        self.hists: dict = {}

    # -------------------------------------------------------------- write
    def counter_add(self, name: str, value) -> None:
        self.counters[name] = self.counters.get(name, 0) + _num(value)

    def gauge_set(self, name: str, value) -> None:
        self.gauges[name] = _num(value)

    def hist_add(self, name: str, counts, buckets=None) -> None:
        counts = [int(c) for c in np.asarray(counts).ravel()]
        h = self.hists.get(name)
        if h is None:
            if buckets is None:
                buckets = [str(i) for i in range(len(counts) - 1)] \
                    + [f"{len(counts) - 1}+"]
            self.hists[name] = {"buckets": [str(b) for b in buckets],
                                "counts": counts}
            return
        if len(h["counts"]) != len(counts):
            raise ValueError(f"histogram {name!r} bucket count changed: "
                             f"{len(h['counts'])} != {len(counts)}")
        h["counts"] = [a + b for a, b in zip(h["counts"], counts)]

    # --------------------------------------------------------------- read
    def to_dict(self) -> dict:
        return {"counters": dict(self.counters), "gauges": dict(self.gauges),
                "hists": {k: {"buckets": list(v["buckets"]),
                              "counts": list(v["counts"])}
                          for k, v in self.hists.items()}}

    def flat(self) -> dict:
        """Flat numeric dict (hists summarized as mean/p50/p99/total)."""
        out = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, h in self.hists.items():
            counts = np.asarray(h["counts"], np.float64)
            total = counts.sum()
            out[f"{name}/total"] = float(total)
            if total > 0:
                centers = np.arange(len(counts), dtype=np.float64)
                out[f"{name}/mean"] = float((counts * centers).sum() / total)
                cum = np.cumsum(counts) / total
                out[f"{name}/p50"] = float(np.searchsorted(cum, 0.5))
                out[f"{name}/p99"] = float(np.searchsorted(cum, 0.99))
        return out


def _num(v):
    v = np.asarray(v).item() if np.ndim(v) == 0 else v
    if isinstance(v, (bool, np.bool_)):
        return int(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    return float(v)


def drain_device(reg: MetricsRegistry, acc, prefix: str = "ps") -> None:
    """Fold a returned accumulator pytree (``Trace.obs``) into ``reg``."""
    if acc is None:
        raise ValueError("trace carries no obs accumulators — run the "
                         "engine with obs=ObsSpec() to collect them")
    get = lambda k: np.asarray(acc[k])
    reg.gauge_set(f"{prefix}/clocks", get("clocks"))
    reg.hist_add(f"{prefix}/staleness_lag", get("lag_hist"))
    reg.gauge_set(f"{prefix}/lag_max", get("lag_max"))
    reg.counter_add(f"{prefix}/forced_intra", get("forced_intra"))
    reg.counter_add(f"{prefix}/forced_xpod", get("forced_xpod"))
    reg.counter_add(f"{prefix}/delivered", get("delivered"))
    reg.counter_add(f"{prefix}/ship_floats_total",
                    float(get("ship_floats").sum()))
    reg.counter_add(f"{prefix}/dead_worker_clocks",
                    get("dead_worker_clocks"))


def record_compiles(reg: MetricsRegistry) -> None:
    """Snapshot the engines' compile/trace counters into the registry —
    the sweep/runtime one-compile claims become observable metrics."""
    from ..core.sweep import trace_count as sweep_traces
    from ..psrun.runtime import trace_count as runtime_traces
    reg.gauge_set("compiles/sweep_traces", sweep_traces())
    reg.gauge_set("compiles/runtime_traces", runtime_traces())


def record_timing(reg: MetricsRegistry, trace, model: str, tm, fold=(),
                  cfg=None, schedule=None, prefix: str = "ps") -> None:
    """Fold a run's modeled seconds (`TimeModel`) into the registry:
    total / compute / comm seconds plus per-worker modeled compute and
    the cross-pod wire seconds of the second tier."""
    tl = tm.timeline_np(trace, model, fold=fold, cfg=cfg, schedule=schedule)
    reg.gauge_set(f"{prefix}/modeled_wall_s", tl["wall"].sum())
    reg.gauge_set(f"{prefix}/modeled_comp_s", tl["comp_clock"].sum())
    reg.gauge_set(f"{prefix}/modeled_comm_s", tl["comm_clock"].sum())
    reg.gauge_set(f"{prefix}/modeled_wire_s", tl["wire"].sum())
    for p, s in enumerate(tl["comp"].sum(axis=0)):
        reg.gauge_set(f"{prefix}/worker{p:02d}/modeled_comp_s", s)
