"""Markdown run reports: staleness / throughput / wire tables per family.

The human-facing end of the telemetry substrate.  ``trace_summary``
reduces one run (`Trace` + config + `TimeModel`) to a flat row of
headline telemetry — read-lag stats, tier-split forced refreshes, floats
on the cross-pod wire, modeled wall/compute/comm seconds —
``render_report`` lays a list of such rows out as a markdown document
(one row per consistency family/scenario), and ``churn_grid_table``
renders the robustness benchmark's family × failure-scenario grid.  CI
uploads the rendered reports next to the `BENCH_*.json` artifacts.
"""
from __future__ import annotations

import numpy as np

from ..core.delays import same_pod_mask
from .metrics import MetricsRegistry


def fmt(v) -> str:
    """One table cell: compact numbers, em-dash for missing."""
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        if v == 0:
            return "0"
        a = abs(v)
        if a >= 1e5 or a < 1e-3:
            return f"{v:.3g}"
        return f"{v:.3f}".rstrip("0").rstrip(".")
    return str(v)


def md_table(headers, rows) -> str:
    """GitHub-flavored markdown table (cells formatted via ``fmt``)."""
    head = "| " + " | ".join(str(h) for h in headers) + " |"
    sep = "|" + "|".join(" --- " for _ in headers) + "|"
    body = ["| " + " | ".join(fmt(c) for c in row) + " |" for row in rows]
    return "\n".join([head, sep, *body])


def trace_summary(trace, cfg, tm, label: str | None = None,
                  model: str | None = None, fold=(),
                  schedule=None) -> dict:
    """One run → one flat row of headline telemetry (host-side numpy).

    Works on any Trace producer's output, with or without ``Trace.obs``
    (the row is derived from the per-clock arrays; the on-device
    accumulators exist so *hot* paths don't need these arrays at all).
    """
    model = cfg.model if model is None else model
    staleness = np.asarray(trace.staleness)          # [T, P, P]
    forced = np.asarray(trace.forced)
    live = np.asarray(trace.live)                    # [T, P]
    loss_ref = np.asarray(trace.loss_ref)
    T, P, _ = staleness.shape
    tl = tm.timeline_np(trace, model, fold=fold, cfg=cfg,
                        schedule=schedule)

    lag = -1 - staleness                             # read lag in clocks
    reader_live = np.broadcast_to(live[:, :, None], lag.shape)
    lags = lag[reader_live]
    in_pod = np.broadcast_to(
        np.asarray(same_pod_mask(P, cfg.n_pods))[None], forced.shape)
    f_live = forced & reader_live
    wall_s = float(tl["wall"].sum())
    return {
        "label": model if label is None else label,
        "model": model, "family": str(cfg.family), "clocks": T,
        "loss_final": float(loss_ref[-1]),
        "lag_mean": float(lags.mean()) if lags.size else None,
        "lag_p99": (float(np.percentile(lags, 99)) if lags.size else None),
        "lag_max": int(lags.max()) if lags.size else None,
        "forced_intra": int((f_live & in_pod).sum()),
        "forced_xpod": int((f_live & ~in_pod).sum()),
        "delivered": int((np.asarray(trace.delivered)
                          & reader_live).sum()),
        "ship_floats": float(np.asarray(trace.ship_floats).sum()),
        "dead_worker_clocks": int((~live).sum()),
        "wall_s": wall_s, "comp_s": float(tl["comp_clock"].sum()),
        "comm_s": float(tl["comm_clock"].sum()),
        "wire_s": float(tl["wire"].sum()),
        "clocks_per_s": (T / wall_s) if wall_s > 0 else None,
    }


def render_report(title: str, summaries: list[dict],
                  registry: MetricsRegistry | None = None,
                  notes=()) -> str:
    """Markdown report over one or more ``trace_summary`` rows."""
    parts = [f"# {title}", ""]
    for note in notes:
        parts += [f"> {note}", ""]
    parts += ["## Staleness", "", md_table(
        ["run", "lag mean", "lag p99", "lag max", "forced intra",
         "forced xpod", "delivered"],
        [[s["label"], s["lag_mean"], s["lag_p99"], s["lag_max"],
          s["forced_intra"], s["forced_xpod"], s["delivered"]]
         for s in summaries]), ""]
    parts += ["## Throughput", "", md_table(
        ["run", "clocks", "wall s", "comp s", "comm s", "clocks/s",
         "final loss", "dead worker-clocks"],
        [[s["label"], s["clocks"], s["wall_s"], s["comp_s"], s["comm_s"],
          s["clocks_per_s"], s["loss_final"], s["dead_worker_clocks"]]
         for s in summaries]), ""]
    parts += ["## Wire", "", md_table(
        ["run", "floats shipped", "wire s"],
        [[s["label"], s["ship_floats"], s["wire_s"]]
         for s in summaries]), ""]
    if registry is not None:
        flat = registry.flat()
        parts += ["## Metrics", "", md_table(
            ["metric", "value"],
            [[k, flat[k]] for k in sorted(flat)]), ""]
    return "\n".join(parts)


def attribution_table(diff: dict) -> str:
    """One `repro.obs.diff` result -> markdown attribution section.

    Stream diffs render the component share table plus the exact wall
    split; BENCH diffs render the ranked component/driver table.  Either
    way the table answers "which subsystem moved" — `benchmarks.compare`
    prints the same content as plain lines (`repro.obs.diff.explain`).
    """
    if diff["kind"] == "streams":
        head = [f"## Attribution: {diff['base_run']} -> "
                f"{diff['cur_run']}", ""]
        d = diff["target_delta"]
        if d is not None:
            head += [f"> {diff['target']}: {fmt(diff['target_base'])} -> "
                     f"{fmt(diff['target_cur'])} ({d:+g})", ""]
        comp_tbl = md_table(
            ["component", "indicator", "base", "cur", "share"],
            [[name, c["indicator"], c["base"], c["cur"],
              f"{c['share']:.0%}"]
             for name in diff["ranked"]
             for c in [diff["components"][name]]])
        wall_tbl = md_table(
            ["seconds", "base", "cur", "delta"],
            [[k, w["base"], w["cur"], w["delta"]]
             for k, w in diff["wall"].items()])
        return "\n".join([*head, comp_tbl, "", "### Wall split (exact)",
                          "", wall_tbl])
    rows = []
    for name, comp in diff["flipped_claims"]:
        rows.append([comp, f"claim {name}", "True", "False", "flipped"])
    for name in diff["ranked"]:
        c = diff["components"][name]
        if c["driver"] is not None:
            rows.append([name, c["driver"], None, None,
                         f"{c['driver_rel']:+.1%}"])
    return "\n".join([
        f"## Attribution: BENCH_{diff.get('bench')}", "",
        md_table(["component", "driver", "base", "cur", "moved"],
                 rows or [["—", "no attributable movement", None, None,
                           "—"]])])


def churn_cell(row: dict) -> str:
    """One grid cell: ``clocks (+lost)``, ∞ for never-recovered, ``DIV``
    appended on divergence."""
    c = row.get("clocks_to_thresh")
    cell = "∞" if c is None else str(c)
    lost = row.get("lost_clocks")
    if lost is not None and lost != 0:
        cell += f" ({lost:+d})"
    if row.get("diverged"):
        cell += " DIV"
    return cell


def churn_grid_table(grid: dict, scenarios=None) -> str:
    """The robustness family × scenario matrix as one markdown table.

    ``grid[family][scenario]`` rows carry ``clocks_to_thresh`` /
    ``lost_clocks`` / ``diverged`` (see `benchmarks.robustness`).
    """
    fams = list(grid)
    if scenarios is None:
        scenarios = list(grid[fams[0]])
    return md_table(
        ["family \\ scenario", *scenarios],
        [[f, *[churn_cell(grid[f][s]) for s in scenarios]] for f in fams])
