"""Versioned, schema-checked JSONL event stream of one PS run.

``collect_events`` turns a `Trace` (any of the three producers — the
simulator oracle, `PSRuntime`, `PodsRuntime` — emits the same stream for
the same run, by the Trace-producer contract) into a flat list of event
dicts on the modeled timebase of `core.timemodel.TimeModel.timeline_np`:
every timestamp/duration is in *modeled seconds from run start*, so the
stream, the Perfetto export (`repro.obs.perfetto`), and the benchmark
wall-second claims all measure the same axis.

Stream layout (one JSON object per line, ``write_jsonl``/``read_jsonl``):

- ``run_start`` — header: schema version (``v``), run/app name, model,
  config family, fleet shape, clock count;
- per clock ``t`` (ascending): one ``clock`` summary, a ``worker_span``
  per live worker (modeled compute + blocking-fetch seconds), a
  ``shipment`` per producer that put floats on the cross-pod wire
  (hierarchical runs), a ``stale_read`` per reader whose bound tripped
  (forced channel count + worst read lag), and a ``churn`` transition per
  worker that died/rejoined entering this clock;
- ``metrics`` — one snapshot of a `MetricsRegistry` (when given);
- ``run_end`` — totals (wall/comp/comm/wire seconds, clocks).

``validate_events`` checks the stream against ``SCHEMA``: known types,
required fields present with the right shapes, version compatibility,
header / terminator placement, and non-decreasing clock order — the CI
obs lane runs it on a fresh churned pods run every push.

Versioning & forward compatibility
----------------------------------
The schema version is **major.minor** (``SCHEMA_VERSION`` /
``SCHEMA_MINOR``, stamped on ``run_start`` as ``v`` / ``vm``) so
producers and consumers can evolve independently:

- a **major** bump breaks consumers: the validator rejects any stream
  whose ``v`` differs from its own (pinned by ``tests/test_obs.py``);
- a **minor** bump is additive only — new *optional* fields on existing
  events (``SCHEMA_OPTIONAL``) or new event types.  The validator
  accepts unknown keys on any event unconditionally (they are optional
  fields from a newer producer), type-checks the optional fields it
  *does* know, and tolerates unknown event **types** only when the
  stream's minor version is newer than its own — a same-or-older stream
  using a type we don't know is corrupt, not future.

Minor history: ``1.0`` the PR 8 substrate; ``1.1`` adds per-clock
read-lag stats (``clock.lag_p99`` / ``clock.lag_max``), the declared
staleness contract on the header (``run_start.bound``), and the
``slo_violation`` event `repro.obs.monitor` folds back into the stream;
``1.2`` adds the ``recovery_action`` event (`repro.ctrl.recover` folds
the controller's typed decisions back into the stream it consumed) and
``run_start.retry_budget``, the lossy-wire widening already included in
``run_start.bound`` (`comm.wire.WireFaults.retry_budget`).  Consumers
(`repro.obs.monitor`, `repro.ctrl.recover`) key on the pair via
:func:`check_version`.
"""
from __future__ import annotations

import json

import numpy as np

from .metrics import MetricsRegistry

SCHEMA_VERSION = 1          # major: compatibility-breaking changes
SCHEMA_MINOR = 2            # minor: additive fields / event types

# required fields per event type (beyond "type"); values document the
# expected JSON type and are checked by validate_events.
SCHEMA = {
    "run_start": {"v": int, "run": str, "model": str, "family": str,
                  "n_workers": int, "n_pods": int, "n_clocks": int,
                  "ts": float},
    "clock": {"t": int, "ts": float, "dur": float, "loss_ref": float,
              "forced": int, "delivered": int, "live": int,
              "ship_floats": float},
    "worker_span": {"t": int, "worker": int, "ts": float, "dur": float,
                    "comp_s": float, "sync_s": float},
    "shipment": {"t": int, "worker": int, "ts": float, "dur": float,
                 "floats": float},
    "stale_read": {"t": int, "worker": int, "ts": float, "n_forced": int,
                   "max_lag": int},
    "churn": {"t": int, "worker": int, "ts": float, "event": str},
    "metrics": {"ts": float, "registry": dict},
    "slo_violation": {"t": int, "ts": float, "slo": str, "window": int,
                      "value": float, "limit": float},
    "recovery_action": {"t": int, "ts": float, "action": str},
    "run_end": {"ts": float, "wall_s": float, "comp_s": float,
                "comm_s": float, "wire_s": float, "clocks": int},
}

# optional fields per event type (type-checked when present, never
# required): the minor-version extension surface.  Anything *not* listed
# here is still accepted — a newer minor may carry fields this build has
# never heard of — but what we do know about must have the right type.
SCHEMA_OPTIONAL = {
    "run_start": {"vm": int, "bound": int, "retry_budget": int},
    "clock": {"lag_p99": float, "lag_max": int},
    "recovery_action": {"worker": int, "pod": int, "reason": str,
                        "quant": str, "agg_clocks": int, "clocks": int},
}


class SchemaError(ValueError):
    """An event stream violating the versioned schema."""


def declared_bound(cfg, retry_budget: int = 0) -> int | None:
    """The run's declared worst-case read lag in clocks, or ``None`` for
    families without a clock bound (async; VAP is value-bounded).

    The two-tier contract of `core.delays.staleness_bound_matrix`:
    ``s`` intra-pod, widened to ``s + s_xpod + agg_clocks - 1`` on
    cross-pod channels, plus ``retry_budget`` under a lossy wire
    (`comm.wire.WireFaults.retry_budget` — 0 on a perfect wire).
    Stamped on ``run_start`` so stream consumers (the SLO monitor)
    check the contract the producer actually declared rather than
    re-deriving it from a config they don't have.
    """
    if cfg.model not in ("bsp", "ssp", "essp"):
        return None
    bound = int(np.asarray(cfg.staleness))
    if int(cfg.n_pods) > 1:
        bound += int(np.asarray(cfg.s_xpod))
        if cfg.comm_active:
            bound += int(np.asarray(cfg.agg_clocks)) - 1 + int(retry_budget)
    return bound


def clock_lag_stats(staleness_t, live_t) -> tuple[float, int] | None:
    """One clock's live-reader read-lag stats ``(lag_p99, lag_max)``.

    ``staleness_t`` is the clock's ``[P, P]`` staleness rows, ``live_t``
    its ``[P]`` liveness mask; dead readers perform no read and are
    excluded.  Shared by the producer (``collect_events``) and the
    consumer-side ground truth (`benchmarks.detect_bench`), so "SLO
    verdicts agree with the Trace" is a real pipeline check, not two
    codepaths that happen to match.  ``None`` when no reader is live.
    """
    lag = -1 - np.asarray(staleness_t)
    rows = lag[np.asarray(live_t, bool)]
    if rows.size == 0:
        return None
    return _r(np.percentile(rows, 99)), int(rows.max())


def _r(x) -> float:
    """Timestamps/durations rounded to ns so streams are byte-stable
    across platforms (the goldens pin the JSON text)."""
    return round(float(x), 9)


def collect_events(trace, cfg, tm, model: str | None = None, fold=(),
                   schedule=None, run: str = "run",
                   registry: MetricsRegistry | None = None,
                   faults=None) -> list[dict]:
    """Flatten one run into the event stream (see module doc).

    ``trace`` must be unbatched (one run, clock axis leading); ``cfg`` is
    the run's `ConsistencyConfig` and ``tm`` the `TimeModel` whose
    ``timeline_np`` provides the timebase.  ``model`` defaults to
    ``cfg.model``.  ``faults`` (a `comm.wire.WireFaults`) widens the
    declared bound by its retry budget and stamps
    ``run_start.retry_budget`` so consumers can tell a lossy-wire run
    from a slow one.
    """
    model = cfg.model if model is None else model
    tl = tm.timeline_np(trace, model, fold=fold, cfg=cfg,
                        schedule=schedule)
    staleness = np.asarray(trace.staleness)          # [T, P, P]
    forced = np.asarray(trace.forced)
    delivered = np.asarray(trace.delivered)
    ship = np.asarray(trace.ship_floats)             # [T, P]
    live = np.asarray(trace.live)                    # [T, P]
    loss_ref = np.asarray(trace.loss_ref)
    T, P, _ = staleness.shape
    tiered = cfg.n_pods > 1

    head = {
        "type": "run_start", "v": SCHEMA_VERSION, "vm": SCHEMA_MINOR,
        "run": run, "model": model, "family": str(cfg.family),
        "n_workers": P, "n_pods": int(cfg.n_pods), "n_clocks": T,
        "ts": 0.0,
    }
    retry_budget = 0 if faults is None else int(faults.retry_budget)
    bound = declared_bound(cfg, retry_budget=retry_budget)
    if bound is not None:
        head["bound"] = bound
    if retry_budget:
        head["retry_budget"] = retry_budget
    ev: list[dict] = [head]
    prev_live = np.ones((P,), bool)
    for t in range(T):
        ts, dur = _r(tl["start"][t]), _r(tl["wall"][t])
        for p in np.flatnonzero(live[t] != prev_live):
            ev.append({"type": "churn", "t": t, "worker": int(p), "ts": ts,
                       "event": "up" if live[t, p] else "down"})
        prev_live = live[t]
        clock = {
            "type": "clock", "t": t, "ts": ts, "dur": dur,
            "loss_ref": float(loss_ref[t]),
            "forced": int(forced[t].sum()), "delivered": int(delivered[t].sum()),
            "live": int(live[t].sum()), "ship_floats": float(ship[t].sum()),
        }
        stats = clock_lag_stats(staleness[t], live[t])
        if stats is not None:
            clock["lag_p99"], clock["lag_max"] = stats
        ev.append(clock)
        for p in range(P):
            if not live[t, p]:
                continue
            ev.append({
                "type": "worker_span", "t": t, "worker": p, "ts": ts,
                "dur": _r(tl["comp"][t, p] + tl["sync"][t, p]),
                "comp_s": _r(tl["comp"][t, p]),
                "sync_s": _r(tl["sync"][t, p]),
            })
            n_forced = int(forced[t, p].sum())
            if n_forced:
                lag = -1 - staleness[t, p]
                ev.append({
                    "type": "stale_read", "t": t, "worker": p, "ts": ts,
                    "n_forced": n_forced,
                    "max_lag": int(lag.max()),
                })
        if tiered and ship[t].any():
            # allocate the clock's wire seconds across the shipping
            # producers in proportion to their floats
            tot = ship[t].sum()
            for p in np.flatnonzero(ship[t] > 0):
                ev.append({
                    "type": "shipment", "t": t, "worker": int(p), "ts": ts,
                    "dur": _r(tl["wire"][t] * ship[t, p] / tot),
                    "floats": float(ship[t, p]),
                })
    if registry is not None:
        ev.append({"type": "metrics", "ts": _r(tl["end"][-1]),
                   "registry": registry.to_dict()})
    ev.append({
        "type": "run_end", "ts": _r(tl["end"][-1]),
        "wall_s": _r(tl["wall"].sum()), "comp_s": _r(tl["comp_clock"].sum()),
        "comm_s": _r(tl["comm_clock"].sum()), "wire_s": _r(tl["wire"].sum()),
        "clocks": T,
    })
    return ev


def check_version(events: list[dict]) -> tuple[int, int]:
    """The stream's ``(major, minor)``; `SchemaError` on major mismatch.

    Consumers (`repro.obs.monitor`, `repro.obs.diff`) call this before
    reading anything else: same major means every event type and field
    they know keeps its meaning; a newer minor only ever *adds*.
    """
    if not events:
        raise SchemaError("empty event stream")
    if events[0].get("type") != "run_start":
        raise SchemaError(f"stream must open with run_start, got "
                          f"{events[0].get('type')!r}")
    v = events[0].get("v")
    if v != SCHEMA_VERSION:
        raise SchemaError(f"major schema version {v!r} != {SCHEMA_VERSION} "
                          f"— incompatible stream")
    return v, events[0].get("vm", 0)


def _check_fields(e: dict, spec: dict, optional: dict, i: int,
                  etype: str) -> None:
    for field in spec:
        if field not in e:
            raise SchemaError(f"event {i} ({etype}): missing {field!r}")
    for field, ftype in [*spec.items(), *optional.items()]:
        if field not in e:
            continue                      # optional and absent
        v = e[field]
        ok = (isinstance(v, (int, float)) and not isinstance(v, bool)
              if ftype is float else isinstance(v, ftype))
        if not ok:
            raise SchemaError(f"event {i} ({etype}): {field}="
                              f"{v!r} is not {ftype.__name__}")


def validate_events(events: list[dict]) -> None:
    """Raise `SchemaError` unless ``events`` is a valid major-version-1
    stream (any minor — see the module's forward-compatibility policy)."""
    _, minor = check_version(events)
    if events[-1].get("type") != "run_end":
        raise SchemaError(f"stream must close with run_end, got "
                          f"{events[-1].get('type')!r}")
    n_clocks = events[0]["n_clocks"]
    last_t = -1
    for i, e in enumerate(events):
        etype = e.get("type")
        spec = SCHEMA.get(etype)
        if spec is None:
            if minor > SCHEMA_MINOR:
                continue    # a newer producer's additive event type
            raise SchemaError(f"event {i}: unknown type {etype!r} in a "
                              f"v{SCHEMA_VERSION}.{minor} stream (ours is "
                              f".{SCHEMA_MINOR})")
        _check_fields(e, spec, SCHEMA_OPTIONAL.get(etype, {}), i, etype)
        if "ts" in e and e["ts"] < 0:
            raise SchemaError(f"event {i} ({etype}): negative ts")
        if "t" in e:
            if not (0 <= e["t"] < n_clocks):
                raise SchemaError(f"event {i} ({etype}): clock {e['t']} "
                                  f"outside [0, {n_clocks})")
            if e["t"] < last_t:
                raise SchemaError(f"event {i} ({etype}): clock order "
                                  f"regressed ({e['t']} after {last_t})")
            last_t = e["t"]
        if i > 0 and etype == "run_start":
            raise SchemaError(f"event {i}: duplicate run_start")


def write_jsonl(events: list[dict], path) -> None:
    """One event per line; validates before writing."""
    validate_events(events)
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e, sort_keys=True) + "\n")


def read_jsonl(path) -> list[dict]:
    """Load and re-validate a stream written by ``write_jsonl``."""
    with open(path) as f:
        events = [json.loads(line) for line in f if line.strip()]
    validate_events(events)
    return events
