"""Telemetry substrate: on-device metrics, event streams, timelines.

The observability layer the ROADMAP's closed-loop controller and
failure-detection items read from.  Four modules, one boundary:

- ``metrics`` — the device-side accumulator pytree threaded through the
  engines' scan (zero host callbacks in the hot path) and the host-side
  ``MetricsRegistry`` it drains into;
- ``events`` — versioned, schema-checked JSONL event stream of a run
  (clocks, worker spans, shipments, churn transitions, stale reads) on
  the modeled timebase from `core.timemodel.TimeModel`;
- ``perfetto`` — Chrome/Perfetto ``trace_event`` export of that stream
  (per-worker clock lanes, shipment spans, outage windows, stale-read
  instants) for ``ui.perfetto.dev``;
- ``report`` — markdown run reports (staleness / throughput / wire
  tables per consistency family) for benchmarks and CI artifacts.

Enable collection by passing ``obs=ObsSpec()`` to ``core.ps.simulate``,
``PSRuntime.run``, ``PodsRuntime.run``, or ``core.sweep.sweep``; the
accumulators come back as ``Trace.obs``.  Disabled (the default) the
engines compile the exact pre-obs program — `Trace` is bit-identical.
"""
from .metrics import (DEFAULT_LAG_BUCKETS, MetricsRegistry, ObsSpec,
                      device_init, device_reduce, device_update,
                      drain_device, obs_on, record_compiles, record_timing)

__all__ = [
    "DEFAULT_LAG_BUCKETS", "MetricsRegistry", "ObsSpec", "device_init",
    "device_reduce", "device_update", "drain_device", "obs_on",
    "record_compiles", "record_timing",
]
