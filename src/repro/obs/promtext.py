"""OpenMetrics/Prometheus text exporter for `MetricsRegistry`.

The scrape-side sibling of the Perfetto exporter: ``render`` lays a
registry (or its ``to_dict()`` snapshot — what a stream's ``metrics``
event carries) out as OpenMetrics text, byte-deterministically
(sorted metric names, canonical number formatting), so the golden test
can pin the exact bytes the same way ``tests/golden/perfetto_small.json``
pins the trace export.

Mapping: counters become ``<name>_total`` counter families, gauges map
1:1, histograms become classic cumulative-``le`` bucket families with
``_count`` and a bucket-center-weighted ``_sum`` (the registry keeps
integer bucket counts, not raw samples — the sum is the standard
center-of-bucket estimate, exact for integer-valued histograms such as
``ps/staleness_lag``).  ``/``-separated registry paths are sanitized to
the OpenMetrics charset (``ps/forced_xpod`` -> ``ps_forced_xpod``); a
bucket label that does not parse as a number (the ``"15+"`` overflow) is
the ``+Inf`` bucket.  Output ends with the mandatory ``# EOF``.
Numpy/stdlib only.
"""
from __future__ import annotations

import re

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _name(raw: str) -> str:
    name = _NAME_RE.sub("_", raw)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _num(v) -> str:
    """Canonical OpenMetrics number: integral values render as integers."""
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _le(label: str) -> str:
    """Bucket upper bound from a registry bucket label (non-numeric
    labels — the trailing ``"15+"`` overflow — are the +Inf bucket)."""
    try:
        return _num(float(label))
    except ValueError:
        return "+Inf"


def render(registry) -> str:
    """Registry (or ``MetricsRegistry.to_dict()`` dict) -> OpenMetrics
    text, byte-deterministic."""
    snap = registry if isinstance(registry, dict) else registry.to_dict()
    counters = snap.get("counters", {})
    gauges = snap.get("gauges", {})
    hists = snap.get("hists", {})
    lines: list[str] = []

    for raw in sorted(counters):
        name = _name(raw)
        if name.endswith("_total"):     # family name must not carry the
            name = name[:-len("_total")]  # sample suffix (OpenMetrics)
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name}_total {_num(counters[raw])}")
    for raw in sorted(gauges):
        name = _name(raw)
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {_num(gauges[raw])}")
    for raw in sorted(hists):
        name = _name(raw)
        h = hists[raw]
        lines.append(f"# TYPE {name} histogram")
        cum = 0
        total = sum(h["counts"])
        seen_inf = False
        for label, count in zip(h["buckets"], h["counts"]):
            cum += count
            le = _le(str(label))
            seen_inf = seen_inf or le == "+Inf"
            lines.append(f'{name}_bucket{{le="{le}"}} {_num(cum)}')
        if not seen_inf:
            lines.append(f'{name}_bucket{{le="+Inf"}} {_num(total)}')
        lines.append(f"{name}_count {_num(total)}")
        centers = [_center(str(b)) for b in h["buckets"]]
        sum_est = sum(c * n for c, n in zip(centers, h["counts"]))
        lines.append(f"{name}_sum {_num(sum_est)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def _center(label: str) -> float:
    """Bucket-center estimate backing ``_sum`` (overflow labels such as
    ``"15+"`` contribute their threshold)."""
    try:
        return float(label)
    except ValueError:
        digits = re.sub(r"[^0-9.eE+-]", "", label).rstrip("+-")
        try:
            return float(digits)
        except ValueError:
            return 0.0


def write(path, registry) -> None:
    with open(path, "w") as f:
        f.write(render(registry))
