"""``python -m repro.obs`` — one entry point over JSONL stream artifacts.

Subcommands (numpy/stdlib — jax only loads for ``monitor --score``,
whose oracle scorer lives in `core.delays`; everything else runs
anywhere the CI artifacts land):

- ``tail FILE``      pretty-print a stream, newest-last; ``--type`` /
                     ``--worker`` filter, ``-n`` bounds the line count.
- ``validate FILE``  schema-check (`events.validate_events`); exit 1 on
                     the first violation.
- ``report FILE...`` markdown report over one or more streams
                     (`monitor.stream_summary` rows through
                     `report.render_report`).
- ``monitor FILE``   run the failure detector + SLO monitors
                     (`monitor.monitor_stream`); ``--score`` grades the
                     verdicts against the stream's own churn events as
                     oracle (`core.delays.score_detections` over
                     `monitor.live_from_events`); ``--actions`` runs the
                     recovery controller (`ctrl.recover.plan_recovery`)
                     and prints its decisions; ``--emit OUT`` writes
                     the stream with ``slo_violation`` (and, under
                     ``--actions``, ``recovery_action``) events spliced
                     in.  Exit 1 on ``--fail-on-false-alarm`` (scored
                     false alarm or missed outage), ``--fail-on-alarm``
                     (any worker_down — the neutral-artifact CI gate),
                     or, under ``--actions``, on any SLO violation the
                     controller left unrecovered.
- ``diff BASE CUR``  regression attribution (`repro.obs.diff`):
                     ``BENCH_*.json`` pairs via ``diff_bench``, JSONL
                     pairs via ``diff_streams``; ``--markdown`` renders
                     `report.attribution_table` instead of plain lines.
- ``prom FILE``      OpenMetrics text from the stream's ``metrics``
                     registry snapshot (`promtext.render`).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import diff as obs_diff
from . import promtext
from .events import SchemaError, validate_events
from .monitor import (DetectorParams, SLOParams, live_from_events,
                      monitor_stream, stream_summary)


def _load(path: str) -> list:
    """Parse a JSONL stream without validating — ``validate`` is its own
    subcommand, and the analysis paths check the version themselves."""
    try:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        raise SystemExit(2) from e


def cmd_tail(args) -> int:
    ev = _load(args.file)
    if args.type:
        ev = [e for e in ev if e.get("type") == args.type]
    if args.worker is not None:
        ev = [e for e in ev if e.get("worker") == args.worker]
    for e in ev[-args.n:]:
        ts = e.get("ts")
        stamp = "        —" if ts is None else f"{ts:9.4f}"
        rest = {k: v for k, v in e.items() if k not in ("type", "ts")}
        if e.get("type") == "metrics":
            rest = {"registry": f"<{len(e['registry'].get('counters', {}))}"
                                f" counters, ...>"}
        body = " ".join(f"{k}={v}" for k, v in rest.items())
        print(f"{stamp}  {e.get('type', '?'):13s} {body}")
    return 0


def cmd_validate(args) -> int:
    ev = _load(args.file)
    try:
        validate_events(ev)
    except SchemaError as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print(f"OK: {len(ev)} events, schema v{ev[0]['v']}"
          f".{ev[0].get('vm', 0)}")
    return 0


def cmd_report(args) -> int:
    from .report import render_report

    rows = [stream_summary(_load(p)) for p in args.files]
    print(render_report(args.title, rows))
    return 0


def _monitor_params(args):
    det = DetectorParams(timeout_clocks=args.timeout)
    slo = SLOParams(window=args.window,
                    staleness_bound=args.staleness_bound,
                    min_clocks_per_s=args.min_clocks_per_s,
                    max_floats_per_clock=args.max_floats_per_clock)
    return det, slo


def cmd_monitor(args) -> int:
    from ..core.delays import score_detections

    ev = _load(args.file)
    det, slo = _monitor_params(args)
    res = monitor_stream(ev, det, slo)
    for v in res.verdicts:
        print(f"t={v['t']:4d}  {v['kind']:12s} "
              + " ".join(f"{k}={v[k]}" for k in ("worker", "pod", "missed",
                                                 "phi") if k in v))
    for v in res.violations:
        print(f"t={v['t']:4d}  slo:{v['slo']:9s} value={v['value']:g} "
              f"limit={v['limit']:g} window={v['window']}")
    print(json.dumps({"health": res.health}, indent=2, default=str))

    failed = False
    actions = None
    if args.actions:
        from ..ctrl.recover import (attach_actions, plan_from_result,
                                    unrecovered_violations)

        actions = plan_from_result(res)
        for a in actions:
            print(f"t={a['t']:4d}  act:{a['action']:13s} "
                  + " ".join(f"{k}={a[k]}" for k in ("worker", "pod",
                                                     "quant", "agg_clocks",
                                                     "clocks", "reason")
                             if k in a))
        unrec = unrecovered_violations(res.violations, actions)
        if unrec:
            print(f"UNRECOVERED: {len(unrec)} slo_violation(s) after the "
                  f"last recovery action", file=sys.stderr)
            failed = True
        res.events = attach_actions(res.events, actions)
    if args.score:
        live = live_from_events(ev)
        score = score_detections(live, res.verdicts, args.budget)
        print(json.dumps({"score": score}, indent=2, default=str))
        if args.fail_on_false_alarm and (score["n_false_alarms"] > 0
                                         or score["n_missed"] > 0):
            failed = True
    elif args.fail_on_false_alarm:
        print("warning: --fail-on-false-alarm needs --score (oracle "
              "churn events) — gating on any alarm instead",
              file=sys.stderr)
        failed = failed or res.health["n_worker_down"] > 0
    if args.fail_on_alarm and res.health["n_worker_down"] > 0:
        failed = True
    if args.emit:
        from .events import write_jsonl

        write_jsonl(res.events, args.emit)
    return 1 if failed else 0


def cmd_diff(args) -> int:
    if args.base.endswith(".json") and args.cur.endswith(".json"):
        with open(args.base) as f:
            base = json.load(f)
        with open(args.cur) as f:
            cur = json.load(f)
        d = obs_diff.diff_bench(base, cur)
    else:
        d = obs_diff.diff_streams(_load(args.base), _load(args.cur),
                                  loss_thresh=args.loss_thresh)
    if args.markdown:
        from .report import attribution_table

        print(attribution_table(d))
    else:
        for line in obs_diff.explain(d, top=args.top):
            print(line)
    return 0


def cmd_prom(args) -> int:
    ev = _load(args.file)
    snap = None
    for e in ev:
        if e.get("type") == "metrics":
            snap = e["registry"]
    if snap is None:
        print("error: stream carries no metrics event", file=sys.stderr)
        return 1
    sys.stdout.write(promtext.render(snap))
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("tail", help="pretty-print / filter a stream")
    p.add_argument("file")
    p.add_argument("--type", help="keep only this event type")
    p.add_argument("--worker", type=int, help="keep only this worker")
    p.add_argument("-n", type=int, default=40, help="max lines")
    p.set_defaults(fn=cmd_tail)

    p = sub.add_parser("validate", help="schema-check a stream")
    p.add_argument("file")
    p.set_defaults(fn=cmd_validate)

    p = sub.add_parser("report", help="markdown report over streams")
    p.add_argument("files", nargs="+")
    p.add_argument("--title", default="obs stream report")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("monitor", help="failure detector + SLO monitors")
    p.add_argument("file")
    p.add_argument("--timeout", type=int, default=2,
                   help="missed-clock verdict trigger")
    p.add_argument("--window", type=int, default=8, help="SLO window")
    p.add_argument("--staleness-bound", type=int, default=None,
                   help="override the stream's declared bound")
    p.add_argument("--min-clocks-per-s", type=float, default=None)
    p.add_argument("--max-floats-per-clock", type=float, default=None)
    p.add_argument("--score", action="store_true",
                   help="grade verdicts against the stream's churn "
                        "events as oracle")
    p.add_argument("--budget", type=int, default=4,
                   help="clocks-to-detect budget for --score")
    p.add_argument("--fail-on-false-alarm", action="store_true",
                   help="exit 1 on a scored false alarm or missed outage")
    p.add_argument("--fail-on-alarm", action="store_true",
                   help="exit 1 on any worker_down (neutral artifacts)")
    p.add_argument("--actions", action="store_true",
                   help="run the recovery controller, print its "
                        "decisions; exit 1 on unrecovered violations")
    p.add_argument("--emit", help="write stream + slo_violation events")
    p.set_defaults(fn=cmd_monitor)

    p = sub.add_parser("diff", help="regression attribution")
    p.add_argument("base", help="baseline stream .jsonl or BENCH .json")
    p.add_argument("cur", help="current stream .jsonl or BENCH .json")
    p.add_argument("--loss-thresh", type=float, default=None,
                   help="attribute clocks-to-this-loss (streams only)")
    p.add_argument("--top", type=int, default=2,
                   help="components to explain")
    p.add_argument("--markdown", action="store_true")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("prom", help="OpenMetrics text from the stream's "
                                    "metrics snapshot")
    p.add_argument("file")
    p.set_defaults(fn=cmd_prom)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
