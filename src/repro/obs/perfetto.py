"""Chrome/Perfetto ``trace_event`` export of an obs event stream.

``from_events`` renders the JSONL stream of `repro.obs.events` into the
trace-event JSON that ``ui.perfetto.dev`` (or ``chrome://tracing``) opens
directly:

- **process 0 — the run**: one ``clock`` lane (complete ``X`` spans, one
  per clock with loss/fleet args) above a lane per worker carrying its
  per-clock step spans (modeled compute + blocking-fetch seconds),
  ``stale_read`` instants where the staleness bound tripped, and
  ``outage`` spans covering churn windows (death → rejoin, or run end);
  ``live_workers`` and ``loss_ref`` ride as counter tracks;
- **process 1 — the cross-pod wire**: a lane per producer with one
  ``ship`` span per shipment (duration = that producer's share of the
  clock's modeled wire seconds, args = floats on the wire).

Timestamps are the stream's modeled seconds converted to µs (the
trace-event unit) — the common `TimeModel` timebase, so lanes line up
with the wall-second benchmark claims.  Output is deterministic for a
given stream (events ordered as emitted, keys sorted by the writer);
``tests/test_obs.py`` pins a small golden.
"""
from __future__ import annotations

import json

# trace-event phase codes: X complete span, i instant, C counter, M metadata
_PID_RUN = 0
_PID_WIRE = 1


def _us(seconds: float) -> float:
    return round(float(seconds) * 1e6, 3)


def from_events(events: list[dict]) -> dict:
    """Build the Perfetto/Chrome trace dict for one validated stream."""
    head = events[0]
    if head.get("type") != "run_start":
        raise ValueError("event stream must open with run_start "
                         "(run it through events.validate_events)")
    P = head["n_workers"]
    run = head["run"]
    te: list[dict] = []

    def meta(pid, name, args, tid=None):
        e = {"ph": "M", "pid": pid, "name": name, "args": args}
        if tid is not None:
            e["tid"] = tid
        te.append(e)

    meta(_PID_RUN, "process_name", {"name": f"ps-run:{run} ({head['model']})"})
    meta(_PID_RUN, "thread_name", {"name": "clocks"}, tid=0)
    for p in range(P):
        meta(_PID_RUN, "thread_name", {"name": f"worker {p}"}, tid=p + 1)
    if head["n_pods"] > 1:
        meta(_PID_WIRE, "process_name",
             {"name": f"xpod-wire:{run} ({head['n_pods']} pods)"})
        for p in range(P):
            meta(_PID_WIRE, "thread_name", {"name": f"producer {p}"},
                 tid=p + 1)

    down_since: dict[int, float] = {}     # worker -> outage start (s)
    end_ts = events[-1]["ts"] if events[-1].get("type") == "run_end" else 0.0

    for e in events:
        t = e.get("type")
        if t == "clock":
            te.append({"ph": "X", "pid": _PID_RUN, "tid": 0,
                       "ts": _us(e["ts"]), "dur": _us(e["dur"]),
                       "name": f"clock {e['t']}", "cat": "clock",
                       "args": {"loss_ref": e["loss_ref"],
                                "forced": e["forced"],
                                "delivered": e["delivered"],
                                "live": e["live"],
                                "ship_floats": e["ship_floats"]}})
            te.append({"ph": "C", "pid": _PID_RUN, "tid": 0,
                       "ts": _us(e["ts"]), "name": "live_workers",
                       "args": {"live": e["live"]}})
            te.append({"ph": "C", "pid": _PID_RUN, "tid": 0,
                       "ts": _us(e["ts"]), "name": "loss_ref",
                       "args": {"loss": e["loss_ref"]}})
        elif t == "worker_span":
            te.append({"ph": "X", "pid": _PID_RUN, "tid": e["worker"] + 1,
                       "ts": _us(e["ts"]), "dur": _us(e["dur"]),
                       "name": "step", "cat": "worker",
                       "args": {"t": e["t"], "comp_s": e["comp_s"],
                                "sync_s": e["sync_s"]}})
        elif t == "stale_read":
            te.append({"ph": "i", "pid": _PID_RUN, "tid": e["worker"] + 1,
                       "ts": _us(e["ts"]), "s": "t",
                       "name": f"stale_read lag={e['max_lag']}",
                       "cat": "staleness",
                       "args": {"t": e["t"], "n_forced": e["n_forced"],
                                "max_lag": e["max_lag"]}})
        elif t == "shipment":
            te.append({"ph": "X", "pid": _PID_WIRE, "tid": e["worker"] + 1,
                       "ts": _us(e["ts"]), "dur": _us(e["dur"]),
                       "name": "ship", "cat": "wire",
                       "args": {"t": e["t"], "floats": e["floats"]}})
        elif t == "churn":
            if e["event"] == "down":
                down_since.setdefault(e["worker"], e["ts"])
            else:
                start = down_since.pop(e["worker"], None)
                if start is not None:
                    te.append(_outage(e["worker"], start, e["ts"]))
    # workers still down at run end: close their outage window at the end
    for p, start in sorted(down_since.items()):
        te.append(_outage(p, start, end_ts))

    return {"traceEvents": te, "displayTimeUnit": "ms",
            "otherData": {"schema": f"repro.obs v{head['v']}", "run": run}}


def _outage(worker: int, start_s: float, end_s: float) -> dict:
    return {"ph": "X", "pid": _PID_RUN, "tid": worker + 1,
            "ts": _us(start_s), "dur": _us(end_s - start_s),
            "name": "outage", "cat": "churn",
            "args": {"worker": worker}}


def write_trace(events: list[dict], path) -> dict:
    """Export ``events`` to a ``.perfetto.json`` file; returns the dict."""
    trace = from_events(events)
    with open(path, "w") as f:
        json.dump(trace, f, sort_keys=True, separators=(",", ":"))
    return trace
