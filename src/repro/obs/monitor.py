"""Streaming health layer: online failure detection + SLO monitoring.

The first *consumer* of the obs event stream (`repro.obs.events`): where
PR 6's elasticity relies on an oracle `core.delays.ChurnSchedule`, a real
parameter server has to *observe* failures and contract violations from
telemetry.  ``monitor_stream`` ingests one validated v1 stream — a list
already in memory, or any iterator of event dicts (``read_jsonl``, a live
tail) — checks the schema version (:func:`events.check_version`), and
runs two engines over it in a single pass:

**Online failure detector** (:class:`FailureDetector`).  Liveness is
scored from cadence only — ``clock`` events are the server's own global
heartbeat, ``worker_span`` events are the workers' — never from the
stream's ``churn`` events, which are oracle ground truth and reserved
for *scoring* the detector (`core.delays.score_detections`).  Two
signals per worker:

- ``missed`` — whole clocks since the worker's last span, evaluated at
  every clock event.  The verdict trigger: ``missed >=
  timeout_clocks`` raises ``worker_down`` (and ``pod_down`` once every
  worker of a pod is suspected); the first span from a suspected worker
  raises ``worker_up``.  A live worker emits a span every clock it is
  live, so healthy ``missed`` is identically 0 — neutral schedules
  raise zero alarms at *any* timeout setting (hypothesis-pinned).
- ``phi`` — a phi-accrual suspicion score (Hayashibara et al., the
  detector Cassandra/Akka ship) on the modeled-seconds axis, where
  straggler noise actually lives.  The silence of worker ``p`` at clock
  start is normalized by the *current clock wall* (the gap between the
  last two clock events), so a cross-pod bandwidth crunch that stretches
  every clock stretches the yardstick with it; the score is
  ``-log10 P(silence >= observed)`` under a normal fit to the worker's
  recent normalized heartbeat gaps.  Phi is evidence, not the trigger:
  `benchmarks.detect_bench` measures the separation between the weakest
  true-death phi and the noisiest healthy phi, making "timeouts in
  seconds would also have worked" a claim with a number on it.

**SLO monitors** (:class:`SLOMonitor`).  Tumbling ``window``-clock checks
emitting ``slo_violation`` events back into the stream (schema minor 1):

- ``staleness`` — the window's worst per-clock p99 read lag
  (``clock.lag_p99``) must stay within the declared
  ``s + s_xpod + agg_clocks - 1`` contract (``run_start.bound``, or an
  explicit tighter SLO);
- ``throughput`` — windowed clocks/sec on the modeled timebase must not
  fall below the floor;
- ``wire`` — windowed mean floats-on-wire per clock must stay inside
  the budget.

``monitor_stream`` returns a :class:`MonitorResult`: the verdict and
violation lists, a health summary, and the input stream with the
``slo_violation`` events spliced in at their clock positions (still
schema-valid — ``events.validate_events`` accepts what we emit).
Everything here is numpy/stdlib only: consumers of the stream never need
jax.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass

PHI_CAP = 40.0          # -log10 of the smallest probability we resolve


@dataclass(frozen=True)
class DetectorParams:
    """Failure-detector knobs (see module doc for the two signals)."""

    timeout_clocks: int = 2     # missed-clock verdict trigger
    phi_window: int = 12        # recent heartbeat gaps kept per worker
    phi_min_samples: int = 3    # gaps needed before phi is scored
    phi_sigma_floor: float = 0.1  # std floor (normalized clock units)

    def __post_init__(self):
        if self.timeout_clocks < 1:
            raise ValueError("timeout_clocks must be >= 1")


@dataclass(frozen=True)
class SLOParams:
    """Windowed SLO thresholds; ``None`` disables a check.

    ``staleness_bound=None`` falls back to the stream's declared
    contract (``run_start.bound``) when the header carries one.
    """

    window: int = 8
    staleness_bound: int | None = None
    min_clocks_per_s: float | None = None
    max_floats_per_clock: float | None = None


@dataclass
class MonitorResult:
    verdicts: list        # worker_down / worker_up / pod_down / pod_up
    violations: list      # slo_violation event dicts (also in .events)
    health: dict          # run-level summary (see monitor_stream doc)
    events: list          # input stream + slo_violation events, in order


def _phi_normal(elapsed: float, mu: float, sigma: float,
                sigma_floor: float = 0.1) -> float:
    sigma = max(sigma, sigma_floor)
    p_later = 0.5 * math.erfc((elapsed - mu) / (sigma * math.sqrt(2.0)))
    if p_later <= 10.0 ** -PHI_CAP:
        return PHI_CAP
    return -math.log10(p_later)


class FailureDetector:
    """Per-worker / per-pod liveness scoring from stream cadence.

    Feed it ``run_start`` / ``clock`` / ``worker_span`` events in stream
    order (``observe``); it appends verdicts to ``self.verdicts``.  The
    churn events of the stream must *not* be fed — the detector's whole
    point is to reconstruct them from cadence (``score_detections``
    checks how well).
    """

    def __init__(self, params: DetectorParams | None = None):
        self.p = params or DetectorParams()
        self.verdicts: list = []
        self.max_healthy_phi = 0.0      # noisiest live worker ever scored
        self._started = False

    def _start(self, head: dict) -> None:
        P, n_pods = head["n_workers"], head["n_pods"]
        self.P, self.n_pods = P, n_pods
        self.pod = [p // (P // n_pods) for p in range(P)]
        self.last_clock = [-1] * P      # clock of the last span seen
        self.last_arrival = [0.0] * P   # modeled-seconds heartbeat time
        self.gaps = [deque(maxlen=self.p.phi_window) for _ in range(P)]
        self.suspected = [False] * P
        self.pod_suspected = [False] * n_pods
        self.prev_clock_ts: float | None = None
        self.last_wall = 0.0            # gap between the last two clocks
        self._started = True

    # ------------------------------------------------------------ scoring
    def _score(self, worker: int, now_ts: float, wall: float) -> float:
        """Phi of the worker's current silence, normalized by the current
        clock wall (``wall`` = the last clock-event gap)."""
        gaps = self.gaps[worker]
        if len(gaps) < self.p.phi_min_samples or wall <= 0.0:
            return 0.0
        elapsed = (now_ts - self.last_arrival[worker]) / wall
        mu = sum(gaps) / len(gaps)
        var = sum((g - mu) ** 2 for g in gaps) / len(gaps)
        return _phi_normal(elapsed, mu, math.sqrt(var),
                           self.p.phi_sigma_floor)

    def _evaluate(self, t: int, ts: float, wall: float) -> None:
        """Liveness pass at clock event ``t`` (all spans of ``t-1`` have
        been observed; spans of ``t`` have not)."""
        for w in range(self.P):
            if self.suspected[w]:
                continue
            missed = (t - 1) - self.last_clock[w]
            phi = self._score(w, ts, wall)
            if missed >= self.p.timeout_clocks:
                self.suspected[w] = True
                self.verdicts.append({
                    "kind": "worker_down", "worker": w, "t": t, "ts": ts,
                    "missed": missed, "phi": phi})
            else:
                self.max_healthy_phi = max(self.max_healthy_phi, phi)
        for g in range(self.n_pods):
            down = all(self.suspected[w] for w in range(self.P)
                       if self.pod[w] == g)
            if down and not self.pod_suspected[g]:
                self.pod_suspected[g] = True
                self.verdicts.append({"kind": "pod_down", "pod": g,
                                      "t": t, "ts": ts})

    # ----------------------------------------------------------- ingest
    def observe(self, e: dict) -> None:
        etype = e.get("type")
        if etype == "run_start":
            self._start(e)
            return
        if not self._started:
            raise ValueError("stream must open with run_start")
        if etype == "clock":
            self.last_wall = (0.0 if self.prev_clock_ts is None
                              else e["ts"] - self.prev_clock_ts)
            if e["t"] > 0:
                self._evaluate(e["t"], e["ts"], self.last_wall)
            self.prev_clock_ts = e["ts"]
        elif etype == "worker_span":
            w = e["worker"]
            arrival = e["ts"] + e["dur"]
            if self.suspected[w]:
                self.suspected[w] = False
                self.verdicts.append({"kind": "worker_up", "worker": w,
                                      "t": e["t"], "ts": arrival})
                g = self.pod[w]
                if self.pod_suspected[g]:
                    self.pod_suspected[g] = False
                    self.verdicts.append({"kind": "pod_up", "pod": g,
                                          "t": e["t"], "ts": arrival})
                # the outage gap is not a heartbeat interval: resume the
                # phi statistics from the rejoin heartbeat instead
            elif self.last_clock[w] >= 0 and self.last_wall > 0.0:
                self.gaps[w].append(
                    (arrival - self.last_arrival[w]) / self.last_wall)
            self.last_clock[w] = e["t"]
            self.last_arrival[w] = arrival


class SLOMonitor:
    """Tumbling-window SLO checks over the clock events (module doc)."""

    def __init__(self, params: SLOParams | None = None,
                 declared_bound: int | None = None):
        self.p = params or SLOParams()
        self.bound = (self.p.staleness_bound
                      if self.p.staleness_bound is not None
                      else declared_bound)
        self.violations: list = []
        self._win: list = []            # buffered clock events

    def observe(self, e: dict) -> None:
        if e.get("type") != "clock":
            return
        self._win.append(e)
        if len(self._win) >= self.p.window:
            self._close()

    def finish(self) -> None:
        """Evaluate the final partial window (if any clocks are buffered)."""
        if self._win:
            self._close()

    def _close(self) -> None:
        win, self._win = self._win, []
        last = win[-1]
        t, ts = last["t"], last["ts"] + last["dur"]
        n = len(win)

        def violate(slo: str, value: float, limit: float) -> None:
            self.violations.append({
                "type": "slo_violation", "t": t, "ts": round(ts, 9),
                "slo": slo, "window": n, "value": round(float(value), 9),
                "limit": round(float(limit), 9)})

        if self.bound is not None:
            p99s = [c["lag_p99"] for c in win if "lag_p99" in c]
            if p99s and max(p99s) > self.bound:
                violate("staleness", max(p99s), self.bound)
        if self.p.min_clocks_per_s is not None:
            dur = sum(c["dur"] for c in win)
            rate = n / dur if dur > 0 else float("inf")
            if rate < self.p.min_clocks_per_s:
                violate("throughput", rate, self.p.min_clocks_per_s)
        if self.p.max_floats_per_clock is not None:
            mean_floats = sum(c["ship_floats"] for c in win) / n
            if mean_floats > self.p.max_floats_per_clock:
                violate("wire", mean_floats, self.p.max_floats_per_clock)


def live_from_events(events) -> "list[list[bool]]":
    """Reconstruct the oracle ``live[T][P]`` mask from the stream's
    ``churn`` transitions — the scoring ground truth when the original
    `ChurnSchedule` is not at hand (the CLI's ``monitor --score``)."""
    head = events[0]
    T, P = head["n_clocks"], head["n_workers"]
    live = [[True] * P for _ in range(T)]
    for e in events:
        if e.get("type") == "churn":
            alive = e["event"] == "up"
            for t in range(e["t"], T):
                live[t][e["worker"]] = alive
    return live


def monitor_stream(events, detector: DetectorParams | None = None,
                   slo: SLOParams | None = None) -> MonitorResult:
    """Run the failure detector + SLO monitors over one event stream.

    ``events`` is a list or iterator of event dicts opening with
    ``run_start`` (major version checked).  Returns a `MonitorResult`
    whose ``events`` is the input with ``slo_violation`` events spliced
    in at their window-closing clocks, and whose ``health`` summarizes:
    verdict/violation counts, final suspected set, and the phi evidence
    (``max_healthy_phi``, ``min_alarm_phi``) the detection-quality claim
    is scored on.
    """
    from .events import check_version

    events = list(events)
    check_version(events)
    det = FailureDetector(detector)
    slo_mon = SLOMonitor(slo, declared_bound=events[0].get("bound"))
    for e in events:
        if e.get("type") in ("run_start", "clock", "worker_span"):
            det.observe(e)
        slo_mon.observe(e)
    slo_mon.finish()

    out, by_clock = [], {}
    for v in slo_mon.violations:
        by_clock.setdefault(v["t"], []).append(v)
    for e in events:                     # splice violations after their clock
        out.append(e)
        if e.get("type") == "clock":
            out.extend(by_clock.pop(e["t"], []))
    for t in sorted(by_clock):           # defensive: never drop a verdict
        out[-1:-1] = by_clock[t]

    alarms = [v for v in det.verdicts if v["kind"] == "worker_down"]
    health = {
        "n_worker_down": len(alarms),
        "n_worker_up": sum(v["kind"] == "worker_up" for v in det.verdicts),
        "n_pod_down": sum(v["kind"] == "pod_down" for v in det.verdicts),
        "n_slo_violations": len(slo_mon.violations),
        "violations_by_slo": _count_by(slo_mon.violations, "slo"),
        "suspected_at_end": [w for w, s in enumerate(det.suspected) if s],
        "max_healthy_phi": det.max_healthy_phi,
        "min_alarm_phi": (min(v["phi"] for v in alarms) if alarms
                          else None),
    }
    return MonitorResult(verdicts=det.verdicts,
                         violations=slo_mon.violations,
                         health=health, events=out)


def _count_by(items, key) -> dict:
    out: dict = {}
    for it in items:
        out[it[key]] = out.get(it[key], 0) + 1
    return out


def stream_summary(events) -> dict:
    """One stream -> a `repro.obs.report.trace_summary`-shaped row,
    derived from events alone (no `Trace`, no `TimeModel`): what the CLI
    ``report`` subcommand renders for a JSONL artifact.  Fields the
    stream cannot carry (e.g. ``lag_mean`` — only per-clock p99s are
    streamed) are ``None``; the tier split of forced refreshes comes
    from the ``metrics`` registry snapshot when one rode along.
    """
    from .events import check_version

    events = list(events)
    check_version(events)
    head = events[0]
    clocks = [e for e in events if e.get("type") == "clock"]
    end = events[-1] if events[-1].get("type") == "run_end" else None
    counters = {}
    for e in events:
        if e.get("type") == "metrics":
            counters = e["registry"].get("counters", {})
    P = head["n_workers"]
    lag_p99s = [c["lag_p99"] for c in clocks if "lag_p99" in c]
    lag_maxs = [c["lag_max"] for c in clocks if "lag_max" in c]
    wall_s = end["wall_s"] if end else sum(c["dur"] for c in clocks)
    return {
        "label": head["run"], "model": head["model"],
        "family": head["family"], "clocks": head["n_clocks"],
        "loss_final": clocks[-1]["loss_ref"] if clocks else None,
        "lag_mean": None,
        "lag_p99": max(lag_p99s) if lag_p99s else None,
        "lag_max": max(lag_maxs) if lag_maxs else None,
        "forced_intra": counters.get("ps/forced_intra"),
        "forced_xpod": counters.get("ps/forced_xpod"),
        "delivered": sum(c["delivered"] for c in clocks),
        "ship_floats": sum(c["ship_floats"] for c in clocks),
        "dead_worker_clocks": sum(P - c["live"] for c in clocks),
        "wall_s": wall_s,
        "comp_s": end["comp_s"] if end else None,
        "comm_s": end["comm_s"] if end else None,
        "wire_s": end["wire_s"] if end else None,
        "clocks_per_s": (len(clocks) / wall_s if wall_s else None),
    }
