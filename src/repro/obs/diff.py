"""Regression attribution: explain *why* two runs differ, not just that
they do.

`benchmarks.compare` flags a tripped perf gate; this module answers the
follow-up question.  Two inputs, one vocabulary:

- **Streams** (`run_profile` / `diff_streams`): reduce each run's JSONL
  event stream to a component profile, then attribute the headline delta
  (clocks-to-loss when a threshold is given, modeled wall seconds
  otherwise) across four components:

  - ``staleness`` — mean per-clock p99 read lag (``clock.lag_p99``),
    falling back to forced-refresh counts when the stream predates the
    lag fields;
  - ``straggler`` — worker-span spread (mean over clocks of
    ``max dur / mean dur`` across that clock's spans): regime shifts
    widen the spread without moving the mean much;
  - ``wire`` — floats shipped per clock (``clock.ship_floats``),
    falling back to ``run_end.wire_s``;
  - ``churn`` — dead worker-clock *fraction* (an absolute delta — the
    baseline is usually churn-free, so a relative delta is undefined).

  The wall-second split is exact — ``Δwall = Δcomp + Δcomm`` holds to
  rounding because ``run_end`` decomposes wall that way — while the
  component *shares* are indicator-scored: each component's share of the
  attributed delta is its normalized indicator movement, an honest
  heuristic (reported as shares, never as seconds) for pointing a human
  at the right subsystem first.

- **BENCH records** (`diff_bench`): map each ``BENCH_*.json`` metric to
  a component by name, score components by their largest relative metric
  movement, and rank.  `benchmarks.compare` calls this to annotate every
  regressed record with the likely component and its driver metric.

`repro.obs.report.attribution_table` renders either result as markdown.
Numpy/stdlib only — stream consumers never need jax.
"""
from __future__ import annotations

COMPONENTS = ("staleness", "straggler", "wire", "churn")

# BENCH metric-name fragments -> component (first match wins, in order).
_BENCH_PATTERNS = (
    ("churn", ("churn", "dead", "recover", "lost", "detect", "outage",
               "false_alarm", "alarm")),
    ("wire", ("floats", "wire", "bytes", "compress", "ship", "quant",
              "topk")),
    ("straggler", ("straggler", "comp_s", "span", "slowdown")),
    ("staleness", ("lag", "stale", "forced", "bound", "refresh")),
)


def component_of(metric: str) -> str:
    """Component a BENCH metric name belongs to (``"other"`` if none)."""
    low = metric.lower()
    for comp, toks in _BENCH_PATTERNS:
        if any(tok in low for tok in toks):
            return comp
    return "other"


def _rel(base, cur):
    """Relative delta, ``None`` when undefined (missing / zero base)."""
    if base is None or cur is None:
        return None
    if not isinstance(base, (int, float)) or isinstance(base, bool):
        return None
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        return None
    if base == 0:
        return None
    return (cur - base) / abs(base)


# ------------------------------------------------------------- streams

def run_profile(events, loss_thresh: float | None = None) -> dict:
    """One event stream -> the flat component profile ``diff_profiles``
    consumes.  ``loss_thresh`` adds ``clocks_to_loss`` (first clock whose
    ``loss_ref`` reaches the threshold; ``None`` if never)."""
    from .events import check_version

    events = list(events)
    check_version(events)
    head = events[0]
    T, P = head["n_clocks"], head["n_workers"]
    clocks = [e for e in events if e.get("type") == "clock"]
    end = events[-1] if events[-1].get("type") == "run_end" else None

    spans_by_t: dict = {}
    for e in events:
        if e.get("type") == "worker_span":
            spans_by_t.setdefault(e["t"], []).append(e["dur"])
    spreads = [max(durs) / (sum(durs) / len(durs))
               for durs in spans_by_t.values()
               if durs and sum(durs) > 0]

    clocks_to_loss = None
    if loss_thresh is not None:
        for c in clocks:
            if c["loss_ref"] <= loss_thresh:
                clocks_to_loss = c["t"] + 1
                break

    lag_p99s = [c["lag_p99"] for c in clocks if "lag_p99" in c]
    wall_s = end["wall_s"] if end else sum(c["dur"] for c in clocks)
    n = len(clocks) or 1
    return {
        "run": head["run"], "model": head["model"], "clocks": T,
        "n_workers": P,
        "loss_final": clocks[-1]["loss_ref"] if clocks else None,
        "loss_thresh": loss_thresh, "clocks_to_loss": clocks_to_loss,
        "wall_s": wall_s,
        "comp_s": end["comp_s"] if end else None,
        "comm_s": end["comm_s"] if end else None,
        "wire_s": end["wire_s"] if end else None,
        "clocks_per_s": (len(clocks) / wall_s if wall_s else None),
        "lag_p99_mean": (sum(lag_p99s) / len(lag_p99s)
                         if lag_p99s else None),
        "lag_p99_max": max(lag_p99s) if lag_p99s else None,
        "forced_per_clock": sum(c["forced"] for c in clocks) / n,
        "ship_floats_per_clock": (sum(c["ship_floats"]
                                      for c in clocks) / n),
        "span_spread": (sum(spreads) / len(spreads) if spreads else None),
        "dead_frac": sum(P - c["live"] for c in clocks) / (n * P),
    }


def _indicators(base: dict, cur: dict) -> dict:
    """Per-component indicator movement between two profiles.

    Each entry: ``{indicator, base, cur, rel}`` where ``rel`` is the
    relative delta (churn uses the absolute dead-fraction delta — the
    baseline fraction is usually exactly 0).
    """
    def pick(names):
        for name in names:
            if base.get(name) is not None and cur.get(name) is not None:
                return name
        return names[0]

    out = {}
    k = pick(("lag_p99_mean", "forced_per_clock"))
    out["staleness"] = {"indicator": k, "base": base.get(k),
                        "cur": cur.get(k), "rel": _rel(base.get(k),
                                                       cur.get(k))}
    k = pick(("span_spread", "comp_s"))
    out["straggler"] = {"indicator": k, "base": base.get(k),
                        "cur": cur.get(k), "rel": _rel(base.get(k),
                                                        cur.get(k))}
    k = pick(("ship_floats_per_clock", "wire_s"))
    out["wire"] = {"indicator": k, "base": base.get(k),
                   "cur": cur.get(k), "rel": _rel(base.get(k),
                                                  cur.get(k))}
    b, c = base.get("dead_frac"), cur.get("dead_frac")
    out["churn"] = {"indicator": "dead_frac", "base": b, "cur": c,
                    "rel": (None if b is None or c is None else c - b)}
    return out


def diff_profiles(base: dict, cur: dict) -> dict:
    """Attribute the headline delta between two `run_profile` rows.

    Picks clocks-to-loss as the attributed quantity when both profiles
    carry one, modeled wall seconds otherwise.  Component shares are the
    normalized absolute indicator movements (`_indicators`); the wall
    split (``Δwall = Δcomp + Δcomm``) is exact.
    """
    if (base.get("clocks_to_loss") is not None
            and cur.get("clocks_to_loss") is not None):
        target = "clocks_to_loss"
    else:
        target = "wall_s"
    t_base, t_cur = base.get(target), cur.get(target)
    t_delta = (None if t_base is None or t_cur is None
               else t_cur - t_base)

    comps = _indicators(base, cur)
    weights = {k: abs(v["rel"]) if v["rel"] is not None else 0.0
               for k, v in comps.items()}
    total = sum(weights.values())
    for k, v in comps.items():
        v["share"] = (weights[k] / total) if total > 0 else 0.0

    wall = {key: {"base": base.get(key), "cur": cur.get(key),
                  "delta": (None if base.get(key) is None
                            or cur.get(key) is None
                            else cur[key] - base[key])}
            for key in ("wall_s", "comp_s", "comm_s", "wire_s")}
    ranked = sorted(comps, key=lambda k: -comps[k]["share"])
    return {
        "kind": "streams", "base_run": base.get("run"),
        "cur_run": cur.get("run"), "target": target,
        "target_base": t_base, "target_cur": t_cur,
        "target_delta": t_delta, "components": comps,
        "ranked": ranked, "wall": wall,
    }


def diff_streams(base_events, cur_events,
                 loss_thresh: float | None = None) -> dict:
    """`run_profile` + `diff_profiles` over two event streams."""
    return diff_profiles(run_profile(base_events, loss_thresh),
                         run_profile(cur_events, loss_thresh))


# ------------------------------------------------------- BENCH records

def diff_bench(base: dict, cur: dict) -> dict:
    """Attribute a ``BENCH_*.json`` pair's movement across components.

    Every shared non-``meta.`` metric gets a relative delta and a
    component (`component_of`); each component is scored by its largest
    absolute relative movement, whose metric becomes the component's
    ``driver``.  Claims that flipped True -> False are listed with their
    component — a flipped claim pins its component to the top of the
    ranking even when the metric movements are small.
    """
    bm, cm = base.get("metrics", {}), cur.get("metrics", {})
    comps: dict = {c: {"score": 0.0, "driver": None, "driver_rel": None,
                       "metrics": []} for c in (*COMPONENTS, "other")}
    for name in sorted(set(bm) & set(cm)):
        if name.startswith("meta."):
            continue
        rel = _rel(bm[name], cm[name])
        if rel is None:
            continue
        comp = comps[component_of(name)]
        comp["metrics"].append((name, bm[name], cm[name], rel))
        if abs(rel) > comp["score"]:
            comp["score"] = abs(rel)
            comp["driver"], comp["driver_rel"] = name, rel

    flipped = []
    for name, was in _flat_claims(base.get("claim", {})).items():
        now = _flat_claims(cur.get("claim", {})).get(name)
        if was is True and now is False:
            flipped.append((name, component_of(name)))
            comps[component_of(name)]["score"] = float("inf")

    ranked = sorted((c for c in comps if comps[c]["score"] > 0),
                    key=lambda c: -comps[c]["score"])
    return {"kind": "bench", "bench": cur.get("bench"),
            "components": comps, "ranked": ranked,
            "flipped_claims": flipped}


def _flat_claims(claim, prefix: str = "") -> dict:
    out: dict = {}
    if isinstance(claim, dict):
        for k, v in claim.items():
            out.update(_flat_claims(v, f"{prefix}.{k}" if prefix else
                                    str(k)))
    elif isinstance(claim, bool):
        out[prefix] = claim
    return out


def explain(diff: dict, top: int = 2) -> list[str]:
    """Human-readable attribution lines for either diff kind."""
    lines = []
    if diff["kind"] == "streams":
        d = diff["target_delta"]
        if d is not None:
            lines.append(
                f"{diff['target']}: {diff['target_base']:g} -> "
                f"{diff['target_cur']:g} ({d:+g})")
        for name in diff["ranked"][:top]:
            c = diff["components"][name]
            if c["share"] <= 0:
                continue
            rel = c["rel"]
            moved = "" if rel is None else f" ({rel:+.1%})"
            lines.append(f"{name}: share {c['share']:.0%} via "
                         f"{c['indicator']} {c['base']} -> "
                         f"{c['cur']}{moved}")
    else:
        for name, comp in diff["flipped_claims"]:
            lines.append(f"claim {name} flipped -> component {comp}")
        for name in diff["ranked"][:top]:
            c = diff["components"][name]
            if c["driver"] is None:
                continue
            lines.append(f"{name}: driver {c['driver']} "
                         f"({c['driver_rel']:+.1%})")
    return lines or ["no attributable movement"]
