"""Low-rank matrix factorization via SGD on the parameter server.

This is the paper's primary SGD benchmark (Netflix, rank 100).  We scale it
down to laptop size but keep the *exact* update equations of the paper:

    L_i*  <- L_i* + γ (e_ij R_*j^T − λ L_i*)
    R_*j  <- R_*j + γ (e_ij L_i*^T − λ R_*j)      e_ij = D_ij − L_i* R_*j

Both factor matrices live on the PS (packed into the flat vector); the
observed ratings are partitioned by rows across workers — data parallelism —
exactly as described in the paper.  Each clock a worker processes a
fixed-size minibatch of its own ratings and INCs the resulting additive
deltas.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core.ps import PSApp
from ..core.timemodel import TimeModel


def mf_time_model(**kw) -> TimeModel:
    """Paper-class wall-clock constants for the MF/SGD app.

    The 1 GbE defaults of `TimeModel` already describe the paper's MF
    cluster (50 ms SGD clocks, ~4 MB of factor rows per producer); this is
    the single place benchmarks get them from, so the Fig 2 time axis and
    the auto-tuner stay on the same constants.
    """
    return TimeModel(**kw)


@dataclass(frozen=True)
class MFConfig:
    n_rows: int = 240
    n_cols: int = 240
    rank: int = 24           # K — lifted from 12 once the ring-view kernel
                             # streamed d-blocks (ROADMAP d-scaling): the
                             # benchmarks are view-bound, not compile-bound,
                             # so doubling d costs ~linear sim time (see the
                             # d-scaling profile in benchmarks/sweep_bench.py)
    true_rank: int = 12
    density: float = 0.18    # fraction of observed entries
    noise: float = 0.01
    n_workers: int = 8
    batch: int = 128         # ratings per worker per clock
    lr: float = 0.7          # γ (absorbs constants, as in the paper; chosen
                             # "large while still converging with staleness 0")
    lr_decay: bool = True    # γ_t = γ / sqrt(1 + t)
    lam: float = 1e-4        # λ
    init_scale: float = 0.1
    seed: int = 0


def _pack(L, R):
    return jnp.concatenate([L.ravel(), R.ravel()])


def make_mf_app(cfg: MFConfig) -> PSApp:
    n, m, k, P = cfg.n_rows, cfg.n_cols, cfg.rank, cfg.n_workers
    rng = jax.random.PRNGKey(cfg.seed)
    k_t, k_o, k_n, k_i = jax.random.split(rng, 4)

    # Synthetic ground truth and observations.
    kL, kR = jax.random.split(k_t)
    Lstar = jax.random.normal(kL, (n, cfg.true_rank)) / jnp.sqrt(cfg.true_rank)
    Rstar = jax.random.normal(kR, (cfg.true_rank, m)) / jnp.sqrt(cfg.true_rank)
    D = Lstar @ Rstar + cfg.noise * jax.random.normal(k_n, (n, m))

    # Observed entries, partitioned by row blocks across workers (the paper
    # partitions data across machines; row blocks keep L-updates local-ish
    # while R rows are contended — the interesting PS case).
    assert n % P == 0, "n_rows must divide by n_workers"
    rows_per = n // P
    n_obs_per = int(rows_per * m * cfg.density)
    keys = jax.random.split(k_o, P)

    def sample_worker(key, w):
        ki, kj = jax.random.split(key)
        ii = jax.random.randint(ki, (n_obs_per,), 0, rows_per) + w * rows_per
        jj = jax.random.randint(kj, (n_obs_per,), 0, m)
        return ii.astype(jnp.int32), jj.astype(jnp.int32)

    ii, jj = jax.vmap(sample_worker)(keys, jnp.arange(P))
    vv = D[ii, jj]                                       # [P, n_obs_per]

    kLi, kRi = jax.random.split(k_i)
    L0 = cfg.init_scale * jax.random.normal(kLi, (n, k))
    R0 = cfg.init_scale * jax.random.normal(kRi, (k, m))

    def unpack(x):
        return x[: n * k].reshape(n, k), x[n * k:].reshape(k, m)

    def worker_update(view, local, _wid, clock, rng):
        L, R = unpack(view)
        gamma = cfg.lr / jnp.sqrt(1.0 + clock) if cfg.lr_decay else cfg.lr
        idx = jax.random.randint(rng, (cfg.batch,), 0, n_obs_per)
        i, j, v = local["ii"][idx], local["jj"][idx], local["vv"][idx]
        Li = L[i]                      # [B, k]
        Rj = R[:, j].T                 # [B, k]
        e = v - jnp.sum(Li * Rj, axis=-1)
        dL = jnp.zeros_like(L).at[i].add(gamma * (e[:, None] * Rj - cfg.lam * Li))
        dR = jnp.zeros_like(R).at[:, j].add(
            (gamma * (e[:, None] * Li - cfg.lam * Rj)).T)
        return _pack(dL, dR), local

    all_i, all_j, all_v = ii.ravel(), jj.ravel(), vv.ravel()

    def loss(x, locals_):
        del locals_
        L, R = unpack(x)
        pred = jnp.sum(L[all_i] * R[:, all_j].T, axis=-1)
        return jnp.mean(jnp.square(all_v - pred))

    local0 = {"ii": ii, "jj": jj, "vv": vv}
    return PSApp(name="matfact", dim=(n + m) * k, n_workers=P,
                 x0=_pack(L0, R0), local0=local0,
                 worker_update=worker_update, loss=loss)


def sequential_baseline(cfg: MFConfig, n_clocks: int):
    """Single-worker (strongly consistent) reference: same app with P=1
    doing P*batch ratings per clock.  Used as the gold standard in tests."""
    import dataclasses
    c1 = dataclasses.replace(cfg, n_workers=1, batch=cfg.batch * cfg.n_workers)
    app = make_mf_app(c1)
    from ..core.consistency import bsp
    from ..core.ps import simulate
    return simulate(app, bsp(), n_clocks)
