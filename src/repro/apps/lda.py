"""LDA topic modeling via collapsed Gibbs sampling on the parameter server.

The paper's second benchmark (NYT, K=100, 50% minibatch per clock).  The
shared PS state is the topic-word count table ``n_kw`` (K×V, additive count
deltas = INC updates); the doc-topic counts ``n_dk`` and topic assignments
``z`` are worker-local, exactly like Yahoo-LDA / ESSPTable's LDA app.  Each
clock a worker resamples a minibatch of its tokens against its (possibly
stale) view of ``n_kw``:

    p(z = k) ∝ (n_dk + α) (ñ_kw + β) / (ñ_k + Vβ)

and sends the count deltas to the server.  Sampling within a minibatch is
done against frozen counts (standard in distributed LDA samplers, e.g. plda)
— the PS staleness applies *between* clocks, which is what the paper
studies.  Quality metric: predictive log-likelihood of the whole corpus
under point estimates of θ, φ.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core.ps import PSApp
from ..core.timemodel import TimeModel


def lda_time_model(**kw) -> TimeModel:
    """Paper-class wall-clock constants for the LDA/Gibbs app.

    A Gibbs clock resamples half of each worker's tokens, so it costs more
    compute than an SGD minibatch (t_comp = 0.2 s), while a producer's
    per-clock count deltas are sparser than MF factor rows (2 MB per
    channel).  Single source of truth for every LDA time axis (Fig 2,
    comm/comp split, auto-tuner).
    """
    kw.setdefault("t_comp", 0.2)
    kw.setdefault("bytes_per_channel", 2e6)
    return TimeModel(**kw)


@dataclass(frozen=True)
class LDAConfig:
    n_docs: int = 64          # total documents (divisible by n_workers)
    doc_len: int = 96         # tokens per document
    vocab: int = 200          # V
    n_topics: int = 10        # K
    true_topics: int = 10
    alpha: float = 0.5        # doc-topic prior
    beta: float = 0.1         # topic-word prior
    n_workers: int = 8
    minibatch_frac: float = 0.5   # fraction of local tokens per clock (paper: 50%)
    concentration: float = 0.05   # Dirichlet concentration of true topics
    seed: int = 0


def make_lda_app(cfg: LDAConfig) -> PSApp:
    P, K, V = cfg.n_workers, cfg.n_topics, cfg.vocab
    assert cfg.n_docs % P == 0
    docs_per = cfg.n_docs // P
    ntok = docs_per * cfg.doc_len                    # tokens per worker
    B = max(1, int(ntok * cfg.minibatch_frac))      # minibatch per clock

    rng = jax.random.PRNGKey(cfg.seed)
    k_phi, k_theta, k_words, k_z = jax.random.split(rng, 4)

    # --- synthetic corpus from a true topic model ------------------------
    phi_true = jax.random.dirichlet(
        k_phi, cfg.concentration * jnp.ones(V), (cfg.true_topics,))
    theta_true = jax.random.dirichlet(
        k_theta, 0.3 * jnp.ones(cfg.true_topics), (cfg.n_docs,))
    kz, kw = jax.random.split(k_words)
    z_true = jax.random.categorical(
        kz, jnp.log(theta_true)[:, None, :], axis=-1,
        shape=(cfg.n_docs, cfg.doc_len))
    words_all = jax.random.categorical(
        kw, jnp.log(phi_true)[z_true], axis=-1)     # [D, doc_len]

    # partition docs across workers
    words = words_all.reshape(P, docs_per * cfg.doc_len).astype(jnp.int32)
    docid = jnp.tile(
        jnp.repeat(jnp.arange(docs_per, dtype=jnp.int32), cfg.doc_len),
        (P, 1))

    # --- initial assignments and counts ----------------------------------
    z0 = jax.random.randint(k_z, (P, ntok), 0, K).astype(jnp.int32)

    def counts_for_worker(z_w, words_w, docid_w):
        onehot = jax.nn.one_hot(z_w, K)                       # [ntok, K]
        ndk = jnp.zeros((docs_per, K)).at[docid_w].add(onehot)
        nkw = jnp.zeros((K, V)).at[z_w, words_w].add(1.0)
        return ndk, nkw

    ndk0, nkw0_per = jax.vmap(counts_for_worker)(z0, words, docid)
    nkw0 = jnp.sum(nkw0_per, axis=0)                          # [K, V]

    def worker_update(view, local, _wid, clock, rng):
        nkw = view.reshape(K, V)
        # Clamp: staleness can transiently make counts locally negative;
        # real samplers clamp at read time too.
        nkw = jnp.maximum(nkw, 0.0)
        nk = jnp.sum(nkw, axis=-1)                            # [K]

        start = (clock * B) % ntok
        idx = (start + jnp.arange(B)) % ntok                  # rotating slice
        w = local["words"][idx]
        d = local["docid"][idx]
        zold = local["z"][idx]
        oh_old = jax.nn.one_hot(zold, K)                      # [B, K]

        ndk_tok = local["ndk"][d] - oh_old                    # exclude self
        nkw_tok = nkw[:, w].T - oh_old
        nk_tok = nk[None, :] - oh_old
        logits = (jnp.log(ndk_tok + cfg.alpha)
                  + jnp.log(jnp.maximum(nkw_tok, 0.0) + cfg.beta)
                  - jnp.log(jnp.maximum(nk_tok, 0.0) + V * cfg.beta))
        znew = jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
        oh_new = jax.nn.one_hot(znew, K)

        ndk = local["ndk"].at[d].add(oh_new - oh_old)
        z = local["z"].at[idx].set(znew)
        # INC deltas on the shared topic-word table.
        delta = (jnp.zeros((K, V)).at[znew, w].add(1.0)
                 .at[zold, w].add(-1.0))
        new_local = dict(local, z=z, ndk=ndk)
        return delta.ravel(), new_local

    def loss(x, locals_):
        """Negative predictive log-likelihood per token (lower = better)."""
        nkw = jnp.maximum(x.reshape(K, V), 0.0)
        phi = (nkw + cfg.beta) / (jnp.sum(nkw, -1, keepdims=True) + V * cfg.beta)
        ndk = locals_["ndk"]                                  # [P, docs_per, K]
        theta = (ndk + cfg.alpha) / (
            jnp.sum(ndk, -1, keepdims=True) + K * cfg.alpha)
        w = locals_["words"]                                  # [P, ntok]
        d = locals_["docid"]
        # mixture likelihood per token: sum_k theta[d,k] phi[k,w]
        th = jnp.take_along_axis(
            theta, d[:, :, None], axis=1)                     # [P, ntok, K]
        ph = phi[:, w].transpose(1, 2, 0)                     # [P, ntok, K]
        ll = jnp.log(jnp.sum(th * ph, axis=-1) + 1e-30)
        return -jnp.mean(ll)

    local0 = {"words": words, "docid": docid, "z": z0, "ndk": ndk0}
    return PSApp(name="lda", dim=K * V, n_workers=P, x0=nkw0.ravel(),
                 local0=local0, worker_update=worker_update, loss=loss)
