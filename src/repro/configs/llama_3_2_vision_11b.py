"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector are stubbed (assignment carve-out):
``image_embeds`` [B, 1601, d_model] arrive precomputed.  A gated
cross-attention block every 5th layer, as in the model card.
"""
from .base import AttnConfig, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, d_ff=14336, vocab_size=128256,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=5e5),
    vision=VisionConfig(n_image_tokens=1601, cross_attn_every=5),
    param_dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def smoke():
    return CONFIG.replace(
        n_layers=10, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64),
        vision=VisionConfig(n_image_tokens=17, cross_attn_every=5),
        param_dtype="float32",
        remat=False)
