"""mamba2-130m [ssm] — SSD (state-space duality), attn-free
[arXiv:2405.21060]."""
from .base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, d_ff=0, vocab_size=50280,
    attn=None,
    mamba=MambaConfig(d_state=128, headdim=64, expand=2, chunk=128,
                      conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, vocab_size=512,
        mamba=MambaConfig(d_state=32, headdim=32, expand=2, chunk=32,
                          conv_width=4),
        remat=False)
