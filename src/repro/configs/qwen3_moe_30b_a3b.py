"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, d_ff=6144, vocab_size=151936,
    attn=AttnConfig(n_heads=32, n_kv_heads=4, head_dim=128, qk_norm=True,
                    rope_theta=1e6),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, n_shared=0,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64, qk_norm=True),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, n_shared=0),
        param_dtype="float32",
        remat=False)
