"""Assigned-architecture registry: ``get_config(arch_id)``."""
from __future__ import annotations

import importlib

from .base import (AttnConfig, EncoderConfig, INPUT_SHAPES, LONG_CONTEXT_WINDOW,
                   MLAConfig, MambaConfig, ModelConfig, MoEConfig, ShapeConfig,
                   VisionConfig)

ARCHS = (
    "whisper-medium",
    "qwen3-4b",
    "deepseek-v2-lite-16b",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-11b",
    "stablelm-3b",
    "mamba2-130m",
    "qwen3-moe-30b-a3b",
    "llama3-8b",
    "qwen3-0.6b",
    # paper apps (not part of the assigned pool, used by examples/benchmarks)
)


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    mod = importlib.import_module(f".{_module_name(arch)}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    mod = importlib.import_module(f".{_module_name(arch)}", __package__)
    return mod.smoke()
