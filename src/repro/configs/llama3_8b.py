"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, d_ff=14336, vocab_size=128256,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=5e5),
    param_dtype="bfloat16",
    source="arXiv:2407.21783",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64),
        param_dtype="float32",
        remat=False)
