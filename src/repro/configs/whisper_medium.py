"""whisper-medium [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356].

24 encoder + 24 decoder layers, d_model=1024, MHA (kv=16), GeLU MLP.
The mel+conv frontend is stubbed: ``frames`` [B, 1500, 1024] arrive
precomputed (assignment carve-out).
"""
from .base import AttnConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, d_ff=4096, vocab_size=51865,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64, rope_theta=1e4),
    encoder=EncoderConfig(n_layers=24, n_ctx=1500),
    act="gelu",
    source="arXiv:2212.04356",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=64),
        encoder=EncoderConfig(n_layers=2, n_ctx=30),
        remat=False)
