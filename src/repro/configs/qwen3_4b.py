"""qwen3-4b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, d_ff=9728, vocab_size=151936,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, qk_norm=True,
                    rope_theta=1e6),
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen3-8B (4B sibling card)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64, qk_norm=True),
        param_dtype="float32",
        remat=False)
