"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Deviations recorded in DESIGN.md: the original uses Mamba-1 mixers; we use
our Mamba-2 SSD block (same interface, one well-tested kernel).  MoE on
every other sublayer (Jamba's placement), 16 experts top-2.
"""
from .base import AttnConfig, MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, d_ff=24576, vocab_size=65536,
    attn=AttnConfig(n_heads=64, n_kv_heads=8, head_dim=128, rope_theta=1e4),
    mamba=MambaConfig(d_state=128, headdim=64, expand=2, chunk=128,
                      conv_width=4),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, n_shared=0,
                  capacity_factor=1.25),
    attn_every=8,                  # 1 attention sublayer per 8 (1:7)
    # 398B params: bf16 params + bf16 AdamW moments to fit one v5e pod
    param_dtype="bfloat16",
    source="arXiv:2403.19887",
)


def smoke():
    return CONFIG.replace(
        n_layers=8, d_model=256, d_ff=512, vocab_size=512, attn_every=8,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64),
        mamba=MambaConfig(d_state=32, headdim=32, expand=2, chunk=32,
                          conv_width=4),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, n_shared=0),
        param_dtype="float32",
        remat=False)
