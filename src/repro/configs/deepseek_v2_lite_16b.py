"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].

Note (DESIGN.md §Arch-applicability): the assignment line lists both
"MoE 64e top-6" and "160 routed"; DeepSeek-V2-*Lite* has 64 routed experts
(160 belongs to full V2), so we implement 64 routed + 2 shared, top-6.
"""
from .base import AttnConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, d_ff=10944, vocab_size=102400,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128, rope_theta=1e4,
                    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                                  qk_rope_head_dim=64, v_head_dim=128)),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
    source="arXiv:2405.04434",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32, rope_theta=1e4,
                        mla=MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32,
                                      qk_rope_head_dim=16, v_head_dim=32)),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, n_shared=1),
        param_dtype="float32",
        remat=False)
