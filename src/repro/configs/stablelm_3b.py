"""stablelm-3b [dense] — MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b family]."""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, d_ff=6912, vocab_size=50304,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=80, rope_theta=1e4),
    source="hf:stabilityai/stablelm-2-1_6b (3B sibling card)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=64),
        remat=False)
