"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, d_ff=3072, vocab_size=151936,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128, qk_norm=True,
                    rope_theta=1e6),
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B (0.6B sibling card)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64, qk_norm=True),
        remat=False)
