"""Architecture/model configuration dataclasses covering all six families.

One `ModelConfig` describes any of: dense decoder, MoE decoder, SSM (Mamba2),
hybrid (Mamba+attention interleave), VLM (cross-attention decoder), audio
encoder-decoder.  Each assigned architecture is a module in this package
exporting ``CONFIG``; the registry maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention (compressed KV)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int = 16
    n_kv_heads: int = 16              # GQA: kv groups
    head_dim: int | None = None       # default d_model // n_heads
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    causal: bool = True
    window: int | None = None         # sliding-window size (None = full)
    mla: MLAConfig | None = None      # if set, use MLA instead of GQA


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8                # routed experts
    top_k: int = 2
    d_ff_expert: int = 1408           # per-expert hidden dim
    n_shared: int = 0                 # always-on shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 128                  # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Stub-frontend encoder (audio frames or vision patches)."""

    n_layers: int = 24
    n_ctx: int = 1500                 # frames/patches after the stub frontend
    d_model: int | None = None        # defaults to decoder d_model


@dataclass(frozen=True)
class VisionConfig:
    """Stubbed vision frontend for VLM cross-attention."""

    n_image_tokens: int = 1601        # e.g. 1 tile of 40x40 patches + cls
    cross_attn_every: int = 5         # a cross-attn block every Nth layer


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"             # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int = 12
    d_model: int = 768
    d_ff: int = 3072                  # dense-MLP hidden (MoE: shared path)
    vocab_size: int = 32000
    attn: AttnConfig | None = field(default_factory=AttnConfig)
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    attn_every: int | None = None     # hybrid: 1 attn layer per this many
    encoder: EncoderConfig | None = None
    vision: VisionConfig | None = None
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"               # swiglu | gelu
    max_seq_len: int = 131072
    # numerics / execution policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True                # checkpoint each layer in the scan
    scan_layers: bool = True
    # citation for the assignment table
    source: str = ""

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def head_dim(self) -> int:
        a = self.attn
        if a is None:
            return 0
        return a.head_dim if a.head_dim is not None else self.d_model // a.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding-window size used for the long_500k decode shape on pure
# full-attention architectures (see DESIGN.md §Decode-shape policy).
LONG_CONTEXT_WINDOW = 8192
