"""Staleness (clock-differential) measurement — paper Fig 1 (left).

The paper measures, at every read, the "clock differential": the difference
between the clock of the parameter copy being read and the reader's own
clock.  Under BSP this is always −1; under lazy SSP it is ≈uniform over the
window [−s−1, −1]; under ESSP it concentrates at −1.
"""
from __future__ import annotations

import numpy as np

from .ps import Trace


def clock_differentials(trace: Trace, exclude_self: bool = True,
                        skip_warmup: bool = False) -> np.ndarray:
    """Flatten per-read clock differentials from a trace.

    Returns an int array of ``cview[r,q] − c`` over all clocks and channels.
    Self-channels (r == q) are excluded by default since read-my-writes pins
    them at −1.

    ``skip_warmup`` drops the leading clocks where every off-diagonal
    ``cview`` entry is still the initial −1 (no delivery or forced refresh
    has happened yet): those reads return the shared initial parameters, so
    their "staleness" is an artifact of the cold start, not a property of
    the consistency model.
    """
    st = np.asarray(trace.staleness)               # [T, P, P]
    P = st.shape[-1]
    off = ~np.eye(P, dtype=bool)
    if skip_warmup and st.shape[0]:
        # cview[t] = staleness[t] + t; warm clocks have cview == -1 on every
        # off-diagonal channel.
        cview = st + np.arange(st.shape[0])[:, None, None]
        warm = (cview[:, off] == -1).all(axis=1)    # [T]
        n_warm = int(np.argmin(warm)) if not warm.all() else st.shape[0]
        st = st[n_warm:]
    if exclude_self:
        return st[:, off].ravel()
    return st.ravel()


def histogram(trace: Trace, lo: int | None = None, hi: int = 0,
              exclude_self: bool = True, skip_warmup: bool = False):
    """Normalized histogram of clock differentials.

    Returns ``(bin_values, probabilities)`` with bins ``lo..hi`` inclusive.
    """
    diffs = clock_differentials(trace, exclude_self, skip_warmup)
    if lo is None:
        lo = int(diffs.min()) if diffs.size else -1
    bins = np.arange(lo, hi + 2) - 0.5
    counts, _ = np.histogram(diffs, bins=bins)
    total = max(1, counts.sum())
    return np.arange(lo, hi + 1), counts / total


def summary(trace: Trace, exclude_self: bool = True) -> dict:
    """Moment statistics of the staleness distribution (μ_γ, σ_γ of the
    paper's Theorem 5 are driven by these).

    Warm-up clocks (cview still at the initial −1 on every channel) are
    skipped; if the whole trace is warm-up (e.g. lazy SSP with a bound
    longer than the run) the unskipped distribution is used so the moments
    stay defined.
    """
    diffs = clock_differentials(trace, exclude_self,
                                skip_warmup=True).astype(np.float64)
    if diffs.size == 0:
        diffs = clock_differentials(trace, exclude_self).astype(np.float64)
    return {
        "mean": float(diffs.mean()),
        "std": float(diffs.std()),
        "min": int(diffs.min()),
        "max": int(diffs.max()),
        "frac_fresh": float((diffs >= -1).mean()),
    }
