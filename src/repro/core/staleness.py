"""Staleness (clock-differential) measurement — paper Fig 1 (left).

The paper measures, at every read, the "clock differential": the difference
between the clock of the parameter copy being read and the reader's own
clock.  Under BSP this is always −1; under lazy SSP it is ≈uniform over the
window [−s−1, −1]; under ESSP it concentrates at −1.
"""
from __future__ import annotations

import numpy as np

from .ps import Trace


def clock_differentials(trace: Trace, exclude_self: bool = True) -> np.ndarray:
    """Flatten per-read clock differentials from a trace.

    Returns an int array of ``cview[r,q] − c`` over all clocks and channels.
    Self-channels (r == q) are excluded by default since read-my-writes pins
    them at −1.
    """
    st = np.asarray(trace.staleness)               # [T, P, P]
    if exclude_self:
        P = st.shape[-1]
        mask = ~np.eye(P, dtype=bool)
        return st[:, mask].ravel()
    return st.ravel()


def histogram(trace: Trace, lo: int | None = None, hi: int = 0,
              exclude_self: bool = True):
    """Normalized histogram of clock differentials.

    Returns ``(bin_values, probabilities)`` with bins ``lo..hi`` inclusive.
    """
    diffs = clock_differentials(trace, exclude_self)
    if lo is None:
        lo = int(diffs.min())
    bins = np.arange(lo, hi + 2) - 0.5
    counts, _ = np.histogram(diffs, bins=bins)
    total = max(1, counts.sum())
    return np.arange(lo, hi + 1), counts / total


def summary(trace: Trace, exclude_self: bool = True) -> dict:
    """Moment statistics of the staleness distribution (μ_γ, σ_γ of the
    paper's Theorem 5 are driven by these)."""
    diffs = clock_differentials(trace, exclude_self).astype(np.float64)
    # Skip the warm-up clocks where cview is still the initial -1 everywhere.
    return {
        "mean": float(diffs.mean()),
        "std": float(diffs.std()),
        "min": int(diffs.min()),
        "max": int(diffs.max()),
        "frac_fresh": float((diffs >= -1).mean()),
    }
