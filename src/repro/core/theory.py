"""Empirical checks of the paper's theorems.

- Theorem 1/3 (convergence in expectation): regret R[X]/T computed from the
  per-clock view losses must decay like O(1/sqrt(T)).
- Theorem 5 (convergence in probability): the deviation bound depends on the
  staleness moments (μ_γ, σ_γ); we compute both sides' ingredients.
- Theorem 2/6 (decreasing variance): Var_t of the iterate across independent
  seeds must decrease as the algorithm approaches the optimum, and ESSP
  (smaller staleness moments) must have smaller variance than SSP.
"""
from __future__ import annotations

import numpy as np

from .consistency import ConsistencyConfig
from .ps import PSApp


def regret_curve(loss_view: np.ndarray, loss_star: float) -> np.ndarray:
    """R[X]/T over clocks: mean excess loss of the noisy views.

    ``loss_view[t]`` plays the role of f_t(x̃_t); ``loss_star`` approximates
    f(x*)/T (per-clock optimal loss).
    """
    excess = np.asarray(loss_view, np.float64) - loss_star
    return np.cumsum(excess) / (np.arange(len(excess)) + 1.0)


def sqrt_decay_fit(curve: np.ndarray, skip: int = 10) -> float:
    """Fit curve[t] ~ a / sqrt(t); returns the fitted exponent from a
    log-log regression (should be <= ~-0.3 for O(T^{-1/2})-style decay)."""
    t = np.arange(len(curve), dtype=np.float64) + 1.0
    t, y = t[skip:], np.maximum(np.asarray(curve[skip:], np.float64), 1e-12)
    A = np.stack([np.log(t), np.ones_like(t)], -1)
    coef, *_ = np.linalg.lstsq(A, np.log(y), rcond=None)
    return float(coef[0])


def variance_trace(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                   n_seeds: int = 8) -> np.ndarray:
    """Var_t = Σ_i E[x̃_{t,i}²] − E[x̃_{t,i}]² across seeds (paper Thm 2/6).

    Runs ``n_seeds`` independent simulations (one compiled program via the
    sweep engine) and returns the summed component-wise variance of
    worker-0's view at every clock.
    """
    from .sweep import sweep

    res = sweep(app, [cfg], n_clocks, seeds=n_seeds, record_views=True)
    views = np.asarray(res.traces[0].views0, np.float64)    # [S, T, d]
    return views.var(axis=0).sum(axis=-1)                   # [T]


def theorem5_bound(T: int, s: int, P: int, eta: float, L: float, F: float,
                   mu_gamma: float, sigma_gamma: float, tau: float) -> dict:
    """Evaluate both sides of Theorem 5's tail bound for given constants.

    Returns the deviation threshold (the 1/sqrt(T)(ηL² + F²/η + 2ηL²μ_γ)
    term) and the exponential tail probability for deviation ``tau``.
    """
    thresh = (eta * L**2 + F**2 / eta + 2 * eta * L**2 * mu_gamma) / np.sqrt(T)
    eta_bar = eta**2 * L**4 * (np.log(T) + 1.0) / T
    denom = 2 * eta_bar * sigma_gamma + (2.0 / 3) * eta * L**2 * (2 * s + 1) * P * tau
    tail = float(np.exp(-T * tau**2 / max(denom, 1e-12)))
    return {"threshold": float(thresh), "tail_prob": tail, "eta_bar": float(eta_bar)}
