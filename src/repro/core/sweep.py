"""Batched consistency-model sweep engine: one XLA program per family.

The paper's empirical claims (C1–C6) are all *sweeps*: staleness profiles,
convergence curves, robustness and straggler ablations measured across
consistency models, staleness bounds, delivery rates, and seeds.  The seed
implementation re-traced and re-compiled ``simulate`` once per configuration
in a Python loop — compile time, not simulation time, dominated every paper
figure.

This module compiles ``simulate`` **once per config family** and ``vmap``s
it over the whole (config-grid × seeds) batch:

- a *family* is the static structure of a config — ``(model,
  read_my_writes, max_extra_delay)`` — everything that selects Python-level
  control flow inside the simulator.  Numeric knobs (``staleness``,
  ``push_prob``, ``v0``, ``straggler_*``) are pytree data leaves of
  ``ConsistencyConfig`` and batch freely;
- within a family the ring window is *harmonized* to the maximum
  ``effective_window`` so every config shares one compiled shape.  For
  bounded models results are unchanged (updates older than the bound are
  visible to every reader before they would fold either way), but float
  summation order differs from a run with a smaller window — compare
  against ``simulate`` with the same window (``SweepResult.harmonized``)
  when checking bit-identity.  For unbounded models (async/vap) the window
  is part of the simulated physics, so ``cfg.family`` already splits
  configs with different windows into separate compiles;
- with multiple devices the flattened (config × seed) batch is sharded over
  a 1-D mesh via ``shard_map`` (pad-to-multiple, slice after), spreading a
  paper figure across a pod with the same single compile;
- traced consumers (``core.tune``, the traced ``TimeModel``) can ride
  *inside* the compiled program via ``post``: a callable ``post(trace, cfg,
  seed, cfg_idx) -> pytree`` applied to each (config, seed) trace on device,
  before anything is fetched to host.  The ``trace`` a ``post`` callback
  receives follows the Trace-producer contract documented in ``core/ps.py``
  (all fields, clock axis leading), so the same callback works on traces
  from the executable runtime (``repro.psrun``) unchanged.  With
  ``keep_traces=False`` the full per-clock traces are dropped on device and
  only the (typically tiny) post outputs come back — a frontier over
  hundreds of grid points then moves O(points x T) floats instead of
  O(points x T x P^2).

Example::

    res = sweep(app, [ssp(1), ssp(3), ssp(7)], n_clocks=200, seeds=4)
    res.n_compiles            # 1 — one program for the whole figure
    res.trace(2, seed_idx=1)  # plain Trace for ssp(7), seed 1
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consistency import DATA_FIELDS, ConsistencyConfig
from .ps import PSApp, Trace, simulate

# Incremented inside the traced function: one tick per (re)trace, i.e. per
# compiled program.  `benchmarks/sweep_bench.py` uses this to demonstrate
# batched-vs-sequential compile counts.
_TRACE_COUNTER = {"count": 0}

_KNOB_DTYPES = {"staleness": jnp.int32, "straggler_workers": jnp.int32,
                "s_xpod": jnp.int32, "agg_clocks": jnp.int32}


def trace_count() -> int:
    return _TRACE_COUNTER["count"]


def family_window(configs: Sequence[ConsistencyConfig]) -> int:
    """Harmonized ring window for one family: the max effective window."""
    return max(c.effective_window for c in configs)


def stack_configs(configs: Sequence[ConsistencyConfig],
                  window: int | None = None) -> ConsistencyConfig:
    """Stack same-family configs into one batched config (leaves [N])."""
    fams = {c.family for c in configs}
    if len(fams) != 1:
        raise ValueError(f"cannot stack configs across families: {fams}")
    window = window or family_window(configs)
    knobs = {
        name: jnp.asarray([getattr(c, name) for c in configs],
                          _KNOB_DTYPES.get(name, jnp.float32))
        for name in DATA_FIELDS
    }
    c0 = configs[0]
    # Pin the comm-substrate decision statically: after stacking, the knob
    # leaves are arrays (comm_active could no longer derive it from
    # values), and the family guarantees all members share it.
    return ConsistencyConfig(
        model=c0.model, read_my_writes=c0.read_my_writes, window=window,
        max_extra_delay=c0.max_extra_delay, n_pods=c0.n_pods,
        quant=c0.quant, wire=c0.comm_active, **knobs)


@dataclass
class SweepResult:
    """Per-config batched traces plus compile/timing evidence.

    ``traces[i]`` has every `Trace` leaf batched with a leading ``[n_seeds]``
    axis, aligned with ``configs[i]``.  ``harmonized[i]`` is ``configs[i]``
    with its family's shared ring window applied — a standalone
    ``simulate(app, harmonized[i], n_clocks, seed)`` reproduces
    ``trace(i, j)`` exactly.
    """

    configs: list
    harmonized: list
    seeds: np.ndarray
    traces: list
    n_compiles: int
    t_first_s: float          # first execution, including compile
    t_exec_s: float | None    # steady-state re-execution (timeit=True)
    families: dict = field(default_factory=dict)
    posts: list = field(default_factory=list)   # per-config batched post out

    def trace(self, i: int, seed_idx: int = 0) -> Trace:
        """Unbatched `Trace` for config ``i`` at seed index ``seed_idx``.

        Unavailable when the sweep ran with ``keep_traces=False``."""
        if self.traces[i] is None:
            raise ValueError("sweep ran with keep_traces=False; only `posts` "
                             "outputs were kept")
        return jax.tree_util.tree_map(lambda x: x[seed_idx], self.traces[i])

    def post(self, i: int, seed_idx: int | None = None):
        """Post-callback output for config ``i`` (one seed, or batched)."""
        if not self.posts or self.posts[i] is None:
            raise ValueError("sweep ran without a post callback")
        if seed_idx is None:
            return self.posts[i]
        return jax.tree_util.tree_map(lambda x: x[seed_idx], self.posts[i])


def _device_mesh(devices):
    if devices is None:
        devices = jax.devices()
    return list(devices)


def _family_runner(app: PSApp, n_clocks: int, record_views: bool, devices,
                   post=None, keep_traces: bool = True, mesh=None,
                   mesh_axis: str = "batch", obs=None):
    """Build the once-compiled runner for one family: `simulate` vmapped
    over a flat (config × seed) batch, sharded over devices when more than
    one is available.  Returns ``fn(stacked_flat, seeds_flat, idx_flat) ->
    {"trace": Trace|None, "post": pytree|None}``; repeated calls with the
    same batch shape reuse the compiled program.

    By default the batch shards over a 1-D ``("batch",)`` mesh spanning
    ``devices``; pass ``mesh``/``mesh_axis`` to shard it over one named
    axis of an existing mesh instead — e.g. the "pod" axis of a
    `launch.mesh.make_pods_mesh` 3-D mesh, spreading a sweep across pods
    while each pod's ``("data","model")`` devices stay free for the
    runtime (the batch is replicated over the non-sharded axes)."""

    def one(cfg, seed, cfg_idx):
        _TRACE_COUNTER["count"] += 1          # fires once per trace/compile
        tr = simulate(app, cfg, n_clocks, seed=seed,
                      record_views=record_views, obs=obs)
        return {
            "trace": tr if (keep_traces or post is None) else None,
            "post": None if post is None else post(tr, cfg, seed, cfg_idx),
        }

    batched = jax.vmap(one, in_axes=(0, 0, 0))
    if mesh is None:
        if len(devices) == 1:
            return jax.jit(batched)
        from ..launch.mesh import make_batch_mesh
        mesh, mesh_axis = make_batch_mesh(devices), "batch"
    n_shards = mesh.shape[mesh_axis]
    if n_shards == 1:
        return jax.jit(batched)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = P(mesh_axis)
    sharded = jax.jit(shard_map(batched, mesh=mesh,
                                in_specs=(spec, spec, spec),
                                out_specs=spec))

    def fn(stacked_flat, seeds_flat, idx_flat):
        n = seeds_flat.shape[0]
        pad = (-n) % n_shards
        if pad:
            padder = lambda x: jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
            stacked_flat = jax.tree_util.tree_map(padder, stacked_flat)
            seeds_flat = padder(seeds_flat)
            idx_flat = padder(idx_flat)
        out = sharded(stacked_flat, seeds_flat, idx_flat)
        if pad:
            out = jax.tree_util.tree_map(lambda x: x[:n], out)
        return out

    return fn


def sweep(app: PSApp, configs: Sequence[ConsistencyConfig], n_clocks: int,
          seeds: int | Sequence[int] = 1, record_views: bool = False,
          devices=None, timeit: bool = False, post=None,
          keep_traces: bool = True, mesh=None,
          mesh_axis: str = "batch", obs=None) -> SweepResult:
    """Run every (config, seed) pair with one compiled program per family.

    Args:
      app: the PS application.
      configs: any mix of consistency configs; they are grouped by
        ``cfg.family`` and each group compiles exactly once.
      n_clocks: clocks to simulate.
      seeds: seed count (``k`` → seeds 0..k-1) or explicit seed values.
      record_views: record worker-0 views per clock (`Trace.views0`).
      devices: devices to shard the batch over (default: all local devices;
        a single device runs the plain vmap).
      timeit: re-execute each family once more to measure steady-state
        execution time (`t_exec_s`) separately from compile (`t_first_s`).
      post: optional traced consumer ``post(trace, cfg, seed, cfg_idx) ->
        pytree`` applied to every (config, seed) trace *inside* the compiled
        program (``cfg_idx`` is the config's index in ``configs``, e.g. for
        `TimeModel` RNG folding).  Outputs land in ``SweepResult.posts``,
        batched per config like ``traces``.
      keep_traces: when False (requires ``post``), drop the full traces on
        device and return only the post outputs.
      mesh, mesh_axis: shard the flat batch over one named axis of an
        existing mesh instead of the default 1-D batch mesh — e.g.
        ``mesh=make_pods_mesh(), mesh_axis="pod"`` spreads the sweep over
        the pod axis of the multi-pod mesh (replicated over the within-pod
        axes).  ``devices`` is ignored when ``mesh`` is given.
      obs: optional `repro.obs.ObsSpec` — thread telemetry accumulators
        through every simulated run; each trace's ``obs`` pytree comes
        back batched like any other `Trace` leaf.  ``None`` (default)
        compiles the exact pre-obs program.
    """
    if not keep_traces and post is None:
        raise ValueError("keep_traces=False requires a post callback")
    configs = list(configs)
    if isinstance(seeds, (int, np.integer)):
        seeds = np.arange(seeds)
    seeds = np.asarray(seeds, np.uint32)
    S = len(seeds)
    devices = _device_mesh(devices)

    groups: dict[tuple, list[int]] = {}
    for i, c in enumerate(configs):
        groups.setdefault(c.family, []).append(i)

    traces: list[Any] = [None] * len(configs)
    posts: list[Any] = [None] * len(configs)
    harmonized: list[Any] = [None] * len(configs)
    fam_info = {}
    t_first = 0.0
    t_exec = 0.0 if timeit else None
    for fam, idxs in groups.items():
        group = [configs[i] for i in idxs]
        W = family_window(group)
        stacked = stack_configs(group, window=W)
        for i in idxs:
            harmonized[i] = configs[i].replace(window=W)
        # flatten (config × seed): config-major, seed-minor
        rep = lambda x: jnp.repeat(x, S, axis=0)
        stacked_flat = jax.tree_util.tree_map(rep, stacked)
        seeds_flat = jnp.tile(jnp.asarray(seeds), len(group))
        idx_flat = jnp.repeat(jnp.asarray(idxs, jnp.uint32), S)

        fn = _family_runner(app, n_clocks, record_views, devices,
                            post=post, keep_traces=keep_traces,
                            mesh=mesh, mesh_axis=mesh_axis, obs=obs)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(stacked_flat, seeds_flat, idx_flat))
        t_first += time.perf_counter() - t0
        if timeit:
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(stacked_flat, seeds_flat, idx_flat))
            t_exec += time.perf_counter() - t0
        for j, i in enumerate(idxs):
            sl = slice(j * S, (j + 1) * S)
            per_cfg = jax.tree_util.tree_map(lambda x: x[sl], out)
            traces[i] = per_cfg["trace"]
            posts[i] = per_cfg["post"]
        fam_info[fam] = {"configs": len(group), "window": W}

    return SweepResult(configs=configs, harmonized=harmonized, seeds=seeds,
                       traces=traces, n_compiles=len(groups),
                       t_first_s=t_first, t_exec_s=t_exec, families=fam_info,
                       posts=posts)
