"""Sweep-driven consistency auto-tuner: loss vs *modeled wall-clock*.

The paper's payoff (Fig 2 time axes, claim C6) is that the right consistency
knob is the one that reaches the solution fastest in **wall-clock** terms,
not per-clock terms: ESSP beats lazy SSP not because it computes different
math but because its background pushes keep the synchronous-communication
share small, so each clock is cheaper *and* fresher.  Hand-picking
``staleness``/``push_prob`` per app (as the paper does) is exactly the kind
of grid search the batched sweep engine makes cheap — every numeric knob of
``ConsistencyConfig`` is a traced data leaf, so a dense (knob-grid × seed)
batch is **one compiled program per consistency family**.

Objective
---------
Each grid point is scored on two axes, computed on device inside the sweep
via the traced `TimeModel` (`core.timemodel`):

- ``final_loss``: mean training loss over the last ``tail`` clocks — "where
  does this config converge to";
- ``wall_to_threshold``: modeled wall seconds (cumulative `TimeModel`
  per-clock time, which charges blocking fetches, stragglers, and barriers)
  until the loss first drops below a threshold — "how fast does it get
  there".  Configs that never reach the threshold score ``inf``.

The threshold defaults to ``best_final + threshold_frac * (initial -
best_final)`` with ``threshold_frac = 0.05``, i.e. "95% of the way from the
starting loss to the best final loss anywhere on the grid" — the analogue
of the paper picking a common objective value and comparing time-to-reach
(Fig 2).  The interpolating form works for objectives that do not approach
zero (LDA's predictive NLL) as well as ones that do (MF squared error).
`TimeModel` constants default to the paper's 1 GbE hardware class
(t_comp=50 ms/clock, 100 MB/s, 0.5 ms RTT) and are reported alongside every
frontier.

``frontier`` returns the Pareto-optimal subset of the grid under
(final_loss, wall_to_threshold) minimization, plus every scored point for
plotting.  ``refine`` runs a coarse→fine loop: it re-grids around the
current frontier with halved knob steps and merges the new points (each
refinement round is a fresh sweep — one more compile per family, since the
batch shape changes).

Gradient-through-the-sweep (experimental)
-----------------------------------------
``loss_at_budget`` is a differentiable scalar: the trace loss soft-indexed
at a fixed wall budget (softmin weights over clocks by |cum_wall − budget|).
``grad_knobs`` takes ``jax.grad`` of it w.r.t. the traced config knobs
(``push_prob``, ``v0``, ...) *and* the `TimeModel` constants.  Caveat,
stated honestly: the simulator consumes ``push_prob``/``v0`` only through
Bernoulli/threshold *indicators* (delivered/forced masks), which are
piecewise-constant in the knobs, so their pathwise gradients vanish almost
everywhere; the non-degenerate gradients flow through the continuous
time-model paths (``t_comp``, ``bandwidth``, ... shift which clocks the
budget buys).  The dense grid is therefore the primary tuner; the gradient
path is kept as a diagnostic and as the hook for a future smoothed-delivery
relaxation.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consistency import INT_KNOBS, KNOB_BOUNDS, ConsistencyConfig
from .ps import PSApp, simulate
from .sweep import SweepResult, sweep
from .timemodel import TimeModel


def grid_configs(bases: ConsistencyConfig | Sequence[ConsistencyConfig],
                 knob_grids: dict[str, Sequence] | None
                 ) -> list[ConsistencyConfig]:
    """Cartesian product of ``knob_grids`` applied over each base config.

    ``bases`` may span several consistency families (e.g. ``[ssp(1),
    essp(1)]``) — the sweep engine still compiles once per family.
    """
    if isinstance(bases, ConsistencyConfig):
        bases = [bases]
    if not knob_grids:
        return list(bases)
    names = sorted(knob_grids)
    out = []
    for base in bases:
        for combo in itertools.product(*(knob_grids[n] for n in names)):
            out.append(base.replace(**dict(zip(names, combo, strict=True))))
    return out


@dataclass
class FrontierResult:
    """Scored grid + Pareto frontier of a `frontier` run.

    ``points[i]`` is a dict with the config, per-seed and seed-mean metrics;
    ``frontier_idx`` indexes the Pareto-optimal subset (sorted by
    final_loss).  ``threshold`` is the loss level ``wall_to_threshold``
    measures against; ``time_model`` records the constants every wall figure
    is conditioned on.
    """

    points: list[dict]
    frontier_idx: list[int]
    threshold: float
    time_model: TimeModel
    sweep_result: SweepResult | None = None
    history: list[dict] = field(default_factory=list)

    @property
    def frontier(self) -> list[dict]:
        return [self.points[i] for i in self.frontier_idx]

    def best(self, key: str = "wall_to_threshold") -> dict:
        """Frontier point minimizing ``key`` (ties → lower final loss)."""
        pts = [p for p in self.frontier if np.isfinite(p[key])] or self.frontier
        return min(pts, key=lambda p: (p[key], p["final_loss"]))

    def summary(self) -> dict:
        def describe(p):
            c = p["config"]
            return {"model": c.model, "staleness": int(c.staleness),
                    "push_prob": float(c.push_prob),
                    "final_loss": p["final_loss"],
                    "wall_to_threshold": p["wall_to_threshold"]}
        return {"threshold": self.threshold,
                "n_points": len(self.points),
                "frontier": [describe(p) for p in self.frontier],
                "best": describe(self.best())}


def pareto_indices(xs: np.ndarray, ys: np.ndarray) -> list[int]:
    """Indices of the Pareto-minimal points of (xs, ys), sorted by xs.

    A point is dominated if another is <= on both axes and < on at least
    one.  NaNs never join the frontier; +inf can (a config may converge
    lowest yet never cross the threshold)."""
    n = len(xs)
    keep = []
    for i in range(n):
        if not (np.isfinite(xs[i]) or np.isfinite(ys[i])):
            continue
        if np.isnan(xs[i]) or np.isnan(ys[i]):
            continue
        dominated = False
        for j in range(n):
            if j == i:
                continue
            if (xs[j] <= xs[i] and ys[j] <= ys[i]
                    and (xs[j] < xs[i] or ys[j] < ys[i])):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    keep.sort(key=lambda i: (xs[i], ys[i]))
    return keep


def metrics_post(time_model: TimeModel, tail: int = 10,
                 loss_field: str = "loss_ref"):
    """Sweep ``post`` computing the tuner's per-point metrics on device.

    Returns per (config, seed): the per-clock loss curve, the cumulative
    modeled wall clock (`TimeModel` folded over ``(cfg_idx, seed)`` so every
    grid point draws independent stragglers), and the tail-mean final loss.
    The config rides into the time model, so hierarchical grid points are
    charged bandwidth-faithfully (cross-pod bytes over ``bandwidth_xpod``
    from ``Trace.ship_floats`` — frontiers over ``agg_clocks`` /
    ``topk_frac`` / ``quant`` score real wire time, see `core.timemodel`).
    Everything downstream (threshold, time-to-threshold, Pareto) is cheap
    [N, S, T] numpy on these reduced arrays.
    """
    def post(trace, cfg, seed, cfg_idx):
        wall = time_model.wall_time(trace, cfg.model, fold=(cfg_idx, seed),
                                    cfg=cfg)
        loss = getattr(trace, loss_field)
        return {"loss": loss, "cum_wall": wall,
                "final_loss": loss[-tail:].mean()}
    return post


def _wall_to_threshold(loss: np.ndarray, wall: np.ndarray,
                       threshold: float) -> np.ndarray:
    """First-crossing wall seconds, vectorized over leading axes.

    ``loss``/``wall`` are [..., T]; returns [...] with inf where the loss
    never reaches the threshold."""
    hit = loss <= threshold                       # [..., T]
    first = np.argmax(hit, axis=-1)               # 0 if never hit
    t_hit = np.take_along_axis(wall, first[..., None], axis=-1)[..., 0]
    return np.where(hit.any(axis=-1), t_hit, np.inf)


def score(app: PSApp, configs: Sequence[ConsistencyConfig], n_clocks: int,
          time_model: TimeModel, seeds: int | Sequence[int] = 2,
          threshold: float | None = None, threshold_frac: float = 0.05,
          tail: int = 10, devices=None) -> tuple[list[dict], float,
                                                 SweepResult]:
    """Run the grid through one sweep and score every (config, seed) point."""
    res = sweep(app, configs, n_clocks, seeds=seeds, devices=devices,
                post=metrics_post(time_model, tail=tail), keep_traces=False)
    loss = np.stack([np.asarray(res.posts[i]["loss"])
                     for i in range(len(configs))])       # [N, S, T]
    wall = np.stack([np.asarray(res.posts[i]["cum_wall"])
                     for i in range(len(configs))])       # [N, S, T]
    final = np.stack([np.asarray(res.posts[i]["final_loss"])
                      for i in range(len(configs))])      # [N, S]
    if threshold is None:
        best = float(final.mean(axis=1).min())
        init = float(loss[..., 0].mean())
        threshold = best + threshold_frac * max(init - best, 0.0)
    tts = _wall_to_threshold(loss, wall, threshold)       # [N, S]
    points = []
    for i, cfg in enumerate(configs):
        points.append({
            "config": cfg,
            "final_loss": float(final[i].mean()),
            "wall_to_threshold": float(tts[i].mean()),
            "final_loss_per_seed": final[i].tolist(),
            "wall_to_threshold_per_seed": tts[i].tolist(),
            "wall_total": float(wall[i, :, -1].mean()),
        })
    return points, threshold, res


def frontier(app: PSApp, bases, knob_grids: dict[str, Sequence] | None = None,
             *, time_model: TimeModel | None = None, n_clocks: int = 150,
             seeds: int | Sequence[int] = 2, threshold: float | None = None,
             threshold_frac: float = 0.05, tail: int = 10,
             refine_rounds: int = 0, refine_knobs: Sequence[str] = ("push_prob",),
             devices=None) -> FrontierResult:
    """Dense-grid auto-tune: Pareto frontier of (final loss, modeled wall
    seconds to threshold) over ``knob_grids`` × ``bases``.

    One compiled program per consistency family for the whole coarse grid
    (`sweep`); optional ``refine_rounds`` of coarse→fine re-gridding around
    the running frontier (each round re-sweeps the *new* points only).
    """
    time_model = time_model or TimeModel()
    configs = grid_configs(bases, knob_grids)
    points, threshold, res = score(
        app, configs, n_clocks, time_model, seeds=seeds, threshold=threshold,
        threshold_frac=threshold_frac, tail=tail, devices=devices)
    fr = pareto_indices(np.asarray([p["final_loss"] for p in points]),
                        np.asarray([p["wall_to_threshold"] for p in points]))
    out = FrontierResult(points=points, frontier_idx=fr, threshold=threshold,
                         time_model=time_model, sweep_result=res)
    out.history.append({"round": 0, "n_points": len(points),
                        "n_compiles": res.n_compiles})

    steps = _grid_steps(knob_grids, refine_knobs)
    for r in range(refine_rounds):
        steps = {k: v / 2.0 for k, v in steps.items()}
        proposals = _propose_refinements(out, refine_knobs, steps)
        if not proposals:
            break
        new_points, _, res_r = score(
            app, proposals, n_clocks, time_model, seeds=seeds,
            threshold=threshold, tail=tail, devices=devices)
        out.points.extend(new_points)
        out.frontier_idx = pareto_indices(
            np.asarray([p["final_loss"] for p in out.points]),
            np.asarray([p["wall_to_threshold"] for p in out.points]))
        out.history.append({"round": r + 1, "n_points": len(proposals),
                            "n_compiles": res_r.n_compiles})
    return out


def _grid_steps(knob_grids, refine_knobs) -> dict[str, float]:
    """Initial refinement step per knob: the coarse grid spacing (or a
    quarter of the value range for single-point grids)."""
    steps = {}
    for k in refine_knobs:
        vals = sorted(set(float(v) for v in (knob_grids or {}).get(k, [])))
        if len(vals) >= 2:
            steps[k] = min(b - a for a, b in zip(vals, vals[1:], strict=False))
        else:
            steps[k] = max(abs(vals[0]) * 0.5, 0.1) if vals else 0.1
    return steps


def _propose_refinements(result: FrontierResult, refine_knobs,
                         steps: dict[str, float]) -> list[ConsistencyConfig]:
    """± half-step neighbours of each frontier config, deduplicated against
    everything already scored."""
    seen = {_cfg_key(p["config"]) for p in result.points}
    proposals = []
    for p in result.frontier:
        cfg = p["config"]
        for k in refine_knobs:
            step = steps.get(k, 0.1)
            for sign in (-1.0, 1.0):
                v = getattr(cfg, k) + sign * step
                lo, hi = KNOB_BOUNDS.get(k, (None, None))
                if k in INT_KNOBS:
                    v = int(round(v))
                if lo is not None:
                    v = max(lo, v)
                if hi is not None:
                    v = min(hi, v)
                cand = cfg.replace(**{k: v})
                key = _cfg_key(cand)
                if key not in seen:
                    seen.add(key)
                    proposals.append(cand)
    return proposals


def _cfg_key(cfg: ConsistencyConfig) -> tuple:
    vals = []
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        vals.append(round(float(v), 9) if isinstance(v, float) else v)
    return tuple(vals)


# --------------------------------------------------------------------------
# Experimental: gradient through the sweep
# --------------------------------------------------------------------------

def loss_at_budget(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                   time_model: TimeModel, budget: float, seed=0,
                   temp: float = 2.0, fold=(0,)) -> jax.Array:
    """Differentiable loss at a fixed modeled wall budget.

    Soft-indexes the per-clock loss curve at the clock whose cumulative
    modeled wall time is nearest ``budget``: softmin weights
    ``softmax(-|cum_wall - budget| / (temp * t_comp))``.  As ``temp -> 0``
    this approaches the hard "loss when the budget runs out"; finite temp
    keeps it differentiable w.r.t. everything that shifts ``cum_wall`` (the
    `TimeModel` constants) or the loss values.  See the module docstring for
    which knob gradients are non-degenerate.
    """
    tr = simulate(app, cfg, n_clocks, seed=seed)
    wall = time_model.wall_time(tr, cfg.model, fold=fold, cfg=cfg)
    scale = jnp.maximum(jnp.asarray(temp * time_model.t_comp, jnp.float32),
                        1e-9)
    w = jax.nn.softmax(-jnp.abs(wall - budget) / scale)
    return jnp.sum(w * tr.loss_ref)


def grad_knobs(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
               time_model: TimeModel, budget: float,
               knobs: Sequence[str] = ("push_prob",),
               tm_knobs: Sequence[str] = ("t_comp",), seed=0,
               temp: float = 2.0) -> dict[str, Any]:
    """``jax.grad`` of `loss_at_budget` w.r.t. config knobs and `TimeModel`
    constants, in one backward pass.

    Returns ``{"value": float, "grads": {name: float}}``.  Config knobs ride
    as traced pytree data leaves of `ConsistencyConfig`; `TimeModel`
    constants are substituted via ``dataclasses.replace`` (its methods treat
    them as values, so traced floats flow through).
    """
    cfg = cfg.replace(window=cfg.effective_window)   # freeze compiled shape

    def objective(theta):
        c = cfg.replace(**{k: theta[k] for k in knobs})
        tm = dataclasses.replace(time_model,
                                 **{k: theta[k] for k in tm_knobs})
        return loss_at_budget(app, c, n_clocks, tm, budget, seed=seed,
                              temp=temp)

    theta0 = {k: jnp.asarray(getattr(cfg, k), jnp.float32) for k in knobs}
    theta0 |= {k: jnp.asarray(getattr(time_model, k), jnp.float32)
               for k in tm_knobs}
    value, grads = jax.jit(jax.value_and_grad(objective))(theta0)
    return {"value": float(value),
            "grads": {k: float(v) for k, v in grads.items()}}
