"""Parameter-server consistency models (the paper's core abstraction).

A *consistency model* governs which producers' updates a reader's cached
view contains at each clock.  Following the paper we implement:

- ``bsp``    Bulk Synchronous Parallel: a full barrier every clock; a read at
             clock ``c`` sees *all* updates through ``c-1`` (clock
             differential is always -1, as noted under Fig 1).
- ``ssp``    Stale Synchronous Parallel (SSPTable semantics): the client
             cache is refreshed *lazily* — only when its per-row clock would
             violate the staleness bound ``s``.  A read at clock ``c`` is
             guaranteed to include all updates from clocks ``<= c - s - 1``.
- ``essp``   Eager SSP (ESSPTable, this paper): identical *guarantee* to SSP,
             but the server pushes updated rows to registered clients every
             clock, so the empirical staleness concentrates near -1.
- ``async``  No bound at all (Hogwild-style), delivery purely delay-driven.
             Used as a divergence contrast; not a paper contribution.
- ``vap``    Value-bounded Asynchronous Parallel: delivery is delay-driven
             but the aggregated in-transit updates of any producer are forced
             out whenever their infinity-norm would exceed ``v_t = v0/sqrt(t)``
             (eq. 1 of the paper).  Implementable in the simulator because it
             has global knowledge; the paper's point that this requires
             strong-consistency-grade synchronization shows up as the forced
             synchronous deliveries we count in the time model.

Sweep support
-------------
``ConsistencyConfig`` is registered as a JAX pytree whose *numeric* knobs
(``staleness``, ``v0``, ``push_prob``, ``straggler_prob``,
``straggler_workers``, ``straggler_rate``, and the two-tier knobs
``s_xpod``, ``t_net_intra``, ``t_net_xpod``) are data leaves, while the
*structural* knobs (``model``, ``read_my_writes``, ``window``,
``max_extra_delay``, ``n_pods``) are static metadata.  The numeric knobs may therefore
hold traced values or batched arrays: ``core.sweep`` vmaps ``simulate`` over
a whole config grid in one compiled XLA program instead of recompiling per
configuration.  Structural knobs select Python-level control flow inside the
simulator and must stay concrete; configs sharing them form one *family*
(one compiled program per family).

The ring-buffer size (``effective_window``) shapes the compiled program, so
it must be static.  When ``staleness`` is traced/batched, set ``window``
explicitly (``core.sweep`` does this automatically, harmonizing a family to
its maximum window — results are unchanged for bounded models since updates
older than the bound are visible to every reader anyway).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import numpy as np

MODELS = ("bsp", "ssp", "essp", "async", "vap")

# Numeric knobs: pytree data leaves, traceable/batchable (see module doc).
DATA_FIELDS = ("staleness", "v0", "push_prob", "straggler_prob",
               "straggler_workers", "straggler_rate",
               "s_xpod", "t_net_intra", "t_net_xpod",
               "agg_clocks", "topk_frac")
# Structural knobs: static pytree metadata, baked into the compiled program.
META_FIELDS = ("model", "read_my_writes", "window", "max_extra_delay",
               "n_pods", "quant", "wire")

# Wire-value formats of the comm substrate (`repro.comm`), in bits.
QUANT_BITS = {"f32": 32, "bf16": 16, "int8": 8}

# Physically meaningful ranges of the numeric knobs ((lo, hi), None = open).
# The auto-tuner (`core.tune`) clips its coarse→fine refinement proposals to
# these.
KNOB_BOUNDS = {
    "staleness": (0, None),
    "v0": (1e-3, None),
    "push_prob": (0.05, 1.0),
    "straggler_prob": (0.0, 0.95),
    "straggler_workers": (0, None),
    "straggler_rate": (0.01, 1.0),
    "s_xpod": (0, None),
    "t_net_intra": (1.0, None),
    "t_net_xpod": (1.0, None),
    "agg_clocks": (1, None),
    "topk_frac": (0.01, 1.0),
}
# Knobs that live on an integer lattice (refinement rounds to these).
INT_KNOBS = ("staleness", "straggler_workers", "s_xpod", "agg_clocks")


def _concrete(x) -> bool:
    """True for plain Python/numpy scalars (validate eagerly); traced values
    and arrays skip validation — ``core.sweep`` validates per-config up
    front."""
    return isinstance(x, (bool, int, float, np.integer, np.floating))


@dataclass(frozen=True)
class ConsistencyConfig:
    """Configuration of a PS consistency model.

    Attributes:
      model: one of ``MODELS``.
      staleness: SSP/ESSP staleness bound ``s`` (clocks).
      v0: VAP initial value bound (``v_t = v0 / sqrt(t+1)``).
      push_prob: per-clock probability that an eager push (ESSP) or an async
        delivery reaches a given reader within one clock.  Models network
        delay: deliveries are geometric with this success probability.
      straggler_prob: probability that a given (reader, producer) channel is
        "congested" for a clock (its deliveries stall), adding a heavy tail.
      straggler_workers: number of persistently slow *producers* (the first
        N worker ids) whose pushes land at ``straggler_rate`` x the nominal
        rate — the paper's straggler scenario (see core/delays.py).
      straggler_rate: delivery-rate multiplier for straggler workers.
      read_my_writes: whether a worker's own updates are immediately visible
        in its view (true for ESSPTable's local cache with coalesced INCs;
        the theory section of the paper does *not* assume it, so tests cover
        both).
      window: ring-buffer window override; defaults to ``staleness +
        max_extra_delay + 2``.  Must be set explicitly when ``staleness`` is
        a traced value (the window shapes the compiled program).
      max_extra_delay: cap on delay beyond the eager path used to size the
        update window for unbounded models (async/vap).
      n_pods: number of pods in the hierarchical (multi-pod) mode.  The
        ``P`` workers are partitioned into ``n_pods`` contiguous blocks;
        channels between workers of different pods cross the slow network
        tier.  ``n_pods=1`` (default) is the flat single-pod PS and is
        bit-identical to the pre-hierarchy behavior.  Static: it selects the
        pod partition (and, in ``repro.pods``, the mesh axis sizes).
      s_xpod: extra staleness allowance on *cross-pod* channels (clocks).
        SSP/ESSP enforce ``s`` intra-pod and ``s + s_xpod`` cross-pod — the
        two-tier bounded-staleness contract (per-channel lag is bounded by
        ``s_intra + s_xpod``, Wei et al. arXiv:1312.7869).
      t_net_intra: mean delivery delay of the intra-pod network tier, in
        clocks (geometric: a push crosses the tier within one clock with
        probability ``push_prob / max(t_net_intra, 1)``).  1.0 = the
        pre-hierarchy single-tier behavior.
      t_net_xpod: mean delivery delay of the cross-pod tier in clocks —
        typically an order of magnitude above ``t_net_intra`` (the
        datacenter-scale second tier).
      agg_clocks: k-clock delta aggregation of the comm substrate
        (`repro.comm`): cross-pod deltas accumulate locally and ship every
        ``agg_clocks`` clocks as one summed delta.  Content on a cross-pod
        channel may therefore lag up to ``agg_clocks - 1`` extra clocks —
        the two-tier staleness contract widens to ``s + s_xpod +
        agg_clocks - 1`` (``core.delays.staleness_bound_matrix``).  1 (the
        default) ships every clock.
      topk_frac: sparse-shipment fraction of the comm substrate: only the
        ``ceil(topk_frac * d)`` largest-magnitude coordinates of an
        aggregated delta cross the pod boundary; the rest stay in an
        error-feedback residual that re-ships later (``repro.comm``).
        1.0 (the default) ships dense.
      quant: wire value format of the comm substrate: ``"f32"`` (default),
        ``"bf16"``, or ``"int8"`` (per-producer absmax scaling).  Static —
        it selects the pack/unpack code in the compiled program.
      wire: static override of :attr:`comm_active` (route cross-pod
        shipment through the compressed comm substrate).  ``None`` (the
        default) derives it from the knob values; set it explicitly when
        sweeping ``agg_clocks``/``topk_frac`` as traced values.
    """

    model: str = "essp"
    staleness: int = 3
    v0: float = 0.0
    push_prob: float = 0.9
    straggler_prob: float = 0.05
    straggler_workers: int = 0
    straggler_rate: float = 0.25
    read_my_writes: bool = True
    window: int | None = None
    max_extra_delay: int = 6
    n_pods: int = 1
    s_xpod: int = 0
    t_net_intra: float = 1.0
    t_net_xpod: float = 1.0
    agg_clocks: int = 1
    topk_frac: float = 1.0
    quant: str = "f32"
    wire: bool | None = None

    def __post_init__(self):
        if self.model not in MODELS:
            raise ValueError(f"unknown consistency model {self.model!r}; "
                             f"expected one of {MODELS}")
        if _concrete(self.staleness) and self.staleness < 0:
            raise ValueError("staleness must be >= 0")
        if self.model == "vap" and _concrete(self.v0) and self.v0 <= 0:
            raise ValueError("vap requires v0 > 0")
        if self.n_pods < 1:
            raise ValueError("n_pods must be >= 1")
        if _concrete(self.s_xpod) and self.s_xpod < 0:
            raise ValueError("s_xpod must be >= 0")
        if self.quant not in QUANT_BITS:
            raise ValueError(f"unknown quant {self.quant!r}; expected one "
                             f"of {tuple(QUANT_BITS)}")
        if _concrete(self.agg_clocks) and self.agg_clocks < 1:
            raise ValueError("agg_clocks must be >= 1")
        if _concrete(self.topk_frac) and not (0.0 < self.topk_frac <= 1.0):
            raise ValueError("topk_frac must be in (0, 1]")
        if self.comm_active:
            if self.model in ("bsp", "vap"):
                raise ValueError(
                    f"the comm substrate does not apply to {self.model!r}: "
                    "bsp's barrier is a full-state sync and vap's value "
                    "bound needs a synchronous full-precision channel (the "
                    "contrast the paper draws) — use ssp/essp/async")
            if self.n_pods < 2:
                raise ValueError("the comm substrate compresses the "
                                 "cross-pod wire; it requires n_pods >= 2")

    @property
    def comm_active(self) -> bool:
        """Static: does this config route cross-pod shipment through the
        compressed comm substrate (`repro.comm`)?

        ``wire`` overrides when set; otherwise active iff any comm knob is
        non-default.  When ``agg_clocks``/``topk_frac`` are traced (the
        config crossed a jit boundary as an argument) and ``wire`` is
        unset, the substrate stays OFF — the code path must be static, and
        off is the only default that keeps pre-substrate callers
        bit-identical.  Set ``wire=True`` (``consistency.compressed`` does)
        to engage it; ``core.sweep.stack_configs`` pins ``wire`` from the
        concrete per-config values so sweeps are unaffected."""
        if self.wire is not None:
            return bool(self.wire)
        if self.quant != "f32":
            return True
        if _concrete(self.agg_clocks) and _concrete(self.topk_frac):
            return self.agg_clocks > 1 or self.topk_frac < 1.0
        return False

    @property
    def effective_window(self) -> int:
        """Size of the update ring buffer (clocks kept before folding)."""
        if self.window is not None:
            return self.window
        if not (_concrete(self.staleness) and _concrete(self.s_xpod)):
            raise ValueError(
                "effective_window needs concrete staleness/s_xpod; set "
                "`window` explicitly when sweeping them as traced values")
        agg = 0
        if self.comm_active:
            # cross-pod content lags up to agg_clocks - 1 extra clocks
            # behind the shipment schedule; the ring must keep it visible.
            if not _concrete(self.agg_clocks):
                raise ValueError(
                    "effective_window needs a concrete agg_clocks; set "
                    "`window` explicitly when sweeping it as a traced value")
            agg = self.agg_clocks - 1
        if self.model == "bsp":
            return 2
        if self.model in ("async", "vap"):
            return (self.staleness + self.s_xpod + agg
                    + self.max_extra_delay + 2)
        return self.staleness + self.s_xpod + agg + 2

    @property
    def family(self) -> tuple:
        """Static structure shared by configs that can compile together once
        their ring windows are harmonized (see ``core.sweep``).

        For bounded models (bsp/ssp/essp) the window only affects float
        summation order, so it is harmonizable and stays out of the key.
        For unbounded models (async/vap) recycling a ring slot force-folds
        undelivered updates into the globally visible base — the window is
        part of the simulated physics — so it joins the key and configs
        with different windows compile separately.  ``n_pods`` selects the
        pod partition (a different channel-tier mask), so it is part of the
        family too.  ``comm_active`` selects the comm-substrate code path
        (and ``quant`` the pack/unpack code within it), so both join the
        key."""
        key = (self.model, bool(self.read_my_writes),
               int(self.max_extra_delay), int(self.n_pods),
               self.comm_active)
        if self.comm_active:
            key += (self.quant,)
        if self.model in ("async", "vap"):
            key += (self.effective_window,)
        return key

    def replace(self, **kw) -> "ConsistencyConfig":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    ConsistencyConfig, data_fields=list(DATA_FIELDS),
    meta_fields=list(META_FIELDS))


def bsp(**kw) -> ConsistencyConfig:
    return ConsistencyConfig(model="bsp", staleness=0, **kw)


def ssp(staleness: int, **kw) -> ConsistencyConfig:
    return ConsistencyConfig(model="ssp", staleness=staleness, **kw)


def essp(staleness: int, **kw) -> ConsistencyConfig:
    return ConsistencyConfig(model="essp", staleness=staleness, **kw)


def vap(v0: float, **kw) -> ConsistencyConfig:
    return ConsistencyConfig(model="vap", v0=v0, **kw)


def podded(cfg: ConsistencyConfig, n_pods: int, s_xpod: int = 0,
           t_net_xpod: float | None = None,
           t_net_intra: float | None = None) -> ConsistencyConfig:
    """Lift a flat config onto ``n_pods`` pods with a second network tier.

    ``s_xpod`` is the extra cross-pod staleness allowance; the ``t_net_*``
    mean delivery delays (clocks) default to the single-tier behavior
    (1.0) when not given.  ``podded(cfg, 1)`` is bit-identical to ``cfg``.
    """
    kw = dict(n_pods=n_pods, s_xpod=s_xpod)
    if t_net_xpod is not None:
        kw["t_net_xpod"] = t_net_xpod
    if t_net_intra is not None:
        kw["t_net_intra"] = t_net_intra
    return cfg.replace(**kw)


def compressed(cfg: ConsistencyConfig, agg_clocks: int = 1,
               topk_frac: float = 1.0,
               quant: str = "f32") -> ConsistencyConfig:
    """Route ``cfg``'s cross-pod shipment through the comm substrate.

    ``agg_clocks`` batches cross-pod deltas (one summed shipment every k
    clocks; the staleness contract widens by ``agg_clocks - 1``),
    ``topk_frac`` ships only the largest-magnitude fraction of each delta
    (error-feedback residual re-ships the rest), ``quant`` picks the wire
    value format.  Requires a hierarchical config (``n_pods >= 2``) with a
    push/reconcile model (ssp/essp/async).  The neutral knobs
    (``agg_clocks=1, topk_frac=1.0, quant="f32"``) ship the exact dense
    delta through the substrate — semantically identical to the plain
    hierarchical path (float association differs; see `repro.comm`).
    """
    return cfg.replace(agg_clocks=agg_clocks, topk_frac=topk_frac,
                       quant=quant, wire=True)
