"""Parametric wall-clock model for the simulator (paper Fig 1-right, and the
time axis of Fig 2).

The simulator advances in lockstep clocks; real wall time per clock differs
by consistency model because of *synchronous* communication:

- computation: per worker, lognormal around ``t_comp`` (stragglers);
- BSP: a barrier every clock — the clock costs the *max* worker time plus a
  full model sync;
- SSP: forced cache refreshes are synchronous round-trips (the reader
  blocks); each refresh pays latency + (channel bytes)/bandwidth;
- ESSP: pushes ride in the background (overlapped with compute, as
  ESSPTable's server-push does); only the rare forced refresh blocks.

This is a *model* (the container has no cluster); constants default to the
paper's hardware class (1 GbE: ~100 MB/s, 0.5 ms RTT).  All derived claims
(C6 and Fig 2 time axes) are reported with the constants alongside.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .ps import Trace


@dataclass(frozen=True)
class TimeModel:
    t_comp: float = 0.050          # mean compute seconds per clock per worker
    straggler_sigma: float = 0.3   # lognormal sigma of compute time
    rtt: float = 0.0005            # synchronous fetch round-trip (s)
    bandwidth: float = 100e6       # bytes/s (1 GbE)
    bytes_per_channel: float = 4e6 # bytes of one producer's row set
    barrier_overhead: float = 0.002
    seed: int = 0

    def per_clock(self, trace: Trace, model: str):
        """Returns (wall[T], comp[T], comm[T]) per-clock seconds."""
        forced = np.asarray(trace.forced)            # [T, P, P] sync fetches
        T, P, _ = forced.shape
        rng = np.random.default_rng(self.seed)
        comp = self.t_comp * rng.lognormal(
            0.0, self.straggler_sigma, size=(T, P))   # [T, P]

        xfer = self.bytes_per_channel / self.bandwidth
        sync = forced.sum(axis=2) * (self.rtt + xfer)  # [T, P] reader-side

        if model == "bsp":
            # barrier: everyone waits for the slowest, then full sync
            comp_clock = comp.max(axis=1)
            comm_clock = self.barrier_overhead + (P - 1) * xfer + self.rtt
            comm_clock = np.full(T, comm_clock)
        else:
            # lockstep clocks: the clock takes the slowest worker's
            # (compute + its own blocking fetches)
            total = comp + sync
            worst = total.argmax(axis=1)
            comp_clock = comp[np.arange(T), worst]
            comm_clock = sync[np.arange(T), worst]
        return comp_clock + comm_clock, comp_clock, comm_clock

    def wall_time(self, trace: Trace, model: str) -> np.ndarray:
        wall, _, _ = self.per_clock(trace, model)
        return np.cumsum(wall)

    def breakdown(self, trace: Trace, model: str) -> dict:
        """Fig 1-right style comm/comp split over the whole run."""
        wall, comp, comm = self.per_clock(trace, model)
        return {
            "total_s": float(wall.sum()),
            "comp_s": float(comp.sum()),
            "comm_s": float(comm.sum()),
            "comm_frac": float(comm.sum() / max(wall.sum(), 1e-12)),
        }
