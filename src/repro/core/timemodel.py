"""Parametric wall-clock model for the simulator (paper Fig 1-right, and the
time axis of Fig 2).

The simulator advances in lockstep clocks; real wall time per clock differs
by consistency model because of *synchronous* communication:

- computation: per worker, lognormal with mean ``t_comp`` (stragglers);
- BSP: a barrier every clock — the clock costs the *max* worker time plus a
  full model sync;
- SSP: forced cache refreshes are synchronous round-trips (the reader
  blocks); each refresh pays latency + (channel bytes)/bandwidth;
- ESSP: pushes ride in the background (overlapped with compute, as
  ESSPTable's server-push does); only the rare forced refresh blocks.

This is a *model* (the container has no cluster); constants default to the
paper's hardware class (1 GbE: ~100 MB/s, 0.5 ms RTT).  All derived claims
(C6 and Fig 2 time axes) are reported with the constants alongside.

Traced implementation
---------------------
The model is written in ``jnp`` end to end, so it can be ``vmap``-ed over
the batched traces a ``core.sweep`` run produces and consumed *inside* the
one-compile program (see ``core.tune``): ``per_clock``/``wall_time``/
``breakdown`` accept traced `Trace` leaves and return device arrays.  Host
callers can keep treating the results as numpy — the ``*_np`` wrappers (and
``breakdown``'s plain-float dict) convert at the boundary.

Straggler draws are mean-corrected: a lognormal with location 0 has mean
``exp(sigma^2/2)``, so we draw ``exp(N(-sigma^2/2, sigma^2))`` — the
per-clock compute times then average to exactly ``t_comp`` as documented
(the old numpy path overshot by ~4.6% at sigma=0.3, biasing every
straggler ablation's time axis).  Draws are seeded via
``jax.random.fold_in`` over a caller-supplied ``fold`` (config index, seed,
...), so different sweep points get independent straggler realizations
while staying deterministic.

Bandwidth-faithful cross-pod tier
---------------------------------
Passing the run's ``cfg`` (hierarchical, ``n_pods > 1``) switches the
model to *bytes-on-wire* accounting for the second network tier:
``t_net_xpod`` stops being only a delivery-probability knob and the wall
clock charges **seconds per float over the per-tier bandwidth** —

- background shipments (eager reconciliation): each clock's cross-pod
  bytes are ``4 x (n_pods - 1) x Σ_q Trace.ship_floats[t, q]`` (what the
  comm substrate actually put on the wire after aggregation / top-k /
  quantization; a dense push run records ``d`` per producer per clock),
  moved at ``bandwidth_xpod``.  Shipments overlap compute (ESSPTable's
  background push), so the clock costs ``max(compute path, wire time)`` —
  a dense-eager run on a thin cross-pod pipe becomes *bandwidth-bound*,
  which is exactly the effect PR 4's free-delivery model hid;
- forced fetches split by tier: intra-pod refreshes pay
  ``rtt + bytes_per_channel/bandwidth`` as before, cross-pod clock-gated
  pulls pay ``rtt + bytes_per_channel/bandwidth_xpod``;
- under a lossy wire (`repro.comm.wire.WireFaults`) the ARQ charges every
  *transmission* — first attempts and each backoff retransmission —
  into ``Trace.ship_floats`` at the shipment's packed size, so retries
  cost real seconds here with no extra accounting: a 30%-drop run is
  automatically slower in modeled wall time, not just staler.

Without ``cfg`` (or with ``n_pods == 1``) the accounting is unchanged —
every pre-existing caller gets identical numbers.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .delays import same_pod_mask
from .ps import Trace


@dataclass(frozen=True)
class TimeModel:
    t_comp: float = 0.050          # mean compute seconds per clock per worker
    straggler_sigma: float = 0.3   # lognormal sigma of compute time
    rtt: float = 0.0005            # synchronous fetch round-trip (s)
    bandwidth: float = 100e6       # bytes/s (1 GbE, intra-pod tier)
    bytes_per_channel: float = 4e6 # bytes of one producer's row set
    barrier_overhead: float = 0.002
    bandwidth_xpod: float = 10e6   # bytes/s of the cross-pod tier (~10x
    #                                thinner: the datacenter second tier);
    #                                used only when a hierarchical cfg is
    #                                passed (see module doc)
    seed: int = 0

    # ------------------------------------------------------------------ rng
    def key(self, fold=()) -> jax.Array:
        """PRNG key for this model, folded over the sweep coordinates.

        ``fold`` is a sequence of (possibly traced) ints — conventionally
        ``(config_index, seed)`` inside a sweep — so every grid point draws
        independent stragglers while the whole grid stays deterministic.
        """
        key = jax.random.PRNGKey(self.seed)
        for f in fold:
            key = jax.random.fold_in(key, jnp.asarray(f, jnp.uint32))
        return key

    def comp_draws(self, shape, fold=()) -> jax.Array:
        """Mean-corrected lognormal compute times: ``E[draw] == t_comp``."""
        sig = self.straggler_sigma
        z = jax.random.normal(self.key(fold), shape, jnp.float32)
        return self.t_comp * jnp.exp(sig * z - 0.5 * sig * sig)

    # ------------------------------------------------------------- traced
    def _components(self, trace: Trace, fold=(), cfg=None, schedule=None):
        """Per-worker building blocks of the clock cost (traced):
        ``comp[T, P]`` straggler compute draws (live-masked),
        ``sync[T, P]`` blocking-fetch seconds (tier-split under a
        hierarchical ``cfg``), ``wire[T]`` cross-pod shipment seconds on
        the thin tier (``None`` untiered), plus the intra-tier ``xfer``
        constant and the ``tiered`` flag.  This is the decomposition both
        ``per_clock`` (the wall-clock aggregate) and ``timeline_np`` (the
        per-worker observability timebase) are assembled from — one set of
        ops, so the telemetry lanes show exactly the seconds the claims
        charge."""
        forced = jnp.asarray(trace.forced)           # [T, P, P] sync fetches
        T, P, _ = forced.shape
        comp = self.comp_draws((T, P), fold)         # [T, P]
        live = getattr(trace, "live", None)
        if live is not None:
            # all-ones without churn: where(True, comp, 0) == comp exactly,
            # so pre-churn callers get bit-identical numbers
            comp = jnp.where(jnp.asarray(live).astype(bool), comp, 0.0)

        xfer = self.bytes_per_channel / self.bandwidth
        tiered = cfg is not None and cfg.n_pods > 1
        if tiered:
            bw_x = self.bandwidth_xpod               # scalar or [T] scaled
            if schedule is not None and schedule.bw_scale is not None:
                Ts = schedule.bw_scale.shape[0]
                idx = jnp.clip(jnp.arange(T), 0, Ts - 1)
                bw_x = bw_x * jnp.maximum(
                    jnp.asarray(schedule.bw_scale)[idx], 1e-6)
            xfer_x = jnp.asarray(self.bytes_per_channel / bw_x)
            xfer_x_col = xfer_x[:, None] if xfer_x.ndim else xfer_x
            same = same_pod_mask(P, cfg.n_pods)[None, :, :]
            f = forced.astype(jnp.float32)
            sync = ((f * same).sum(axis=2) * (self.rtt + xfer)
                    + (f * ~same).sum(axis=2) * (self.rtt + xfer_x_col))
            # background shipments: bytes each producer put on the wire,
            # to every other pod's replica, through the thin tier
            wire = (4.0 * (cfg.n_pods - 1)
                    * jnp.asarray(trace.ship_floats).sum(axis=1)
                    / bw_x)                          # [T]
        else:
            sync = forced.astype(jnp.float32).sum(axis=2) * (self.rtt + xfer)
            wire = None
        return comp, sync, wire, xfer, tiered

    def per_clock(self, trace: Trace, model: str, fold=(), cfg=None,
                  schedule=None):
        """Returns (wall[T], comp[T], comm[T]) per-clock seconds (traced).

        ``cfg`` (a hierarchical `ConsistencyConfig`, ``n_pods > 1``)
        switches on the bandwidth-faithful cross-pod tier: forced fetches
        split by tier and the clock is floored by the time the clock's
        cross-pod shipments (``Trace.ship_floats``) need on
        ``bandwidth_xpod`` (see module doc).  Without it the accounting
        is exactly the historical single-tier model.

        Churn-aware: dead workers (``Trace.live``) draw no compute, so
        they leave the slowest-worker max — the fleet genuinely shrinks —
        while a rejoiner's catch-up cost is charged automatically through
        its forced-refresh burst at the tiered rates (the rejoin gap in
        seconds).  A ``schedule`` with ``bw_scale`` scales
        ``bandwidth_xpod`` per clock (transient cross-pod crunches): both
        the wire floor and cross-pod fetches ride the scaled tier.
        """
        comp, sync, wire, xfer, tiered = self._components(
            trace, fold, cfg=cfg, schedule=schedule)
        T, P = comp.shape

        if model == "bsp":
            # barrier: everyone waits for the slowest, then full sync
            comp_clock = comp.max(axis=1)
            comm_clock = jnp.full(
                (T,), self.barrier_overhead + (P - 1) * xfer + self.rtt,
                jnp.float32)
        else:
            # lockstep clocks: the clock takes the slowest worker's
            # (compute + its own blocking fetches)
            total = comp + sync
            worst = jnp.argmax(total, axis=1)[:, None]
            comp_clock = jnp.take_along_axis(comp, worst, axis=1)[:, 0]
            comm_clock = jnp.take_along_axis(sync, worst, axis=1)[:, 0]
        wall = comp_clock + comm_clock
        if tiered and model != "bsp":
            # eager shipments overlap compute (background pushes), but the
            # clock cannot close before the wire drains: bandwidth-bound
            # clocks surface here.  The excess is charged as comm.
            wall = jnp.maximum(wall, wire)
            comm_clock = wall - comp_clock
        return wall, comp_clock, comm_clock

    def wall_time(self, trace: Trace, model: str, fold=(),
                  cfg=None) -> jax.Array:
        """Cumulative modeled wall seconds per clock (traced)."""
        wall, _, _ = self.per_clock(trace, model, fold, cfg=cfg)
        return jnp.cumsum(wall)

    def breakdown_traced(self, trace: Trace, model: str, fold=(),
                         cfg=None) -> dict:
        """Fig 1-right comm/comp split as traced scalars (for on-device
        consumers, e.g. a sweep ``post``)."""
        wall, comp, comm = self.per_clock(trace, model, fold, cfg=cfg)
        tot = wall.sum()
        return {"total_s": tot, "comp_s": comp.sum(), "comm_s": comm.sum(),
                "comm_frac": comm.sum() / jnp.maximum(tot, 1e-12)}

    def timeline_np(self, trace: Trace, model: str, fold=(), cfg=None,
                    schedule=None) -> dict:
        """The run's common observability timebase (numpy, host-side).

        Everything `repro.obs.events`/`repro.obs.perfetto` render sits on
        this dict: ``start``/``end``/``wall[T]`` clock windows (exclusive
        cumsum of the same ``per_clock`` walls the benchmark claims
        charge), the ``comp_clock``/``comm_clock[T]`` split, and the
        per-worker components — ``comp[T, P]`` straggler compute seconds,
        ``sync[T, P]`` blocking-fetch seconds, ``wire[T]`` cross-pod
        shipment seconds (zeros untiered).
        """
        comp, sync, wire, _, _ = self._components(
            trace, fold, cfg=cfg, schedule=schedule)
        wall, comp_clock, comm_clock = self.per_clock(
            trace, model, fold, cfg=cfg, schedule=schedule)
        wall = np.asarray(wall)
        end = np.cumsum(wall)
        return {"start": end - wall, "end": end, "wall": wall,
                "comp_clock": np.asarray(comp_clock),
                "comm_clock": np.asarray(comm_clock),
                "comp": np.asarray(comp), "sync": np.asarray(sync),
                "wire": (np.zeros_like(wall) if wire is None
                         else np.broadcast_to(np.asarray(wire),
                                              wall.shape).copy())}

    # -------------------------------------------------- numpy-facing shims
    def per_clock_np(self, trace: Trace, model: str, fold=(), cfg=None):
        return tuple(np.asarray(x)
                     for x in self.per_clock(trace, model, fold, cfg=cfg))

    def wall_time_np(self, trace: Trace, model: str, fold=(),
                     cfg=None) -> np.ndarray:
        return np.asarray(self.wall_time(trace, model, fold, cfg=cfg))

    def breakdown(self, trace: Trace, model: str, fold=(), cfg=None) -> dict:
        """Fig 1-right style comm/comp split over the whole run (floats)."""
        return {k: float(v) for k, v in
                self.breakdown_traced(trace, model, fold, cfg=cfg).items()}
