"""VAP value-bound schedules and condition checking.

The enforcement itself lives in `ps.simulate` (it needs the in-transit ring
buffer); this module holds the schedule definitions and the post-hoc
verification used by tests/benchmarks (paper eq. 1 and Theorem 1's
`v_t = v0/sqrt(t)` requirement).
"""
from __future__ import annotations

import numpy as np

from .ps import Trace


def v_schedule(v0: float, kind: str = "inv_sqrt"):
    """Returns v_t as a function of the clock (0-indexed).

    - ``inv_sqrt``: the paper's v0/sqrt(t+1) (Theorem 1's decreasing bound);
    - ``constant``: fixed threshold (the [Li et al. 2013] style bound the
      paper criticizes — no convergence guarantee as updates shrink);
    - ``inv_t``: faster decay (stress case: forces ~full synchronization).
    """
    if kind == "inv_sqrt":
        return lambda t: v0 / np.sqrt(t + 1.0)
    if kind == "constant":
        return lambda t: v0
    if kind == "inv_t":
        return lambda t: v0 / (t + 1.0)
    raise ValueError(kind)


def check_condition(trace: Trace, v0: float, kind: str = "inv_sqrt",
                    tol: float = 1e-6) -> dict:
    """Verify ``intransit_inf[t] <= v_t`` over a simulation trace.

    The trace measures the aggregate at read time of clock c against the
    bound with t = c (the enforcement clock).
    """
    it = np.asarray(trace.intransit_inf)
    sched = v_schedule(v0, kind)
    vt = np.array([sched(t) for t in range(len(it))])
    # reads at clock c check in-transit accumulated through clock c-1
    viol = it[1:] > vt[:-1] + tol
    return {
        "violations": int(viol.sum()),
        "violation_frac": float(viol.mean()) if len(viol) else 0.0,
        "max_intransit": float(it.max()),
        "bound_final": float(vt[-1]),
    }


def sync_cost(trace: Trace) -> dict:
    """Forced synchronous deliveries — the paper's impracticality metric."""
    forced = np.asarray(trace.forced)
    T, P, _ = forced.shape
    per_clock = forced.sum(axis=(1, 2))
    return {
        "forced_total": int(forced.sum()),
        "forced_per_clock": float(per_clock.mean()),
        "full_sync_fraction": float(
            (per_clock >= P * (P - 1) * 0.9).mean()),
    }
