"""The paper's contribution: PS consistency models + ESSPTable simulator."""
from .consistency import ConsistencyConfig, bsp, ssp, essp, vap, MODELS
from .ps import PSApp, Trace, simulate, simulate_jit
from .sweep import SweepResult, stack_configs, sweep
from . import staleness, theory, timemodel

__all__ = ["ConsistencyConfig", "bsp", "ssp", "essp", "vap", "MODELS",
           "PSApp", "Trace", "simulate", "simulate_jit",
           "SweepResult", "stack_configs", "sweep",
           "staleness", "theory", "timemodel"]
