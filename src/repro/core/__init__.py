"""The paper's contribution: PS consistency models + ESSPTable simulator."""
from .consistency import (ConsistencyConfig, bsp, ssp, essp, vap, podded,
                          compressed, MODELS)
from .delays import ChurnSchedule, make_churn, no_churn
from .ps import PSApp, Trace, simulate, simulate_jit
from .sweep import SweepResult, stack_configs, sweep
from .timemodel import TimeModel
from . import staleness, theory, timemodel, tune

__all__ = ["ConsistencyConfig", "bsp", "ssp", "essp", "vap", "podded",
           "compressed", "MODELS",
           "ChurnSchedule", "make_churn", "no_churn",
           "PSApp", "Trace", "simulate", "simulate_jit",
           "SweepResult", "stack_configs", "sweep", "TimeModel",
           "staleness", "theory", "timemodel", "tune"]
