"""The paper's contribution: PS consistency models + ESSPTable simulator."""
from .consistency import ConsistencyConfig, bsp, ssp, essp, vap, MODELS
from .ps import PSApp, Trace, simulate, simulate_jit
from . import staleness, theory, timemodel

__all__ = ["ConsistencyConfig", "bsp", "ssp", "essp", "vap", "MODELS",
           "PSApp", "Trace", "simulate", "simulate_jit",
           "staleness", "theory", "timemodel"]
