"""Vectorized parameter-server simulator (the paper's ESSPTable, in JAX).

The simulator reproduces the *semantics* of SSPTable/ESSPTable — per-row
cache clocks, lazy-vs-eager delivery, bounded staleness, value bounds — in a
single deterministic ``lax.scan`` over clocks, with all ``P`` workers
vectorized via ``vmap``.  This is what lets us measure the paper's claims
(staleness distributions, convergence per clock, robustness, variance) with
full control over the network-delay model and with exact repeatability.

Mechanics
---------
The global model is a flat vector ``x ∈ R^d`` (apps pack/unpack their own
structure).  Updates are additive (``x ← x + u``), matching the paper's INC
semantics; they are kept in a ring buffer of the last ``W`` clocks, and the
visibility of producer ``q``'s updates to reader ``r`` is tracked by a
per-channel clock matrix ``cview[r, q]`` — the generalization of ESSPTable's
per-row ``c_param``.  The reader's view is::

    view[r] = base + Σ_{q, c' ≤ cview[r,q]} u[q, c']

where ``base`` holds all updates old enough to be visible to everyone
(folded out of the ring).  A consistency model is exactly a policy for
advancing ``cview`` (see ``consistency.py``).

Delivery model: at the end of each clock, every (reader, producer) channel
independently delivers the fresh update with probability ``push_prob``
(unless the channel is "congested" that clock, probability
``straggler_prob``), giving geometric delivery delays with a heavy-tail knob
— the simulator analogue of the paper's 1 GbE cluster network.  SSP ignores
these pushes (SSPTable is pull-based): its caches refresh only when a read
would violate the staleness bound.  ESSP applies them eagerly.

Hierarchical (multi-pod) mode
-----------------------------
With ``cfg.n_pods > 1`` the ``P`` workers are partitioned into contiguous
pod blocks and every channel is classified intra-pod or cross-pod
(``core.delays.same_pod_mask``).  Each pod conceptually holds a full
*replica* of the parameter shards: a reader's view of an intra-pod producer
is governed exactly as before, while cross-pod visibility rides the
*reconciliation channel* of the second network tier —

- **delivery** is two-tier: cross-pod pushes land with probability scaled
  by ``t_net_intra / t_net_xpod`` (``core.delays.channel_push_prob``).
  ESSP/async/VAP reconcile *eagerly* (pushes cross the pod boundary every
  clock as they do intra-pod, only slower); BSP/SSP reconcile *clock-gated*
  (BSP's barrier syncs everything; SSP pulls a cross-pod channel only when
  its bound trips);
- **enforcement** is two-tier: SSP/ESSP force a blocking refresh at
  staleness ``s`` intra-pod and ``s + s_xpod`` cross-pod, so per-channel
  lag is bounded by ``s_intra + s_xpod`` (the bounded-async invariant of
  Wei et al., arXiv:1312.7869), and replica divergence — how far two pods'
  visible prefixes of one producer can drift apart — by the same bound
  (see ``repro.pods.reconcile``).

``n_pods=1`` (the default) is bit-identical to the flat simulator, and BSP
traces are bit-identical across *any* pod count (the barrier drains both
tiers every clock).  The executable counterpart is ``repro.pods``
(``PodsRuntime`` on a 3-D ``("pod","data","model")`` mesh), cross-validated
against this mode exactly like ``repro.psrun`` is against the flat mode.

With ``cfg.comm_active`` (the comm substrate, `repro.comm`) the cross-pod
wire stops being free: each producer accumulates raw updates and ships one
aggregated, top-k-sparsified, quantized delta every ``agg_clocks`` clocks
(error-feedback residual re-ships dropped mass); cross-pod readers
materialize their views from the shipped *wire ring* while intra-pod
readers keep reading raw; cross-pod visibility advances only to shipment
boundaries (bound widened to ``s + s_xpod + agg_clocks - 1``); and
``Trace.ship_floats`` records the bits-weighted floats each shipment put
on the wire.  The substrate is off by default — the dense path is
byte-identical to the pre-substrate simulator — and covered by the same
oracle contract (ssp/essp/async; bsp's barrier and vap's synchronous value
bound don't route through it).

Everything (drift of staleness, forced synchronous fetches, update
magnitudes, losses, per-worker views) is recorded per clock into a `Trace`.

Hot path & sweeps
-----------------
The per-clock view materialization and the VAP suffix-aggregate norms go
through ``kernels.ops`` (pure-jnp reference on CPU, Pallas kernels on
TPU/interpret — see ``kernels/ps_view.py``).  The numeric knobs of
``ConsistencyConfig`` (staleness, push_prob, v0, straggler_*) are consumed
as *values*, never as Python control flow, so they may be traced arrays:
``core.sweep`` vmaps ``simulate`` over an entire config grid × seed batch in
one compiled program.  Only ``cfg.model``/``read_my_writes`` and the ring
window select code structure and must be concrete.

The Trace-producer contract
---------------------------
Two engines produce `Trace`s and must stay interchangeable to every
consumer (``core.staleness``, ``core.theory``, ``core.valuebound``,
``core.timemodel``, the benchmarks):

- ``simulate`` (this module) — the vectorized single-program *oracle*,
  covering both the flat (``n_pods=1``) and hierarchical (``n_pods>1``)
  modes;
- ``repro.psrun.PSRuntime`` — the executable runtime, which runs the same
  clock step sharded over a ``("data","model")`` device mesh;
- ``repro.pods.PodsRuntime`` — the hierarchical runtime on a 3-D
  ``("pod","data","model")`` mesh (replicated parameter shards per pod,
  cross-pod reconciliation), sharing the clock-step machinery with psrun.

All fill every `Trace` field with the clock axis leading, derive all
randomness from the same key stream (``split(rng, 3)`` per clock; worker
keys ``split(k_upd, P)``; delivery from ``k_net``), and keep identical
per-coordinate reduction orders — which is why a seeded BSP run is
bit-identical between them, and SSP/ESSP runs are too (asserted by
``psrun.validate.cross_validate`` since the bit-match was promoted into
the contract; VAP agrees to fusion tolerance with exactly-equal
decisions).  Anything that changes a `Trace` field, the key derivation, or
a reduction order here must be mirrored in ``psrun/runtime.py`` —
`tests/test_psrun.py` and `tests/test_pods.py` enforce the contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..comm import substrate as comm
from ..comm import wire
from ..kernels import ops
from ..kernels.ref import RING_EMPTY, RING_INVALID
from ..obs import metrics as obsm
from .consistency import ConsistencyConfig
from .delays import ChurnSchedule, churn_live, churn_rates, \
    delivery_matrix, pod_of, same_pod_mask, staleness_bound_matrix


@dataclass
class PSApp:
    """An ML application running against the simulated parameter server.

    Attributes:
      name: identifier.
      dim: size of the flat parameter vector.
      n_workers: number of PS workers ``P``.
      x0: initial parameters, shape ``[dim]``.
      local0: worker-local state pytree; every leaf has leading axis ``P``
        (data partitions, Gibbs assignments, doc-topic counts, ...).
      worker_update: ``(view[d], local, worker_id, clock, rng) -> (u[d],
        local')`` — one clock of work for one worker, vmapped by the
        simulator.  ``u`` is the additive update sent to the server.
      loss: ``(x[d], locals) -> scalar`` global training objective, where
        ``locals`` is the stacked worker-local state.
    """

    name: str
    dim: int
    n_workers: int
    x0: jax.Array
    local0: Any
    worker_update: Callable
    loss: Callable


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Trace:
    """Per-clock traces from a simulation (leading axis = clock)."""

    loss_ref: jax.Array        # [T] loss of the reference sequence x_t
    loss_view: jax.Array       # [T] loss of worker 0's (stale) view
    staleness: jax.Array       # [T, P, P] clock differential cview[r,q] - c
    forced: jax.Array          # [T, P, P] synchronous (blocking) fetches
    delivered: jax.Array       # [T, P, P] background deliveries this clock
    u_l2: jax.Array            # [T, P] l2 norm of each worker's update
    intransit_inf: jax.Array   # [T] max inf-norm of in-transit aggregates
    ship_floats: jax.Array     # [T, P] bits-weighted floats each producer
    #                            put on the cross-pod wire this clock
    #                            (comm substrate: quantized values + sparse
    #                            indices at shipment clocks, 0 otherwise;
    #                            dense path: d for push models, 0 for
    #                            pull-based ssp) — see repro.comm
    live: jax.Array            # [T, P] worker liveness per clock (all True
    #                            without a ChurnSchedule): dead workers
    #                            push nothing, their reader rows freeze —
    #                            consumers must re-derive staleness claims
    #                            over the live set (psrun.validate)
    views0: jax.Array | None   # [T, d] worker-0 views (if record_views)
    x_final: jax.Array         # [d] final reference parameters
    locals_final: Any          # final worker-local state
    obs: Any = None            # telemetry accumulators (repro.obs) when the
    #                            run collected them (obs=ObsSpec()); None —
    #                            an empty pytree — otherwise, so traces
    #                            stack/compare exactly as before


def _delivery(rng, cfg: ConsistencyConfig, P: int, rates=None):
    """Sample the end-of-clock delivery matrix (see core/delays.py)."""
    return delivery_matrix(rng, cfg, P, rates)


def enforce_vap(cfg: ConsistencyConfig, c, cview, norms, W: int):
    """Force delivery of oldest in-transit updates so that the per-producer
    aggregated in-transit update satisfies ``||.||_inf <= v_t`` (paper
    eq. 1, v_t = v0/sqrt(t+1)).

    ``norms[k, q]`` is the inf-norm of the suffix aggregate of producer q's
    newest ``k`` clocks (kernels/ps_view.py); we keep in transit the
    largest suffix that satisfies the bound and force-deliver the rest.
    ``cview`` may be the full [P, P] matrix (simulator) or the shard-local
    reader rows [Pl, P] (the runtimes) — the same math serves both engines.
    """
    v_t = cfg.v0 / jnp.sqrt(c.astype(jnp.float32) + 1.0)
    ok = norms <= v_t                                  # [W+1, P]
    ok = ok.at[0].set(True)                            # empty suffix always ok
    # Per (reader, producer) channel: keep the *longest* suffix k that
    # (a) satisfies the bound and (b) does not exceed the channel's
    # current in-transit length (we can only deliver, never undeliver).
    kcur = jnp.clip(c - 1 - cview, 0, W)               # [r, q] suffix length now
    ks = jnp.arange(W + 1, dtype=jnp.int32)[:, None, None]
    cond = ok[:, None, :] & (ks <= kcur[None, :, :])   # [W+1, r, q]
    kbest = jnp.max(jnp.where(cond, ks, -1), axis=0)   # [r, q]
    required = c - 1 - kbest
    forced = cview < required
    return jnp.maximum(cview, required), forced


def simulate(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
             seed=0, record_views: bool = False,
             schedule: ChurnSchedule | None = None,
             obs: obsm.ObsSpec | None = None,
             faults: wire.WireFaults | None = None) -> Trace:
    """Run ``n_clocks`` of the app under the given consistency model.

    ``schedule`` (a `core.delays.ChurnSchedule`) makes the fleet churn:
    dead workers run no update (their pushes are zeroed before entering
    the ring, their worker-local state and reader rows of ``cview``
    freeze, and — under the comm substrate — they ship nothing), while the
    RNG stream, delivery sampling, and every survivor channel stay exactly
    the no-churn stream: survivors' floats are bit-identical between a
    schedule and its all-live restriction wherever no dead content flows.
    A rejoining worker trips the SSP/ESSP bound on its first read and
    catches up through one forced refresh burst, so the (re-derived)
    staleness contract over *live* readers holds unconditionally.

    ``obs`` (a `repro.obs.ObsSpec`, static) threads a telemetry
    accumulator pytree through the scan carry — pure arithmetic on values
    the step already computes, folded on device and returned as
    ``Trace.obs``.  ``None`` (the default) compiles the exact pre-obs
    program: every other `Trace` field is bit-identical either way.

    ``faults`` (a `repro.comm.wire.WireFaults`, comm substrate only)
    makes the cross-pod wire lossy: shipments drop/duplicate/delay per
    the seeded masks and the substrate answers with the stop-and-wait
    ack/retransmit protocol of `comm.wire` — sequence-guarded
    dedup-on-fold, exponential backoff (retries re-charged into
    ``Trace.ship_floats``), give-up mass self-healing through the
    error-feedback residual, and cross-pod visibility capped by what has
    actually *arrived* (``wire_tip``).  The staleness contract widens by
    ``faults.retry_budget`` clocks.  A neutral schedule
    (`wire.no_faults`) is bit-identical to ``faults=None``.
    """
    P, d = app.n_workers, app.dim
    W = cfg.effective_window
    f32 = jnp.float32
    churned = schedule is not None
    if churned and schedule.live.shape[1] != P:
        raise ValueError(f"schedule has {schedule.live.shape[1]} workers, "
                         f"app has {P}")
    # Static: route cross-pod shipment through the comm substrate
    # (k-clock aggregation + sparse/quantized wire with error feedback —
    # see repro.comm).  Off (the default) is byte-identical to the
    # pre-substrate simulator.
    wired = cfg.comm_active
    G = cfg.n_pods
    obs_enabled = obsm.obs_on(obs)
    faulted = faults is not None
    if faulted:
        wire.validate_faults(faults, cfg, P, W)

    base0 = app.x0.astype(f32)
    uring0 = jnp.zeros((W, P, d), f32)
    uclock0 = jnp.full((W,), RING_EMPTY, jnp.int32)   # slot -> clock stored
    cview0 = jnp.full((P, P), -1, jnp.int32)      # everyone saw "clock -1"
    rng0 = jax.random.PRNGKey(seed)
    # Two-tier staleness bound (hierarchical mode): `s` on intra-pod
    # channels, `s + s_xpod` across pods (+ `agg_clocks - 1` under the
    # substrate).  With n_pods=1 every channel is intra-pod and this is
    # exactly `s` (integer ops — bit-identical).  Under a lossy wire the
    # *trigger* deliberately stays at the unwidened bound: the refresh
    # target is capped on `wire_tip`, so firing eagerly is always safe
    # and keeps views as fresh as arrivals allow; only the *declared*
    # contract (events / validate / model checker) carries the
    # `+ retry_budget` widening for the lag an in-flight shipment can
    # still impose.
    s_eff = staleness_bound_matrix(cfg, jnp.arange(P), P)
    if wired:
        in_pod = same_pod_mask(P, G)                  # [P(r), P(q)]
        reader_pods = pod_of(P, G)                    # [P]
        zeros_d = jnp.zeros((d,), f32)
        comm0 = comm.init_state(W, P, d, G)
        if faulted:
            comm0 = {**comm0, **wire.init_wire_state(P, d)}
    if obs_enabled:
        # channel-tier mask for the forced-refresh split (all-True when
        # G == 1: every forced fetch is intra-pod)
        in_pod_obs = in_pod if wired else same_pod_mask(P, G)

    vmapped_update = jax.vmap(app.worker_update,
                              in_axes=(0, 0, 0, None, 0))
    worker_ids = jnp.arange(P, dtype=jnp.int32)

    def step(carry, c):
        if obs_enabled:
            *carry, oacc = carry
        if wired:
            (base, uring, uclock, cview, local, rng, cst) = carry
        else:
            base, uring, uclock, cview, local, rng = carry
        rng, k_upd, k_net = jax.random.split(rng, 3)

        if churned:
            live_now, died = churn_live(schedule, c)        # [P], [P]
            rates = churn_rates(cfg, schedule, P, c)
            if schedule.drop_inflight:
                # drop policy: a worker dying this clock takes its
                # in-flight (and, wired, unshipped) mass with it — the
                # reference sequence loses those updates too.
                keep = ~died
                uring = jnp.where(keep[None, :, None], uring, 0.0)
                if wired:
                    cst = dict(cst,
                               acc=jnp.where(keep[:, None], cst["acc"], 0.0),
                               res=jnp.where(keep[:, None], cst["res"], 0.0),
                               xring=jnp.where(keep[None, :, None],
                                               cst["xring"], 0.0))
                    if faulted:
                        # the dying producer's pending shipment and
                        # in-flight copy vanish with it too
                        cst = wire.drop_pending(cst, keep)
            cview_pre = cview
        else:
            rates = None

        # Per-producer suffix-aggregate inf-norms of the newest k clocks
        # (kernels/ps_view.py): drives both VAP enforcement and the
        # in-transit metric below.
        norms = ops.vap_suffix_norms(uring, uclock, c)      # [W+1, P]

        # --- 1. pre-read consistency enforcement (blocking fetches) -------
        if cfg.model == "bsp":
            forced = cview < (c - 1)
            cview = jnp.full_like(cview, c - 1)
        elif cfg.model in ("ssp", "essp"):
            # SSP condition: a read at clock c must include all updates of
            # clocks <= c - s_eff - 1 (s intra-pod, s + s_xpod cross-pod,
            # + agg_clocks - 1 under the comm substrate).  Lazy SSP
            # refreshes the whole channel from the server (which holds
            # everything through c-1) exactly when the bound trips — on a
            # cross-pod channel that is the clock-gated reconciliation
            # pull; ESSP rarely trips thanks to (two-tier) pushes.  Under
            # the substrate a cross-pod refresh can only fetch what has
            # *shipped* (through the last aggregation boundary).
            forced = cview < (c - s_eff - 1)
            if wired and faulted:
                # a faulted cross-pod refresh can only fetch what has
                # actually *arrived*: wire_tip caps the shipped boundary
                tgt = jnp.where(in_pod, c - 1,
                                jnp.minimum(
                                    comm.shipped_through(c, cfg.agg_clocks),
                                    cst["wire_tip"][None, :]))
                cview = jnp.where(forced, tgt, cview)
            elif wired:
                tgt = jnp.where(in_pod, c - 1,
                                comm.shipped_through(c, cfg.agg_clocks))
                cview = jnp.where(forced, tgt, cview)
            else:
                cview = jnp.where(forced, c - 1, cview)
        elif cfg.model == "vap":
            cview, forced = enforce_vap(cfg, c, cview, norms, W)
        else:  # async
            forced = jnp.zeros_like(cview, dtype=bool)

        if cfg.read_my_writes:
            eye = jnp.eye(P, dtype=bool)
            cview = jnp.where(eye, c - 1, cview)

        if churned:
            # dead readers neither fetch nor advance: their cview rows
            # freeze at death, which is what trips the bound (one forced
            # burst) on their first read back — the catch-up mechanism.
            forced = forced & live_now[:, None]
            cview = jnp.where(live_now[:, None], cview, cview_pre)

        staleness = cview - c                               # [P, P]

        # VAP-condition metric: max over (reader, producer) channels of the
        # inf-norm of the aggregated in-transit updates at read time.  The
        # channel (r, q) has exactly the newest `c - 1 - cview[r,q]` clocks
        # of producer q in transit, so its norm is one gather from `norms`.
        kcur = jnp.clip(c - 1 - cview, 0, W)                # [P(r), P(q)]
        intransit_inf = jnp.max(norms[kcur, jnp.arange(P)[None, :]])

        # --- 2. materialize views ----------------------------------------
        # visibility mask x update ring -> per-reader views (Pallas on TPU).
        # NOTE on the VAP few-ulp drift PR 3 pinned: under a *multi-device*
        # compilation (sharded sweep, the runtimes) XLA's CPU backend
        # instruction-selects the scan body differently when the VAP
        # enforcement graph is present — a replay of the worker update on
        # bit-identical recorded inputs reproduces the plain-jit value, not
        # the sharded one, and optimization barriers around every stage
        # leave the drift byte-identical, so it is backend codegen
        # (FMA/vectorization of the loop body), not fusion across stages or
        # semantic divergence.  Decisions stay exact; float drift is
        # bounded to a few ulp/value and is app-dependent (MF/LDA are
        # exactly stable).  `tests/test_sweep.py` pins it to a strict ulp
        # budget and asserts MF bit-identity.
        if wired:
            # Split the view per channel tier: intra-pod producers read
            # raw, cross-pod producers read the shipped (compressed) wire
            # ring; the folded base is assembled per reader pod
            # (comm.reader_base).  Masked-out channels see nothing
            # (cview pinned below every stored clock).
            cv_intra = jnp.where(in_pod, cview, RING_EMPTY)
            cv_xpod = jnp.where(in_pod, RING_EMPTY, cview)
            rb = comm.reader_base(base, cst["base_pod"], cst["xbase_pod"],
                                  reader_pods)
            views = (rb + ops.ring_view(zeros_d, uring, uclock, cv_intra)
                     + ops.ring_view(zeros_d, cst["xring"], uclock,
                                     cv_xpod))
        else:
            views = ops.ring_view(base, uring, uclock, cview)

        # --- 3. worker computation ----------------------------------------
        upd_keys = jax.random.split(k_upd, P)
        u, local_new = vmapped_update(views, local, worker_ids, c, upd_keys)
        u = u.astype(f32)
        if churned:
            # dead workers push nothing and their local state freezes;
            # the update still *runs* (vmap has no ragged lanes) but its
            # output is discarded, so survivor lanes are untouched.
            u = jnp.where(live_now[:, None], u, 0.0)
            local = jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    live_now.reshape((P,) + (1,) * (new.ndim - 1)),
                    new, old),
                local_new, local)
        else:
            local = local_new

        # --- 4. commit to server: fold oldest slot, write newest ----------
        slot = jnp.mod(c, W)
        old_valid = uclock[slot] > RING_INVALID
        if wired:
            # recycled slots fold per producer pod: raw into base_pod,
            # wire into xbase_pod (base itself stays x0 — reader bases are
            # assembled per pod in comm.reader_base).
            w_old = jnp.where(old_valid, 1.0, 0.0)
            cst = dict(cst,
                       base_pod=cst["base_pod"]
                       + w_old * comm.fold_pods(uring[slot], G),
                       xbase_pod=cst["xbase_pod"]
                       + w_old * comm.fold_pods(cst["xring"][slot], G))
        else:
            base = base + jnp.where(old_valid, 1.0, 0.0) * jnp.sum(uring[slot], axis=0)
        uring = uring.at[slot].set(u)
        uclock = uclock.at[slot].set(c)
        if wired:
            # --- 4b. comm substrate: accumulate, and ship on boundary ----
            acc = cst["acc"] + u
            delta = acc + cst["res"]                    # [P, d]
            thresh = comm.row_threshold(delta, cfg.topk_frac)
            scale = comm.quant_scale(delta, cfg.quant)
            wire_u, resid = ops.delta_pack(delta, thresh, scale, cfg.quant)
            nnz = comm.selected_count(delta, thresh)
            ship = comm.ship_now(c, cfg.agg_clocks)     # traced bool
            if churned:
                # dead producers hold their shipment: acc/res keep the
                # unshipped mass (drain policy) and release it at the
                # first boundary after rejoin — catching up through the
                # wire ring.
                ship = ship & live_now                  # [P]
            if faulted:
                # stop-and-wait ARQ: a busy producer (previous shipment
                # unacked) skips the boundary — acc keeps accumulating
                # and the skipped content rides the next shipment.
                ship = ship & wire.idle(cst)            # [P]
            ship_b = ship[:, None] if (churned or faulted) else ship
            wire_u = jnp.where(ship_b, wire_u, jnp.zeros_like(wire_u))
            floats = comm.wire_floats(nnz, d, cfg.quant)
            if faulted:
                # the recycled ring slot clears; shipments enter the
                # wire ring only when they *arrive*, via the
                # seq-guarded fold inside wire_step (which also runs
                # retransmits, give-up healing, and this clock's
                # instant arrivals, and charges every transmission —
                # retries included — into ship_floats).
                cst = dict(cst,
                           acc=jnp.where(ship_b, jnp.zeros_like(acc), acc),
                           res=jnp.where(ship_b, resid, cst["res"]),
                           xring=cst["xring"].at[slot].set(
                               jnp.zeros_like(wire_u)))
                cst, ship_floats = wire.wire_step(
                    cst, wire_u, floats, ship, c, faults,
                    live=live_now if churned else None)
            else:
                cst = dict(cst,
                           acc=jnp.where(ship_b, jnp.zeros_like(acc), acc),
                           res=jnp.where(ship_b, resid, cst["res"]),
                           xring=cst["xring"].at[slot].set(wire_u))
                ship_floats = jnp.where(
                    ship, floats, jnp.zeros((P,), f32))
        else:
            ship_floats = comm.dense_ship_floats(cfg.model, P, d)
            if churned:
                ship_floats = jnp.where(live_now, ship_floats, 0.0)

        # --- 5. end-of-clock delivery (affects reads at c+1) --------------
        if cfg.model == "bsp":
            delivered = jnp.ones((P, P), bool)
            if churned:
                # the barrier drains to live readers only; dead rows stay
                # frozen (and catch up through the barrier on rejoin)
                delivered = delivered & live_now[:, None]
                cview = jnp.where(live_now[:, None],
                                  jnp.full_like(cview, c), cview)
            else:
                cview = jnp.full_like(cview, c)
        elif cfg.model == "ssp":
            delivered = jnp.zeros((P, P), bool)   # pull-based: no pushes
        else:  # essp / async / vap: delay-driven eager delivery
            delivered = _delivery(k_net, cfg, P, rates)
            if churned:
                # pushes to dead readers are lost (their caches are gone);
                # the sampling itself is unmasked so survivor channels see
                # the identical RNG draws with or without churn.
                delivered = delivered & live_now[:, None]
            if wired and faulted:
                # a cross-pod delivery carries the latest *arrived*
                # shipment: the boundary target capped by wire_tip
                # (updated by this clock's arrivals in wire_step above)
                tgt = jnp.where(in_pod, c,
                                jnp.minimum(
                                    comm.shipped_end(c, cfg.agg_clocks),
                                    cst["wire_tip"][None, :]))
                cview = jnp.where(delivered, jnp.maximum(cview, tgt),
                                  cview)
            elif wired:
                # a cross-pod delivery carries the latest *shipment*, so
                # visibility advances only to the aggregation boundary
                # (== c when agg_clocks == 1).
                tgt = jnp.where(in_pod, c,
                                comm.shipped_end(c, cfg.agg_clocks))
                cview = jnp.where(delivered, jnp.maximum(cview, tgt),
                                  cview)
            else:
                cview = jnp.where(delivered, c, cview)

        # --- 6. record ------------------------------------------------------
        if wired:
            x_ref = (base + jnp.sum(cst["base_pod"], axis=0)) + jnp.sum(
                uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
        else:
            x_ref = base + jnp.sum(
                uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
        loss_ref = app.loss(x_ref, local)
        loss_view = app.loss(views[0], local)
        out = dict(loss_ref=loss_ref, loss_view=loss_view,
                   staleness=staleness, forced=forced, delivered=delivered,
                   u_l2=jnp.linalg.norm(u, axis=-1),
                   intransit_inf=intransit_inf, ship_floats=ship_floats,
                   live=live_now if churned else jnp.ones((P,), bool))
        if record_views:
            out["views0"] = views[0]
        if obs_enabled:
            # fold this clock's already-computed step values into the
            # accumulators — the only obs work inside the compiled step
            oacc = obsm.device_update(
                oacc, staleness=staleness, forced=forced,
                delivered=delivered, ship_floats=ship_floats,
                live=out["live"], live_rows=out["live"],
                in_pod=in_pod_obs)
        new_carry = ((base, uring, uclock, cview, local, rng, cst)
                     if wired else
                     (base, uring, uclock, cview, local, rng))
        if obs_enabled:
            new_carry = (*new_carry, oacc)
        return new_carry, out

    carry0 = ((base0, uring0, uclock0, cview0, app.local0, rng0, comm0)
              if wired else
              (base0, uring0, uclock0, cview0, app.local0, rng0))
    if obs_enabled:
        carry0 = (*carry0, obsm.device_init(P, obs.n_buckets))
    carryT, ys = jax.lax.scan(step, carry0,
                              jnp.arange(n_clocks, dtype=jnp.int32))
    base, uring, uclock, _, local = carryT[0], carryT[1], carryT[2], \
        carryT[3], carryT[4]
    if wired:
        cst = carryT[6]
        x_final = (base + jnp.sum(cst["base_pod"], axis=0)) + jnp.sum(
            uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
    else:
        x_final = base + jnp.sum(
            uring * (uclock[:, None, None] > RING_INVALID), axis=(0, 1))
    return Trace(
        loss_ref=ys["loss_ref"], loss_view=ys["loss_view"],
        staleness=ys["staleness"], forced=ys["forced"],
        delivered=ys["delivered"], u_l2=ys["u_l2"],
        intransit_inf=ys["intransit_inf"], ship_floats=ys["ship_floats"],
        live=ys["live"], views0=ys.get("views0"), x_final=x_final,
        locals_final=local, obs=carryT[-1] if obs_enabled else None)


def simulate_jit(app: PSApp, cfg: ConsistencyConfig, n_clocks: int,
                 seed=0, record_views: bool = False,
                 schedule: ChurnSchedule | None = None,
                 obs: obsm.ObsSpec | None = None,
                 faults: wire.WireFaults | None = None) -> Trace:
    """jit-compiled run; ``seed`` may be a traced int (vmap over seeds).

    The schedule's (and fault schedule's) arrays enter as jit arguments,
    so re-running with a different same-shape schedule reuses the
    compiled program (``None`` is an empty pytree — presence is part of
    the trace structure)."""
    fn = jax.jit(lambda sd, sch, flt: simulate(
        app, cfg, n_clocks, sd, record_views, schedule=sch, obs=obs,
        faults=flt))
    return fn(jnp.asarray(seed, jnp.uint32), schedule, faults)
