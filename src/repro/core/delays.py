"""Network-delay / straggler models for the PS simulator.

The paper's motivation for bounded staleness is *stragglers*: transient or
persistent slow workers whose updates arrive late.  The simulator models
delivery as per-channel Bernoulli trials each clock (geometric delays); this
module adds structured heterogeneity on top:

- ``worker_rates(cfg, P)``: per-*producer* delivery-rate multipliers — the
  first ``straggler_workers`` workers push at ``straggler_rate`` of the
  nominal rate (persistently slow machines);
- ``delivery_matrix``: the full [reader, producer] delivery sample used by
  `ps.simulate` each clock (channel congestion x producer slowness).

Everything is driven by the ConsistencyConfig so experiment sweeps stay
declarative (see benchmarks/stragglers.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .consistency import ConsistencyConfig


def worker_rates(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Per-producer delivery-rate multipliers in (0, 1].

    ``straggler_workers`` / ``straggler_rate`` may be traced values (the
    sweep engine vmaps over them), so the slow-producer prefix is selected
    with a data-dependent ``where`` rather than Python slicing.
    """
    n = getattr(cfg, "straggler_workers", 0)
    rate = getattr(cfg, "straggler_rate", 1.0)
    ids = jnp.arange(P)
    return jnp.where(ids < n, jnp.asarray(rate, jnp.float32), 1.0)


def delivery_matrix(rng, cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Sample the end-of-clock delivery matrix [P(reader), P(producer)].

    A channel delivers this clock iff (a) the producer's push lands
    (Bernoulli(push_prob x producer_rate)) and (b) the channel is not
    transiently congested (Bernoulli(straggler_prob) blocks it).
    """
    k1, k2 = jax.random.split(rng)
    rates = worker_rates(cfg, P)
    p = cfg.push_prob * rates[None, :]             # [1, producer]
    pushed = jax.random.uniform(k1, (P, P)) < p
    congested = jax.random.bernoulli(k2, cfg.straggler_prob, (P, P))
    return pushed & ~congested


def expected_delay(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Analytic mean delivery delay per producer (geometric): 1/p clocks."""
    rates = worker_rates(cfg, P)
    p = cfg.push_prob * rates * (1.0 - cfg.straggler_prob)
    return 1.0 / jnp.maximum(p, 1e-6)
