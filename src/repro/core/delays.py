"""Network-delay / straggler models for the PS simulator.

The paper's motivation for bounded staleness is *stragglers*: transient or
persistent slow workers whose updates arrive late.  The simulator models
delivery as per-channel Bernoulli trials each clock (geometric delays); this
module adds structured heterogeneity on top:

- ``worker_rates(cfg, P)``: per-*producer* delivery-rate multipliers — the
  first ``straggler_workers`` workers push at ``straggler_rate`` of the
  nominal rate (persistently slow machines);
- ``delivery_matrix``: the full [reader, producer] delivery sample used by
  `ps.simulate` each clock (channel congestion x producer slowness).

Two-tier (hierarchical) delivery
--------------------------------
With ``cfg.n_pods > 1`` the ``P`` workers are partitioned into contiguous
pod blocks (:func:`pod_of`) and every (reader, producer) channel belongs to
one of two network tiers: *intra-pod* (mean delivery delay ``t_net_intra``
clocks) or *cross-pod* (``t_net_xpod`` clocks, typically ~10x slower — the
datacenter second tier).  A tier with mean delay ``t`` delivers a push
within one clock with probability ``push_prob / max(t, 1)`` (geometric
delays, so the mean delay really is ``~t/push_prob`` clocks).  Both ``t``
knobs are traced data leaves of `ConsistencyConfig`, so sweeps batch over
network-tier ratios exactly like any other knob.  At the defaults
(``n_pods=1`` or ``t_net_* = 1``) the sample is bit-identical to the flat
single-tier model — the same uniforms compared against the same
probabilities.

Fleet churn
-----------
:class:`ChurnSchedule` makes the fleet itself a traced axis: a per-clock
worker liveness mask (worker outages, whole-pod drop/rejoin windows), an
optional mid-run straggler-*regime* shift (per-clock ``straggler_workers``
/ ``straggler_rate`` arrays overriding the config's static knobs), and an
optional per-clock ``bandwidth_xpod`` multiplier consumed only by
`core.timemodel.TimeModel`.  Both engines (`core.ps.simulate` and
`psrun.runtime`) accept a schedule and honor it identically: dead workers
push nothing (their updates are zeroed before entering the ring), their
reader rows of ``cview`` freeze, and their in-flight updates either keep
draining to survivors (the default) or drop at death
(``drop_inflight=True``).  The schedule is an ordinary pytree whose arrays
are traced jit arguments — different schedules of the same shape reuse the
compiled program — and indexing is by *absolute* clock, so a
``run_from`` segment sees exactly the slice the uninterrupted run would.

Everything is driven by the ConsistencyConfig so experiment sweeps stay
declarative (see benchmarks/stragglers.py, benchmarks/pods_bench.py,
benchmarks/robustness.py for churn scenarios).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .consistency import ConsistencyConfig


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ChurnSchedule:
    """Per-clock fleet churn, indexed by absolute clock.

    ``live[t, p]`` is worker ``p``'s liveness at clock ``t`` (clocks past
    the schedule's horizon clamp to the last row).  The optional regime
    arrays override the config's static straggler knobs per clock; the
    optional ``bw_scale`` multiplies ``TimeModel.bandwidth_xpod`` per
    clock (a transient cross-pod bandwidth crunch) and never touches the
    traces.  ``drop_inflight`` selects the in-flight policy at death:
    False (default) lets a dead worker's already-produced updates keep
    draining to survivors; True drops its ring rows (and, under the comm
    substrate, its unshipped accumulator/residual/wire rows) the clock it
    dies.
    """

    live: jax.Array                 # [T, P] bool worker liveness per clock
    straggler_workers: Any = None   # [T] i32 per-clock slow-worker count
    straggler_rate: Any = None      # [T] f32 per-clock slow-worker rate
    bw_scale: Any = None            # [T] f32 bandwidth_xpod multiplier
    #                                 (TimeModel only — not in the traces)
    drop_inflight: bool = field(default=False, metadata=dict(static=True))

    @property
    def n_clocks(self) -> int:
        return self.live.shape[0]

    @property
    def n_workers(self) -> int:
        return self.live.shape[1]


def no_churn(n_clocks: int, P: int) -> ChurnSchedule:
    """The neutral schedule: everyone live, no regime shift.  Running with
    it is bit-identical to running with no schedule at all (pinned by
    ``tests/test_churn.py``)."""
    return ChurnSchedule(live=jnp.ones((n_clocks, P), bool))


def make_churn(n_clocks: int, P: int, *, n_pods: int = 1,
               worker_outages=(), pod_outages=(), regime_shift=None,
               bw_drop=None, drop_inflight: bool = False) -> ChurnSchedule:
    """Build a `ChurnSchedule` from scenario primitives.

    - ``worker_outages``: ``(worker, t0, t1)`` triples — worker dead on
      clocks ``[t0, t1)``;
    - ``pod_outages``: ``(pod, t0, t1)`` triples — every worker of the pod
      (contiguous blocks, `pod_of`) dead on ``[t0, t1)``;
    - ``regime_shift``: ``(clock, n_workers, rate)`` — from ``clock`` on,
      the first ``n_workers`` producers push at ``rate`` of nominal
      (before it: no stragglers — pass explicit arrays for a different
      baseline regime);
    - ``bw_drop``: ``(t0, t1, scale)`` — cross-pod bandwidth multiplied by
      ``scale`` on ``[t0, t1)`` (TimeModel only).
    """
    live = np.ones((n_clocks, P), bool)
    for w, t0, t1 in worker_outages:
        live[t0:t1, w] = False
    pods = np.asarray(pod_of(P, n_pods))
    for g, t0, t1 in pod_outages:
        live[t0:t1, pods == g] = False
    sw = sr = bws = None
    if regime_shift is not None:
        t0, n_w, rate = regime_shift
        sw = np.zeros(n_clocks, np.int32)
        sw[t0:] = n_w
        sr = np.ones(n_clocks, np.float32)
        sr[t0:] = rate
    if bw_drop is not None:
        t0, t1, scale = bw_drop
        bws = np.ones(n_clocks, np.float32)
        bws[t0:t1] = scale
    return ChurnSchedule(
        live=jnp.asarray(live),
        straggler_workers=None if sw is None else jnp.asarray(sw),
        straggler_rate=None if sr is None else jnp.asarray(sr),
        bw_scale=None if bws is None else jnp.asarray(bws),
        drop_inflight=drop_inflight)


def churn_live(schedule: ChurnSchedule, c):
    """``(live_now[P], died[P])`` at (possibly traced) absolute clock ``c``.

    ``died`` marks workers whose outage *starts* this clock (live at
    ``c-1``, dead at ``c``) — the edge the ``drop_inflight`` policy acts
    on.  Clocks beyond the schedule clamp to its last row, so a short
    schedule extends its final fleet state indefinitely.
    """
    T = schedule.live.shape[0]
    t = jnp.clip(c, 0, T - 1)
    live_now = schedule.live[t]
    prev = jnp.where(c > 0, schedule.live[jnp.clip(c - 1, 0, T - 1)], True)
    died = prev & ~live_now
    return live_now, died


def churn_rates(_cfg: ConsistencyConfig, schedule: ChurnSchedule | None,
                P: int, c) -> jax.Array | None:
    """Per-producer rate multipliers at clock ``c`` under the schedule's
    straggler regime, or ``None`` when the schedule carries no regime
    arrays (callers then fall back to the config's static
    :func:`worker_rates` — the bit-identical default path)."""
    if schedule is None or schedule.straggler_workers is None:
        return None
    T = schedule.straggler_workers.shape[0]
    t = jnp.clip(c, 0, T - 1)
    n = schedule.straggler_workers[t]
    rate = schedule.straggler_rate[t].astype(jnp.float32)
    ids = jnp.arange(P)
    return jnp.where(ids < n, rate, 1.0)


def outage_windows(live) -> "list[tuple[int, int, int]]":
    """Oracle outages as ``(worker, t0, t1)`` — dead on ``[t0, t1)``.

    ``live`` is any ``[T, P]`` bool mask (a `ChurnSchedule.live`, or the
    reconstruction `repro.obs.monitor.live_from_events` builds from a
    stream's churn transitions).  An outage still open at the horizon
    closes at ``t1 = T``.
    """
    live = np.asarray(live, bool)
    T, P = live.shape
    out = []
    for w in range(P):
        t0 = None
        for t in range(T):
            if not live[t, w] and t0 is None:
                t0 = t
            elif live[t, w] and t0 is not None:
                out.append((w, t0, t))
                t0 = None
        if t0 is not None:
            out.append((w, t0, T))
    return out


def score_detections(live, verdicts, budget_clocks: int) -> dict:
    """Score failure-detector verdicts against the oracle ``live`` mask.

    ``verdicts`` is `repro.obs.monitor.FailureDetector` output; only the
    ``worker_down`` alarms are scored.  An alarm at clock ``t`` claiming
    ``missed`` silent clocks asserts the worker was dead somewhere in the
    silence window ``[t - missed, t)`` — a **false alarm** is an alarm
    whose window contains no oracle-dead clock for that worker.  A true
    alarm's **latency** is ``t - t0`` clocks past the outage start; an
    outage is **detected in budget** when some alarm lands within
    ``budget_clocks`` of its start (the claim `benchmarks.detect_bench`
    gates on is ``budget <= s + agg_clocks``).  Outages too short or too
    late to be detectable at all (shorter than the detector could ever
    see: over before ``timeout_clocks`` silent clocks accrue, or open at
    the horizon with fewer than ``budget_clocks`` remaining) still count
    — scenario grids should seed detectable outages.
    """
    live = np.asarray(live, bool)
    T = live.shape[0]
    alarms = [v for v in verdicts if v.get("kind") == "worker_down"]
    windows = outage_windows(live)
    false_alarms, latencies = [], {}
    for v in alarms:
        w, t = v["worker"], v["t"]
        silence0 = t - v.get("missed", 1)
        hit = None
        for (ow, t0, t1) in windows:
            if ow == w and t0 < t and silence0 < t1:
                hit = (ow, t0, t1)
                break
        if hit is None:
            false_alarms.append(v)
        else:
            lat = t - hit[1]
            prev = latencies.get(hit)
            latencies[hit] = lat if prev is None else min(prev, lat)
    missed = [wd for wd in windows if wd not in latencies]
    in_budget = [wd for wd, lat in latencies.items()
                 if lat <= budget_clocks]
    return {
        "n_outages": len(windows),
        "n_alarms": len(alarms),
        "n_false_alarms": len(false_alarms),
        "false_alarms": false_alarms,
        "n_detected": len(latencies),
        "n_missed": len(missed),
        "missed": missed,
        "n_in_budget": len(in_budget),
        "budget_clocks": budget_clocks,
        "latencies": {f"w{w}@{t0}": lat
                      for (w, t0, _t1), lat in sorted(latencies.items())},
        "max_latency": (max(latencies.values()) if latencies else None),
        "all_detected_in_budget": (len(in_budget) == len(windows)
                                   and not false_alarms),
        "horizon": T,
    }


def pod_of(P: int, n_pods: int) -> jax.Array:
    """Pod id of each worker: ``n_pods`` contiguous equal blocks ([P] i32).

    Matches the worker partition of the ``("pod","data")`` mesh axes in
    ``repro.pods`` (pod-major, then data-shard within the pod).
    """
    if P % n_pods:
        raise ValueError(f"n_workers={P} must divide by n_pods={n_pods}")
    return (jnp.arange(P, dtype=jnp.int32) // (P // n_pods)).astype(jnp.int32)


def same_pod_mask(P: int, n_pods: int) -> jax.Array:
    """[reader, producer] bool: True where the channel stays intra-pod."""
    pod = pod_of(P, n_pods)
    return pod[:, None] == pod[None, :]


def staleness_bound_matrix(cfg: ConsistencyConfig, reader_ids,
                           P: int, retry_budget: int = 0) -> jax.Array:
    """Per-channel SSP/ESSP staleness bound [readers, P(producer)].

    ``cfg.staleness`` on intra-pod channels, ``+ s_xpod`` across pods — the
    two-tier bounded-staleness contract.  Under the comm substrate
    (``cfg.comm_active``) k-clock delta aggregation holds cross-pod content
    back up to ``agg_clocks - 1`` extra clocks, so the cross-pod bound
    widens to ``s + s_xpod + agg_clocks - 1`` (asserted by
    ``psrun.validate.check_staleness_bound``).  Under a lossy wire
    (``comm.wire.WireFaults``) the ack/retransmit protocol can hold a
    shipment in flight for up to ``retry_budget`` further clocks
    (``WireFaults.retry_budget`` — two flight windows: one for the
    in-flight shipment, one for the boundary skipped while it was
    unacked), widening the cross-pod bound again.  ``retry_budget`` is 0
    on a perfect wire, keeping the matrix bit-identical to the lossless
    contract.  ``reader_ids`` selects the reader rows (all of them in the
    simulator, the shard-local rows in the runtimes), so the same helper
    drives both engines.  Integer ops only: bit-identical to the flat
    bound when ``n_pods == 1`` (and to the PR 4 two-tier bound when the
    substrate is off or ``agg_clocks == 1``).
    """
    pods = pod_of(P, cfg.n_pods)
    same = pods[reader_ids][:, None] == pods[None, :]
    xpod_bound = cfg.staleness + cfg.s_xpod
    if cfg.comm_active:
        xpod_bound = xpod_bound + (cfg.agg_clocks - 1) + retry_budget
    return jnp.where(same, cfg.staleness, xpod_bound)


def worker_rates(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Per-producer delivery-rate multipliers in (0, 1].

    ``straggler_workers`` / ``straggler_rate`` may be traced values (the
    sweep engine vmaps over them), so the slow-producer prefix is selected
    with a data-dependent ``where`` rather than Python slicing.
    """
    n = getattr(cfg, "straggler_workers", 0)
    rate = getattr(cfg, "straggler_rate", 1.0)
    ids = jnp.arange(P)
    return jnp.where(ids < n, jnp.asarray(rate, jnp.float32), 1.0)


def channel_push_prob(cfg: ConsistencyConfig, P: int,
                      rates=None) -> jax.Array:
    """Per-channel one-clock delivery probability [reader, producer].

    ``push_prob x producer_rate``, divided by the channel's tier delay
    (``t_net_intra`` intra-pod, ``t_net_xpod`` cross-pod).  Division by the
    default delay 1.0 is exact, keeping the flat model bit-identical.
    ``rates`` overrides the config-derived producer multipliers (a churn
    schedule's per-clock straggler regime, :func:`churn_rates`).
    """
    if rates is None:
        rates = worker_rates(cfg, P)
    p = cfg.push_prob * rates[None, :]                    # [1, producer]
    tier_i = 1.0 / jnp.maximum(jnp.asarray(cfg.t_net_intra, jnp.float32), 1.0)
    tier_x = 1.0 / jnp.maximum(jnp.asarray(cfg.t_net_xpod, jnp.float32), 1.0)
    same = same_pod_mask(P, cfg.n_pods)
    return p * jnp.where(same, tier_i, tier_x)            # [reader, producer]


def delivery_matrix(rng, cfg: ConsistencyConfig, P: int,
                    rates=None) -> jax.Array:
    """Sample the end-of-clock delivery matrix [P(reader), P(producer)].

    A channel delivers this clock iff (a) the producer's push crosses the
    channel's network tier (Bernoulli(push_prob x producer_rate / t_tier))
    and (b) the channel is not transiently congested
    (Bernoulli(straggler_prob) blocks it).  ``rates`` threads a churn
    schedule's per-clock straggler regime through (same uniforms, shifted
    thresholds — the RNG stream is schedule-independent).
    """
    k1, k2 = jax.random.split(rng)
    p = channel_push_prob(cfg, P, rates)
    pushed = jax.random.uniform(k1, (P, P)) < p
    congested = jax.random.bernoulli(k2, cfg.straggler_prob, (P, P))
    return pushed & ~congested


def expected_delay(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Analytic mean delivery delay per channel (geometric): 1/p clocks.

    Shape [reader, producer]; rows are identical in the flat (single-pod)
    model, where this reduces to the historical per-producer vector."""
    p = channel_push_prob(cfg, P) * (1.0 - cfg.straggler_prob)
    return 1.0 / jnp.maximum(p, 1e-6)
