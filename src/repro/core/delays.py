"""Network-delay / straggler models for the PS simulator.

The paper's motivation for bounded staleness is *stragglers*: transient or
persistent slow workers whose updates arrive late.  The simulator models
delivery as per-channel Bernoulli trials each clock (geometric delays); this
module adds structured heterogeneity on top:

- ``worker_rates(cfg, P)``: per-*producer* delivery-rate multipliers — the
  first ``straggler_workers`` workers push at ``straggler_rate`` of the
  nominal rate (persistently slow machines);
- ``delivery_matrix``: the full [reader, producer] delivery sample used by
  `ps.simulate` each clock (channel congestion x producer slowness).

Two-tier (hierarchical) delivery
--------------------------------
With ``cfg.n_pods > 1`` the ``P`` workers are partitioned into contiguous
pod blocks (:func:`pod_of`) and every (reader, producer) channel belongs to
one of two network tiers: *intra-pod* (mean delivery delay ``t_net_intra``
clocks) or *cross-pod* (``t_net_xpod`` clocks, typically ~10x slower — the
datacenter second tier).  A tier with mean delay ``t`` delivers a push
within one clock with probability ``push_prob / max(t, 1)`` (geometric
delays, so the mean delay really is ``~t/push_prob`` clocks).  Both ``t``
knobs are traced data leaves of `ConsistencyConfig`, so sweeps batch over
network-tier ratios exactly like any other knob.  At the defaults
(``n_pods=1`` or ``t_net_* = 1``) the sample is bit-identical to the flat
single-tier model — the same uniforms compared against the same
probabilities.

Everything is driven by the ConsistencyConfig so experiment sweeps stay
declarative (see benchmarks/stragglers.py, benchmarks/pods_bench.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .consistency import ConsistencyConfig


def pod_of(P: int, n_pods: int) -> jax.Array:
    """Pod id of each worker: ``n_pods`` contiguous equal blocks ([P] i32).

    Matches the worker partition of the ``("pod","data")`` mesh axes in
    ``repro.pods`` (pod-major, then data-shard within the pod).
    """
    if P % n_pods:
        raise ValueError(f"n_workers={P} must divide by n_pods={n_pods}")
    return (jnp.arange(P, dtype=jnp.int32) // (P // n_pods)).astype(jnp.int32)


def same_pod_mask(P: int, n_pods: int) -> jax.Array:
    """[reader, producer] bool: True where the channel stays intra-pod."""
    pod = pod_of(P, n_pods)
    return pod[:, None] == pod[None, :]


def staleness_bound_matrix(cfg: ConsistencyConfig, reader_ids,
                           P: int) -> jax.Array:
    """Per-channel SSP/ESSP staleness bound [readers, P(producer)].

    ``cfg.staleness`` on intra-pod channels, ``+ s_xpod`` across pods — the
    two-tier bounded-staleness contract.  Under the comm substrate
    (``cfg.comm_active``) k-clock delta aggregation holds cross-pod content
    back up to ``agg_clocks - 1`` extra clocks, so the cross-pod bound
    widens to ``s + s_xpod + agg_clocks - 1`` (asserted by
    ``psrun.validate.check_staleness_bound``).  ``reader_ids`` selects the
    reader rows (all of them in the simulator, the shard-local rows in the
    runtimes), so the same helper drives both engines.  Integer ops only:
    bit-identical to the flat bound when ``n_pods == 1`` (and to the PR 4
    two-tier bound when the substrate is off or ``agg_clocks == 1``).
    """
    pods = pod_of(P, cfg.n_pods)
    same = pods[reader_ids][:, None] == pods[None, :]
    xpod_bound = cfg.staleness + cfg.s_xpod
    if cfg.comm_active:
        xpod_bound = xpod_bound + (cfg.agg_clocks - 1)
    return jnp.where(same, cfg.staleness, xpod_bound)


def worker_rates(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Per-producer delivery-rate multipliers in (0, 1].

    ``straggler_workers`` / ``straggler_rate`` may be traced values (the
    sweep engine vmaps over them), so the slow-producer prefix is selected
    with a data-dependent ``where`` rather than Python slicing.
    """
    n = getattr(cfg, "straggler_workers", 0)
    rate = getattr(cfg, "straggler_rate", 1.0)
    ids = jnp.arange(P)
    return jnp.where(ids < n, jnp.asarray(rate, jnp.float32), 1.0)


def channel_push_prob(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Per-channel one-clock delivery probability [reader, producer].

    ``push_prob x producer_rate``, divided by the channel's tier delay
    (``t_net_intra`` intra-pod, ``t_net_xpod`` cross-pod).  Division by the
    default delay 1.0 is exact, keeping the flat model bit-identical.
    """
    rates = worker_rates(cfg, P)
    p = cfg.push_prob * rates[None, :]                    # [1, producer]
    tier_i = 1.0 / jnp.maximum(jnp.asarray(cfg.t_net_intra, jnp.float32), 1.0)
    tier_x = 1.0 / jnp.maximum(jnp.asarray(cfg.t_net_xpod, jnp.float32), 1.0)
    same = same_pod_mask(P, cfg.n_pods)
    return p * jnp.where(same, tier_i, tier_x)            # [reader, producer]


def delivery_matrix(rng, cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Sample the end-of-clock delivery matrix [P(reader), P(producer)].

    A channel delivers this clock iff (a) the producer's push crosses the
    channel's network tier (Bernoulli(push_prob x producer_rate / t_tier))
    and (b) the channel is not transiently congested
    (Bernoulli(straggler_prob) blocks it).
    """
    k1, k2 = jax.random.split(rng)
    p = channel_push_prob(cfg, P)
    pushed = jax.random.uniform(k1, (P, P)) < p
    congested = jax.random.bernoulli(k2, cfg.straggler_prob, (P, P))
    return pushed & ~congested


def expected_delay(cfg: ConsistencyConfig, P: int) -> jax.Array:
    """Analytic mean delivery delay per channel (geometric): 1/p clocks.

    Shape [reader, producer]; rows are identical in the flat (single-pod)
    model, where this reduces to the historical per-producer vector."""
    p = channel_push_prob(cfg, P) * (1.0 - cfg.straggler_prob)
    return 1.0 / jnp.maximum(p, 1e-6)
