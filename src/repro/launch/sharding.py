"""Logical-axis -> mesh-axis rules, and spec construction for the dry-run.

Parameters carry logical axis names (see models/params.py); activations are
annotated with `layers.shd`.  The rules here map those names onto the
production mesh.  Two profiles:

- "tp":       tensor parallel over "model" only; params replicated over the
              data axes.  Right for <=30B-scale configs (params already /16).
- "tp_fsdp":  additionally shards the params' "embed" dim over
              ("pod","data") — ZeRO-3-style; required for jamba-398B.

KV-cache and batch shardings are shape-dependent (decode batch may be 1, in
which case the cache *sequence* dim takes the data axes instead).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import LONG_CONTEXT_WINDOW, ModelConfig, ShapeConfig
from ..models import params as params_lib


DATA_AXES = ("pod", "data")    # filtered to existing mesh axes automatically


def param_rules(profile: str) -> dict:
    rules = {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "kv_lora": "model",
        "head_dim": None,
        "embed": None,
        "layers": None,
    }
    if profile == "tp_fsdp":
        rules["embed"] = DATA_AXES
        # NOTE (§Perf jamba log, hypothesis refuted): routing experts onto
        # the data axes ("expert parallelism without parameter gathers")
        # made the partitioner un-shard the token batch instead — 2.3x the
        # memory and 2.4x the flops.  Expert weights keep experts->model +
        # embed->data (256-way sharded, gathered per group like the rest of
        # the FSDP params).
    return rules


def activation_rules(shape: ShapeConfig | None = None) -> dict:
    # Megatron-style sequence parallelism on the residual stream for
    # full-sequence passes; decode steps have seq=1 (annotation drops).
    sp = "model" if (shape is None or shape.kind != "decode") else None
    return {
        "batch": DATA_AXES,
        "seq": None,
        "seq_res": sp,
        "embed": None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "vocab": "model",
    }


def profile_for(cfg: ModelConfig) -> str:
    """tp_fsdp for jamba-398B (cannot replicate) and for wide-expert MoE
    (§Perf pair 2: sharding 30B of replicated expert state over the data
    axes flips fits-HBM from 39.5 GiB to 11.8 GiB at ~equal collective
    traffic); plain TP elsewhere."""
    if cfg.n_layers * cfg.d_model >= 72 * 8192:
        return "tp_fsdp"
    if cfg.moe is not None and cfg.moe.n_experts >= 64:
        return "tp_fsdp"
    return "tp"


def _filter_axes(mesh, axes, dim):
    """Keep only mesh axes that exist and whose product divides dim."""
    if axes is None:
        return None
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    keep = []
    prod = 1
    for a in axes:
        if a in mesh.axis_names and dim % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    if not keep:
        return None
    return keep[0] if len(keep) == 1 else tuple(keep)


def ns(mesh, shape, *axes):
    """NamedSharding over `shape` with per-dim mesh-axis requests, dropping
    non-dividing or missing axes (and axes already used by earlier dims)."""
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes, strict=True):
        ax = _filter_axes(mesh, ax, dim)
        if ax is None:
            out.append(None)
            continue
        t = (ax,) if isinstance(ax, str) else tuple(ax)
        t = tuple(a for a in t if a not in used)
        used.update(t)
        out.append(t[0] if len(t) == 1 else (t if t else None))
    return NamedSharding(mesh, P(*out))


# --------------------------------------------------------------------------
# batch input specs per shape
# --------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                accum: int = 1) -> dict:
    """ShapeDtypeStructs (with shardings) for the step's batch inputs.

    ``accum > 1`` prepends a microbatch axis (gradient accumulation — the
    paper's update coalescing); the global batch is split across it.
    """
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    assert B % accum == 0, (B, accum)
    lead = (accum,) if accum > 1 else ()
    lax_ = (None,) if accum > 1 else ()

    def mk(shape_, dtype, *axes):
        return jax.ShapeDtypeStruct(
            lead + shape_, dtype, sharding=ns(mesh, lead + shape_,
                                              *(lax_ + axes)))

    tok = mk((B // accum, S), jnp.int32, DATA_AXES, None)
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = tok
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = mk((B // accum, cfg.encoder.n_ctx, cfg.d_model),
                           cfg.cdtype, DATA_AXES, None, None)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = mk(
            (B // accum, cfg.vision.n_image_tokens, cfg.d_model),
            cfg.cdtype, DATA_AXES, None, None)
    return out


# --------------------------------------------------------------------------
# cache specs (decode/prefill)
# --------------------------------------------------------------------------
_CACHE_AXIS_PATTERNS = {
    # leaf name -> axes request per trailing dim (after the [layers, batch])
    "k": (None, "kv_heads", None),
    "v": (None, "kv_heads", None),
    "ckv": (None, "kv_lora"),
    "krope": (None, None),
    "conv": (None, "mlp"),
    "ssm": ("heads", None, None),
    "cross_k": (None, "kv_heads", None),
    "cross_v": (None, "kv_heads", None),
    "pos": (),
}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                batch_shardable: bool) -> Any:
    """Shape/sharding specs for the stacked KV/SSM caches.

    When the batch does not divide the data axes (long_500k, B=1), the cache
    *sequence* dim (dim 2 of k/v/ckv/krope leaves) takes the data axes.
    """
    from ..models.registry import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 cfg.cdtype))

    rules = param_rules("tp")  # head/group axes onto "model"
    batch_ax = DATA_AXES if batch_shardable else None
    seq_ax = None if batch_shardable else DATA_AXES

    model_size = mesh.shape["model"]

    def assign(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        pat = _CACHE_AXIS_PATTERNS.get(name)
        if pat is None:
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, P(*([None] * len(leaf.shape)))))
        # leaf dims: [<stack dims...>, batch, <pattern dims>]; hybrid caches
        # have two stack dims (outer group, inner sublayer).
        lead = leaf.ndim - len(pat) - 1
        axes = [None] * lead + [batch_ax]
        for i, a in enumerate(pat):
            if i == 0 and name in ("k", "v", "ckv", "krope"):
                # cache sequence dim: takes the data axes when the batch is
                # not shardable; additionally takes "model" when the head /
                # lora dim cannot absorb it (e.g. kv_heads=8 on a 16-way
                # model axis) — the seq dim always divides.
                head_dim_size = (leaf.shape[lead + 2]
                                 if len(pat) >= 2 else 0)
                head_rule = rules.get(pat[1]) if len(pat) >= 2 and \
                    isinstance(pat[1], str) else None
                head_ok = (head_rule == "model"
                           and head_dim_size % model_size == 0)
                if seq_ax is not None:
                    req = (seq_ax if head_ok
                           else tuple(seq_ax) + ("model",))
                else:
                    req = None if head_ok else "model"
                axes.append(req)
            else:
                axes.append(rules.get(a) if isinstance(a, str) else a)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=ns(mesh, leaf.shape, *axes))

    return jax.tree_util.tree_map_with_path(assign, shapes)


# --------------------------------------------------------------------------
# params / train-state specs
# --------------------------------------------------------------------------
def param_shardings(model_specs, mesh, profile: str):
    return params_lib.shardings(model_specs, mesh, param_rules(profile))


def param_structs(model_specs, mesh, profile: str):
    return params_lib.shape_structs(model_specs, mesh, param_rules(profile))


def state_structs(model, opt, sync, mesh, profile: str):
    """ShapeDtypeStruct tree for the full TrainState, sharded."""
    from ..train.state import init_state
    shapes = jax.eval_shape(
        lambda: init_state(model, opt, sync, jax.random.PRNGKey(0)))
    pshard = param_shardings(model.param_specs, mesh, profile)

    flat_p, pdef = jax.tree_util.tree_flatten(pshard)

    def like_params(_tree):
        """Map a tree with params-shaped subtree onto param shardings."""
        return jax.tree_util.tree_unflatten(pdef, flat_p)

    repl = NamedSharding(mesh, P())

    def assign_opt(shapes_opt):
        out = {}
        for k, v in shapes_opt.items():
            if k in ("m", "v", "mu"):
                sh = like_params(v)
                out[k] = jax.tree.map(
                    lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape,
                                                         leaf.dtype,
                                                         sharding=s), v, sh)
            else:
                out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=repl)
        return out

    params_structs = jax.tree.map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                             sharding=s),
        shapes.params, pshard)

    fifo = shapes.fifo
    if fifo is not None:
        buf = jax.tree.map(
            lambda leaf, s: jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype,
                sharding=NamedSharding(
                    mesh, P(*((None,) + tuple(s.spec)))) ),
            fifo["buf"], pshard)
        fifo = {"buf": buf,
                "filled": jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)}

    from ..train.state import TrainState
    return TrainState(
        params=params_structs,
        opt_state=assign_opt(shapes.opt_state),
        fifo=fifo,
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=repl))
