"""Serving launcher: batched prefill + decode on synthetic prompts.

``python -m repro.launch.serve --arch mamba2-130m --batch 4 --new 32``

The serving mesh comes from ``launch.mesh.make_host_mesh`` at call time
(never at import), so an ``XLA_FLAGS=--xla_force_host_platform_device_count``
override is honored: with several visible devices the prompt batch is
sharded over the "data" axis and GSPMD partitions the decode loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import get_config, get_smoke_config
from ..data.synthetic import TokenGenConfig, modality_stub, token_batch
from ..models.registry import build_model
from ..serve.decode import generate_scan
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"serving {cfg.name} ({model.n_params/1e6:.1f}M params), "
          f"batch={args.batch} prompt={args.prompt_len} new={args.new}")

    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                          batch=args.batch, seed=args.seed)
    prompts = token_batch(dcfg, 0)
    extra = modality_stub(cfg, args.batch)

    mesh = make_host_mesh()
    if mesh.shape["data"] > 1 and args.batch % mesh.shape["data"] == 0:
        shard = NamedSharding(mesh, PartitionSpec("data"))
        prompts = jax.device_put(prompts, shard)
        extra = {k: jax.device_put(v, shard) for k, v in extra.items()}
        print(f"sharding batch over mesh {dict(mesh.shape)}")

    t0 = time.time()
    out = generate_scan(model, params, prompts, max_new=args.new,
                        extra_inputs=extra)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
