"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS`` before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips
    ("data","model"); two pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Degenerate mesh over the locally available devices (CPU tests)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~3 links usable per axis
N_ICI_LINKS = 3               # on a 2D torus slice; documented assumption)
