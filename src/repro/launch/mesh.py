"""Mesh construction — the single factory module for every device mesh.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run (and the CI
forced-multi-device lane) must set ``XLA_FLAGS`` before any jax
initialization.  All mesh construction in the repo routes through here so a
``--xla_force_host_platform_device_count=N`` override is honored everywhere:
``core.sweep`` takes its 1-D batch mesh from :func:`make_batch_mesh`, and
the executable runtime (``repro.psrun``) takes its 2-D worker × shard mesh
from :func:`make_ps_mesh`.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips
    ("data","model"); two pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Degenerate mesh over the locally available devices (CPU tests)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_batch_mesh(devices=None) -> Mesh:
    """1-D ``("batch",)`` mesh for embarrassingly parallel sweeps.

    ``core.sweep`` shards its flattened (config × seed) batch over this;
    defaults to every locally visible device.
    """
    if devices is None:
        devices = jax.devices()
    return Mesh(np.asarray(devices), ("batch",))


def make_pods_mesh(pods: int | None = None, data: int | None = None,
                   model: int | None = None, devices=None) -> Mesh:
    """3-D ``("pod","data","model")`` mesh for the hierarchical runtime.

    The leading "pod" axis carries parameter-shard *replicas* (one full
    copy of the table per pod); within a pod, "data" carries that pod's PS
    workers and "model" its parameter shards — `repro.pods` partitions the
    ``P`` workers pod-major over ``("pod","data")``, matching
    ``core.delays.pod_of``.  Defaults: 2 pods when the device count allows
    (else 1), then the `make_ps_mesh` policy for the within-pod axes
    (model=2 when even, >=1 worker-pair per data shard being the caller's
    job).  The CI pods lane forces 16 host devices and runs 2x4x2.
    """
    if devices is None:
        devices = jax.devices()
    n = len(devices)
    if pods is None:
        pods = 2 if n % 2 == 0 and n >= 4 else 1
    if n % pods:
        raise ValueError(f"pods={pods} does not divide the {n} visible "
                         f"devices")
    per_pod = n // pods
    if model is None:
        if data is not None:
            if per_pod % data:
                raise ValueError(
                    f"data={data} does not divide the per-pod device count "
                    f"({per_pod}); pass model= explicitly")
            model = per_pod // data
        else:
            model = 2 if (per_pod > 1 and per_pod % 2 == 0) else 1
    if data is None:
        if per_pod % model:
            raise ValueError(
                f"model={model} does not divide the per-pod device count "
                f"({per_pod}); pass data= explicitly")
        data = per_pod // model
    if pods * data * model > n:
        raise ValueError(f"mesh ({pods}x{data}x{model}) needs "
                         f"{pods * data * model} devices, have {n}")
    return Mesh(np.asarray(devices[:pods * data * model])
                .reshape(pods, data, model), ("pod", "data", "model"))


def make_ps_mesh(data: int | None = None, model: int | None = None,
                 devices=None) -> Mesh:
    """``("data","model")`` mesh for the executable parameter server.

    The "data" axis carries PS *workers* (data partitions), the "model"
    axis carries *parameter shards* (the server side of the table).  By
    default uses every visible device, preferring a true 2-D layout
    (``model=2`` when the device count is even): besides being the layout
    the runtime exists to exercise, it keeps >1 worker per data shard for
    typical worker counts, where the runtime's vmapped worker step compiles
    to the same fused arithmetic as the simulator oracle (a 1-worker shard
    can drift by 1 ulp — see ``psrun.validate``).  Pass ``data`` explicitly
    to run on a device subset (e.g. the worker-scaling curves in
    ``benchmarks/psrun_bench.py``).
    """
    if devices is None:
        devices = jax.devices()
    if model is None:
        if data is not None:
            if len(devices) % data:
                raise ValueError(
                    f"data={data} does not divide the {len(devices)} "
                    f"visible devices; pass model= explicitly")
            model = len(devices) // data
        else:
            model = 2 if (len(devices) > 1 and len(devices) % 2 == 0) else 1
    if data is None:
        if len(devices) % model:
            raise ValueError(
                f"model={model} does not divide the {len(devices)} "
                f"visible devices; pass data= explicitly")
        data = len(devices) // model
    n = data * model
    if n > len(devices):
        raise ValueError(
            f"mesh ({data}x{model}) needs {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~3 links usable per axis
N_ICI_LINKS = 3               # on a 2D torus slice; documented assumption)
