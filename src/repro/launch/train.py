"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container the default runs the *reduced* (smoke) variant of the
chosen architecture on synthetic data; ``--full`` selects the assigned
full-size config (only sensible on a real TPU slice, where the mesh and
shardings come from launch.mesh/launch.sharding — see dryrun.py for the
lowering path this reuses).

The paper's technique is a first-class flag: ``--consistency bsp|ssp|essp``
(+ ``--staleness`` / ``--buckets``) selects the gradient-sync policy.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from ..checkpoint.io import save
from ..configs import get_config, get_smoke_config
from ..data.synthetic import TokenGenConfig, modality_stub, token_batches
from ..models.registry import build_model
from ..optim.optimizers import adamw, cosine_schedule
from ..psdist.grad_sync import GradSync
from ..train.loop import train
from ..train.state import init_state, make_accum_train_step, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--consistency", default="bsp",
                    choices=["bsp", "ssp", "essp"])
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--buckets", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.n_params/1e6:.1f}M "
          f"consistency={args.consistency}(s={args.staleness})")

    opt = adamw(cosine_schedule(args.lr, args.steps // 10, args.steps))
    sync = GradSync(args.consistency, args.staleness, args.buckets)
    state = init_state(model, opt, sync, jax.random.PRNGKey(args.seed))

    if args.accum > 1:
        step = make_accum_train_step(model, opt, sync, accum=args.accum)
    else:
        step = make_train_step(model, opt, sync)

    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch=args.batch * args.accum, seed=args.seed)
    extra = modality_stub(cfg, args.batch * args.accum)

    def reshape(b):
        if args.accum > 1:
            return {k: v.reshape(args.accum, -1, *v.shape[1:])
                    for k, v in b.items()}
        return b

    batches = (reshape(b) for b in token_batches(dcfg, args.steps,
                                                 extra=extra))

    ckpt_fn = None
    if args.checkpoint_dir:
        os.makedirs(args.checkpoint_dir, exist_ok=True)

        def ckpt_fn(state, step_no):
            save(os.path.join(args.checkpoint_dir, f"step{step_no}.npz"),
                 state.params)

    state, history = train(step, state, batches, args.steps,
                           log_every=args.log_every,
                           checkpoint_fn=ckpt_fn, checkpoint_every=50)
    if args.checkpoint_dir:
        save(os.path.join(args.checkpoint_dir, "final.npz"), state.params)
        with open(os.path.join(args.checkpoint_dir, "history.json"),
                  "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
