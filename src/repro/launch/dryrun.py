import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization (see MULTI-POD DRY-RUN spec).

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, INPUT_SHAPES, LONG_CONTEXT_WINDOW, get_config  # noqa: E402
from ..configs.base import ModelConfig, ShapeConfig  # noqa: E402
from ..models.registry import build_model  # noqa: E402
from ..models.layers import push_rules, pop_rules  # noqa: E402
from ..optim.optimizers import adamw  # noqa: E402
from ..psdist.grad_sync import GradSync  # noqa: E402
from ..train.state import make_accum_train_step, make_train_step  # noqa: E402
from ..utils.hlo import analyze, count_op  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from . import sharding as shd  # noqa: E402

OUT_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

# Gradient-accumulation (microbatch) factors for training shapes: the
# paper's update coalescing, sized so activations fit 16 GB/chip v5e HBM.
TRAIN_ACCUM = {
    "llama3-8b": 4,
    "qwen3-4b": 2,
    "deepseek-v2-lite-16b": 8,
    "qwen3-moe-30b-a3b": 4,
    "llama-3.2-vision-11b": 8,
    "jamba-1.5-large-398b": 4,     # §Perf: collective/memory knee at 4
    "mamba2-130m": 4,              # SSD intra-chunk tensors scale with batch
    "whisper-medium": 4,           # 1500-frame encoder activations
    "stablelm-3b": 2,
}


def adapt_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-specific config adjustments (DESIGN.md §Decode-shape policy):
    long_500k uses the sliding-window attention variant on every arch whose
    attention is otherwise full (sub-quadratic requirement)."""
    if shape.name == "long_500k" and cfg.attn is not None \
            and cfg.family != "hybrid":
        cfg = cfg.replace(attn=dataclasses.replace(
            cfg.attn, window=LONG_CONTEXT_WINDOW))
    if shape.kind != "train":
        # inference: no dropout-free distinction here, but prefill/decode use
        # bf16 params regardless of training dtype policy.
        cfg = cfg.replace(param_dtype="bfloat16")
    return cfg


def _data_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              sync_mode: str = "bsp", staleness: int = 0, n_buckets: int = 1,
              profile: str | None = None, save: bool = True,
              tag: str = "", accum: int | None = None) -> dict:
    """Lower + compile one (arch x shape x mesh) and extract roofline terms."""
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    profile = profile or shd.profile_for(cfg)
    act_rules = {**shd.activation_rules(shape)}

    push_rules(mesh, act_rules)
    try:
        if shape.kind == "train":
            opt = adamw(1e-4, state_dtype=(
                jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                else jnp.float32))
            sync = GradSync(sync_mode, staleness, n_buckets)
            # microbatch must stay shardable over the data axes
            if accum is None:
                accum = TRAIN_ACCUM.get(arch, 1)
            accum = max(1, min(accum, shape.global_batch // _data_size(mesh)))
            accum_dt = (jnp.bfloat16 if cfg.n_layers >= 72 else jnp.float32)
            step = make_accum_train_step(model, opt, sync, accum=accum,
                                         accum_dtype=accum_dt)
            state_in = shd.state_structs(model, opt, sync, mesh, profile)
            batch_in = shd.batch_specs(cfg, shape, mesh, accum=accum)
            with mesh:
                lowered = jax.jit(step, donate_argnums=0).lower(state_in, batch_in)
        else:
            params_in = shd.param_structs(model.param_specs, mesh, profile)
            batch_in = shd.batch_specs(cfg, shape, mesh)
            shardable = shape.global_batch % _data_size(mesh) == 0
            cache_in = shd.cache_specs(cfg, shape, mesh, shardable)
            if shape.kind == "prefill":
                fn = model.prefill
            else:
                fn = model.decode_step
            with mesh:
                lowered = jax.jit(fn, donate_argnums=2).lower(params_in, batch_in, cache_in)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    finally:
        pop_rules()

    mem = compiled.memory_analysis()
    # jax 0.4.37 returns a single-element *list* of cost dicts (one per
    # executable); older/newer versions return the dict directly.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    stats = analyze(hlo)   # multiplicity-aware (scan bodies x trip count)

    chips = 512 if multi_pod else 256
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "profile": profile,
        "kind": shape.kind,
        "sync": {"model": sync_mode, "staleness": staleness,
                 "n_buckets": n_buckets} if shape.kind == "train" else None,
        "n_params": model.n_params,
        # multiplicity-corrected (scan bodies x trips), per device:
        "flops_per_device": stats.flops,
        "bytes_accessed_per_device": stats.bytes_accessed,
        # raw XLA cost analysis (counts every while body ONCE — see
        # utils/hlo.py docstring); kept for reference:
        "flops_xla_raw": float(cost.get("flops", 0.0)),
        "bytes_xla_raw": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes
                            + mem.output_size_in_bytes
                            + mem.temp_size_in_bytes
                            - mem.alias_size_in_bytes),
        },
        "collectives": stats.as_dict(),
        "hlo_ops": {"dot": count_op(hlo, "dot"),
                    "fusion": count_op(hlo, "fusion"),
                    "while": count_op(hlo, "while")},
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
    }
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn_out = f"{OUT_DIR}/{arch}_{shape_name}_{result['mesh']}{suffix}.json"
        with open(fn_out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def fmt_row(r: dict) -> str:
    gb = r["memory"]["total_bytes"] / 2**30
    return (f"{r['arch']:25s} {r['shape']:12s} {r['mesh']:8s} "
            f"flops/dev={r['flops_per_device']:.3e} "
            f"mem/dev={gb:6.2f}GiB "
            f"coll={r['collectives']['total_bytes']/2**20:8.1f}MiB "
            f"({r['t_lower_s'] + r['t_compile_s']:5.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sync", default="bsp")
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--buckets", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--profile", default=None, choices=[None, "tp", "tp_fsdp"])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--static-causal", action="store_true",
                    help="enable the static causal KV-prefix optimization "
                         "(§Perf hillclimb variant; baseline is oblivious)")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    from ..kernels import ops
    if args.static_causal:
        ops.set_flag("static_causal", True)
    if args.q_chunk:
        ops.set_flag("q_chunk", args.q_chunk)
    if args.kv_chunk:
        ops.set_flag("kv_chunk", args.kv_chunk)

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
                try:
                    r = lower_one(arch, shape, multi_pod=mp,
                                  sync_mode=args.sync,
                                  staleness=args.staleness,
                                  n_buckets=args.buckets, tag=args.tag,
                                  profile=args.profile, accum=args.accum)
                    results.append(r)
                    print("OK  ", fmt_row(r), flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((key, repr(e)))
                    print("FAIL", key, repr(e), flush=True)
                    traceback.print_exc()
    print(f"\n{len(results)} ok, {len(failures)} failed")
    for k, e in failures:
        print("  FAIL", k, e[:200])
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
