"""repro: Parameter-Server Consistency Models (AAAI 2015) in JAX.

- ``repro.core``    — the paper: BSP/SSP/ESSP/VAP + ESSPTable simulator
- ``repro.psdist``  — the paper on pods: consistency as gradient-sync policies
- ``repro.models``  — six architecture families (dense/MoE/SSM/hybrid/VLM/audio)
- ``repro.kernels`` — Pallas TPU kernels + pure-jnp oracles
- ``repro.launch``  — production meshes, sharding rules, multi-pod dry-run
"""

__version__ = "1.0.0"
