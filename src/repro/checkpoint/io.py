"""Checkpointing: pytree <-> .npz with path-encoded keys (no orbax here)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from ..utils.tree import named_leaves

_SEP = "||"


def save(path: str, tree) -> None:
    """Save a pytree of arrays to ``path`` (.npz)."""
    flat = {}
    for name, leaf in named_leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[name + _SEP + "bf16"] = arr.astype(np.float32)
        else:
            flat[name] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a pytree of arrays/specs)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    from ..utils.tree import flatten_path
    for p, leaf in flat:
        name = flatten_path(p)
        if name in data:
            arr = data[name]
        elif name + _SEP + "bf16" in data:
            arr = data[name + _SEP + "bf16"].astype(jnp.bfloat16)
        else:
            raise KeyError(f"checkpoint missing {name}")
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{name}: shape {arr.shape} != {want}")
        out.append(jnp.asarray(arr, dtype=getattr(leaf, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
