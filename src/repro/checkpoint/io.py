"""Checkpointing: pytree <-> .npz with path-encoded keys (no orbax here).

Besides generic pytrees, this round-trips mid-run PS runtime state
(`psrun.runtime.PSState` — params/base, update ring, per-channel cview
clocks, worker locals, RNG key, clock counter, and the comm-substrate
leaf: aggregation/residual buffers plus, under a lossy wire, the full
ARQ state of `comm.wire` — sequence counters, unacked in-flight
shipments, backoff deadlines, arrival/echo lanes, ``wire_tip``) for
both the flat (`repro.psrun`) and hierarchical (`repro.pods`) runtimes:
``save_runtime`` / ``restore_runtime``.  Restoring and continuing with
``run_from`` reproduces the uninterrupted run bit for bit
(`tests/test_pods.py` pins it; `tests/test_wire.py` pins a resume
*mid-retransmit*), because the state carries the *entire* scan carry —
including the PRNG key stream position.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from ..utils.tree import named_leaves

_SEP = "||"


def save(path: str, tree) -> None:
    """Save a pytree of arrays to ``path`` (.npz)."""
    flat = {}
    for name, leaf in named_leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[name + _SEP + "bf16"] = arr.astype(np.float32)
        else:
            flat[name] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def save_runtime(path: str, state) -> None:
    """Save a mid-run `PSState` (psrun or pods runtime) to ``path``.

    A `PSState` is an ordinary registered-dataclass pytree, so this is
    :func:`save`; the dedicated name marks the contract — everything the
    clock step carries is in the file, nothing implicit."""
    save(path, state)


def restore_runtime(path: str, like):
    """Restore a `PSState` saved by :func:`save_runtime`.

    ``like`` provides the structure/dtypes — use
    ``runtime.init_state(app, cfg, seed=0)`` (any seed: every leaf is
    overwritten).  Continuing with ``runtime.run_from`` reproduces the
    uninterrupted run bit for bit."""
    return restore(path, like)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a pytree of arrays/specs)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    from ..utils.tree import flatten_path
    for p, leaf in flat:
        name = flatten_path(p)
        if name in data:
            arr = data[name]
        elif name + _SEP + "bf16" in data:
            arr = data[name + _SEP + "bf16"].astype(jnp.bfloat16)
        else:
            raise KeyError(f"checkpoint missing {name}")
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{name}: shape {arr.shape} != {want}")
        out.append(jnp.asarray(arr, dtype=getattr(leaf, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
