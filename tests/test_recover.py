"""The detect->act recovery controller (`repro.ctrl.recover`).

Pinned contract:

- **Neutral silence**: a healthy stream (no churn, no SLO breach)
  yields *zero* actions — actions derive only from monitor verdicts and
  violations, never unconditionally.
- **Typed actions**: ``worker_up`` -> ``refresh_burst``; ``pod_down``
  -> ``pod_restore`` (routes via `pods.elastic`'s checkpoint path);
  a sustained violation streak -> escalating ``degrade_comm`` down the
  quantization ladder, then aggregation widening capped at ``max_agg``.
- **Auditability**: actions are schema-v1.2 ``recovery_action`` events;
  splicing them back into the stream keeps it schema-valid.
- **CLI**: ``python -m repro.obs monitor --actions`` prints decisions
  and exits 1 on any SLO violation left unrecovered, mirroring
  ``--fail-on-false-alarm``.

All streams here are synthetic (stdlib only) — the real-run integration
is covered by ``benchmarks/faults_bench.py``'s controller scenarios.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.ctrl.recover import (RecoveryPolicy, apply_actions,
                                attach_actions, plan_recovery,
                                unrecovered_violations)
from repro.obs.events import SchemaError, validate_events
from repro.obs.monitor import SLOParams

HERE = os.path.dirname(__file__)
REPO = os.path.dirname(HERE)


def mk_stream(T=24, P=4, churn=(), slow_after=None):
    """A synthetic but schema-valid v1.2 stream: ``churn`` is a list of
    ``(t, worker, "up"/"down")``; ``slow_after`` makes every clock 10x
    slower from that clock on (throughput-SLO fodder)."""
    ev = [{"type": "run_start", "v": 1, "vm": 2, "run": "synthetic",
           "model": "essp", "family": "essp", "n_workers": P, "n_pods": 2,
           "n_clocks": T, "ts": 0.0, "bound": 3}]
    live = [True] * P
    for t in range(T):
        ts = float(t + 1)
        for (ct, w, e) in churn:
            if ct == t:
                live[w] = (e == "up")
                ev.append({"type": "churn", "t": t, "worker": w, "ts": ts,
                           "event": e})
        dur = 1.0 if (slow_after is None or t < slow_after) else 10.0
        ev.append({"type": "clock", "t": t, "ts": ts, "dur": dur,
                   "loss_ref": 1.0 / (t + 1), "forced": 0,
                   "delivered": sum(live), "live": sum(live),
                   "ship_floats": 64.0})
        for p in range(P):
            if live[p]:
                ev.append({"type": "worker_span", "t": t, "worker": p,
                           "ts": ts, "dur": 0.5, "comp_s": 0.4,
                           "sync_s": 0.1})
    ev.append({"type": "run_end", "ts": float(T), "wall_s": float(T),
               "comp_s": 1.0, "comm_s": 1.0, "wire_s": 0.0, "clocks": T})
    return ev


def test_neutral_stream_triggers_zero_actions():
    actions, res = plan_recovery(mk_stream())
    assert actions == []
    assert res.violations == []
    assert unrecovered_violations(res.violations, actions) == []


def test_worker_rejoin_gets_refresh_burst():
    actions, res = plan_recovery(mk_stream(churn=[(4, 1, "down"),
                                                  (9, 1, "up")]))
    bursts = [a for a in actions if a["action"] == "refresh_burst"]
    assert len(bursts) == 1
    a = bursts[0]
    assert a["worker"] == 1 and a["t"] == 9
    assert a["clocks"] == RecoveryPolicy().refresh_clocks
    assert not any(x["action"] == "pod_restore" for x in actions)


def test_pod_outage_gets_pod_restore():
    # both workers of pod 0 down -> pod_down verdict -> pod_restore
    actions, res = plan_recovery(mk_stream(churn=[(4, 0, "down"),
                                                  (4, 1, "down")]))
    restores = [a for a in actions if a["action"] == "pod_restore"]
    assert len(restores) == 1 and restores[0]["pod"] == 0
    assert "elastic" in restores[0]["reason"]


def test_sustained_slo_escalates_down_the_ladder():
    slo = SLOParams(window=4, min_clocks_per_s=0.5)
    actions, res = plan_recovery(mk_stream(T=48, slow_after=4), slo=slo)
    degrades = [a for a in actions if a["action"] == "degrade_comm"]
    assert len(degrades) >= 3
    assert [d.get("quant") for d in degrades[:2]] == ["bf16", "int8"]
    # past the ladder, aggregation widens geometrically up to the cap
    aggs = [d["agg_clocks"] for d in degrades if "agg_clocks" in d]
    assert aggs == sorted(aggs) and aggs and aggs[-1] \
        <= RecoveryPolicy().max_agg
    assert all("sustained throughput" in d["reason"] for d in degrades)
    # a single violating window stays below the sustained threshold
    one, _ = plan_recovery(mk_stream(T=20, slow_after=16), slo=slo)
    assert [a for a in one if a["action"] == "degrade_comm"] == []


def test_apply_actions_folds_degradations():
    from repro.core.consistency import ConsistencyConfig

    cfg = ConsistencyConfig(model="essp", staleness=2, n_pods=2,
                            agg_clocks=2, wire=True)
    slo = SLOParams(window=4, min_clocks_per_s=0.5)
    actions, _ = plan_recovery(mk_stream(T=48, slow_after=4), slo=slo)
    out = apply_actions(cfg, actions)
    assert out.quant == "int8"
    assert out.agg_clocks > cfg.agg_clocks
    # non-degrade actions leave the config alone
    burst, _ = plan_recovery(mk_stream(churn=[(4, 1, "down"),
                                              (9, 1, "up")]))
    assert apply_actions(cfg, burst) is cfg


def test_attached_actions_keep_stream_schema_valid():
    slo = SLOParams(window=4, min_clocks_per_s=0.5)
    actions, res = plan_recovery(mk_stream(T=48, slow_after=4), slo=slo)
    assert actions
    spliced = attach_actions(res.events, actions)
    validate_events(spliced)
    kinds = [e["type"] for e in spliced]
    assert kinds.count("recovery_action") == len(actions)
    assert kinds[-1] == "run_end"


def test_unrecovered_definition():
    viols = [{"type": "slo_violation", "t": 5}, {"type": "slo_violation",
                                                "t": 9}]
    acts = [{"type": "recovery_action", "t": 7, "ts": 7.0,
             "action": "degrade_comm"}]
    assert unrecovered_violations(viols, []) == viols
    assert unrecovered_violations(viols, acts) == [viols[1]]


def test_plan_recovery_checks_schema_version():
    bad = mk_stream()
    bad[0] = dict(bad[0], v=99)
    with pytest.raises(SchemaError):
        plan_recovery(bad)


# ---------------------------------------------------------------------------
# CLI: python -m repro.obs monitor --actions
# ---------------------------------------------------------------------------
def _run_cli(args):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-m", "repro.obs"] + args,
                          capture_output=True, text=True, env=env,
                          cwd=REPO)


def _write(events, path):
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")


def test_cli_actions_exit_codes(tmp_path):
    neutral = str(tmp_path / "neutral.jsonl")
    _write(mk_stream(), neutral)
    r = _run_cli(["monitor", neutral, "--actions"])
    assert r.returncode == 0, r.stderr
    assert "act:" not in r.stdout

    churny = str(tmp_path / "churny.jsonl")
    emitted = str(tmp_path / "churny_actions.jsonl")
    _write(mk_stream(churn=[(4, 1, "down"), (9, 1, "up")]), churny)
    r = _run_cli(["monitor", churny, "--actions", "--emit", emitted])
    assert r.returncode == 0, r.stderr
    assert "refresh_burst" in r.stdout
    # the emitted stream carries the spliced actions and stays valid
    assert _run_cli(["validate", emitted]).returncode == 0
    with open(emitted) as f:
        types = [json.loads(line)["type"] for line in f if line.strip()]
    assert "recovery_action" in types

    # a tail-end violation with no action after it -> unrecovered -> 1
    slow = str(tmp_path / "slow.jsonl")
    _write(mk_stream(T=20, slow_after=16), slow)
    r = _run_cli(["monitor", slow, "--actions", "--window", "4",
                  "--min-clocks-per-s", "0.5"])
    assert r.returncode == 1
    assert "UNRECOVERED" in r.stderr
    # without --actions the same stream exits 0 (no gate requested)
    r = _run_cli(["monitor", slow, "--window", "4",
                  "--min-clocks-per-s", "0.5"])
    assert r.returncode == 0
