"""End-to-end behaviour tests for the whole system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import TokenGenConfig, token_batches
from repro.models.registry import build_model
from repro.optim.optimizers import adamw, cosine_schedule
from repro.psdist.grad_sync import GradSync
from repro.train.loop import train
from repro.train.state import init_state, make_accum_train_step, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    opt = adamw(cosine_schedule(3e-3, 10, 100))
    return cfg, model, opt


def _run(model, opt, sync, cfg, steps=50, accum=1, seed=0):
    state = init_state(model, opt, sync, jax.random.PRNGKey(seed))
    if accum > 1:
        step = make_accum_train_step(model, opt, sync, accum=accum)
    else:
        step = make_train_step(model, opt, sync)
    step = jax.jit(step)
    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=48, batch=8)
    losses = []
    for _i, b in enumerate(token_batches(dcfg, steps)):
        if accum > 1:
            b = {k: v.reshape(accum, -1, *v.shape[1:]) for k, v in b.items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return np.array(losses)


@pytest.mark.slow
def test_training_loss_decreases(tiny):
    cfg, model, opt = tiny
    losses = _run(model, opt, GradSync("bsp"), cfg)
    assert losses[-1] < 0.5 * losses[0]
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_accum_coalescing_close_to_flat(tiny):
    """Update coalescing (grad accumulation) ~ same trajectory as the flat
    batch (identical data, mean-of-microbatch gradients)."""
    cfg, model, opt = tiny
    l_flat = _run(model, opt, GradSync("bsp"), cfg, steps=20)
    l_acc = _run(model, opt, GradSync("bsp"), cfg, steps=20, accum=2)
    assert abs(l_flat[-1] - l_acc[-1]) < 0.2 * l_flat[-1] + 0.5


@pytest.mark.slow
def test_ssp_delayed_gradients_converge_slower_but_converge(tiny):
    cfg, model, opt = tiny
    l_bsp = _run(model, opt, GradSync("bsp"), cfg)
    l_ssp = _run(model, opt, GradSync("ssp", staleness=2), cfg)
    assert l_ssp[-1] < 0.8 * l_ssp[0]           # converges
    assert l_bsp[-1] <= l_ssp[-1] + 1e-3        # but not faster than BSP


@pytest.mark.slow
def test_essp_bucketing_matches_bsp_exactly(tiny):
    """With s=0, ESSP differs only in collective schedule, not math."""
    cfg, model, opt = tiny
    l_bsp = _run(model, opt, GradSync("bsp"), cfg, steps=10)
    l_essp = _run(model, opt, GradSync("essp", 0, n_buckets=4), cfg, steps=10)
    np.testing.assert_allclose(l_bsp, l_essp, rtol=1e-4)


def test_train_loop_history(tiny):
    cfg, model, opt = tiny
    sync = GradSync("bsp")
    state = init_state(model, opt, sync, jax.random.PRNGKey(0))
    step = make_train_step(model, opt, sync)
    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=32, batch=4)
    state, hist = train(step, state, token_batches(dcfg, 12), n_steps=12,
                        log_every=5, log_fn=lambda s: None)
    assert len(hist) >= 2
    assert int(state.step) == 12


@pytest.mark.slow
def test_checkpoint_resume(tiny, tmp_path):
    from repro.checkpoint.io import restore, save
    cfg, model, opt = tiny
    sync = GradSync("bsp")
    state = init_state(model, opt, sync, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, sync))
    dcfg = TokenGenConfig(vocab_size=cfg.vocab_size, seq_len=32, batch=4)
    batches = list(token_batches(dcfg, 6))
    for b in batches[:3]:
        state, _ = step(state, b)
    path = str(tmp_path / "state.npz")
    save(path, state.params)
    params_back = restore(path, jax.eval_shape(lambda: state.params))
    for (_n1, l1), (_n2, l2) in zip(
            jax.tree_util.tree_leaves_with_path(state.params),
            jax.tree_util.tree_leaves_with_path(params_back), strict=True):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
