"""The psrun oracle contract: the executable sharded PS vs the simulator.

Contract being pinned (see ``psrun/validate.py``):

- seeded BSP **and SSP/ESSP** runs are **bit-identical** to
  ``core.ps.simulate`` — on the quadratic app, on MF (the acceptance app)
  and on LDA.  (The SSP/ESSP bit-match was promoted from "holds in
  practice" into the asserted contract in PR 4: ``cross_validate`` now
  fails on any non-zero float diff for the three deterministic-guarantee
  models.);
- SSP/ESSP runs satisfy the bounded-staleness invariant for arbitrary
  knob draws (hypothesis; the offline stub replays a fixed sample);
- VAP runs satisfy the paper's value-bound condition, with integer
  decisions (staleness/forced/delivered) exactly equal to the oracle and
  floats within the strict ulp budget (``VAP_ULP_BUDGET`` — multi-device
  backend codegen, see ``psrun/validate.py``);
- reruns with the same seed are bit-identical (determinism), different
  seeds differ;
- numeric knob changes reuse the compiled program (one compile per
  config family, like ``core.sweep``).

The mesh helper keeps >1 worker per data shard wherever the device count
allows — the bit-identity regime (a batch-of-1 worker shard may drift by
1 ulp; ``launch.mesh.make_ps_mesh`` documents this).  Under the CI
forced-multi-device lane (``REPRO_FORCE_HOST_DEVICES=8``) these tests run
genuinely sharded over both mesh axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bsp, essp, simulate, ssp, vap
from repro.core.ps import PSApp
from repro.launch.mesh import make_ps_mesh
from repro.psrun import PSRuntime, cross_validate, make_run_fn
from repro.psrun.runtime import default_mesh as ps_mesh_for
from repro.psrun.runtime import trace_count
from repro.psrun.validate import TRACE_FIELDS, check_staleness_bound


def assert_bit_identical(got, want, context=""):
    for name in TRACE_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")


@pytest.fixture(scope="module")
def quad_runtime(quad_app):
    return PSRuntime(ps_mesh_for(quad_app.n_workers))


@pytest.fixture(scope="module")
def mf_app():
    from repro.apps.matfact import MFConfig, make_mf_app
    return make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                                n_workers=4, batch=64, lr=0.5))


def oracle(app, cfg, T, seed):
    return jax.jit(lambda sd: simulate(app, cfg, T, seed=sd))(
        jnp.uint32(seed))


# ---------------------------------------------------------------------------
# BSP bit-identity (the acceptance-criterion contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 3])
def test_bsp_bit_identical_quad(quad_app, quad_runtime, seed):
    got = quad_runtime.run(quad_app, bsp(), 25, seed=seed)
    assert_bit_identical(got, oracle(quad_app, bsp(), 25, seed),
                         context=f"bsp seed={seed}")


def test_bsp_bit_identical_mf(mf_app):
    rt = PSRuntime(ps_mesh_for(mf_app.n_workers))
    got = rt.run(mf_app, bsp(), 12, seed=1)
    assert_bit_identical(got, oracle(mf_app, bsp(), 12, 1), context="mf bsp")


@pytest.mark.slow
def test_bsp_bit_identical_lda():
    from repro.apps.lda import LDAConfig, make_lda_app
    app = make_lda_app(LDAConfig(n_docs=16, doc_len=24, vocab=48, n_topics=4,
                                 true_topics=4, n_workers=4))
    rt = PSRuntime(ps_mesh_for(app.n_workers))
    got = rt.run(app, bsp(), 8, seed=0)
    assert_bit_identical(got, oracle(app, bsp(), 8, 0), context="lda bsp")


def test_ssp_essp_bit_identical_quad(quad_app, quad_runtime):
    """Part of the asserted contract since PR 4: with the shared synthetic
    delay model the whole RNG stream is replayed, so SSP/ESSP match
    bit-for-bit (in the >1-worker-per-shard regime)."""
    for cfg in (ssp(3), essp(3), essp(5, push_prob=0.6)):
        got = quad_runtime.run(quad_app, cfg, 25, seed=2)
        assert_bit_identical(got, oracle(quad_app, cfg, 25, 2),
                             context=f"{cfg.model}({cfg.staleness})")


def test_record_views_matches(quad_app, quad_runtime):
    got = quad_runtime.run(quad_app, essp(2), 10, seed=0, record_views=True)
    want = jax.jit(lambda: simulate(quad_app, essp(2), 10, seed=0,
                                    record_views=True))()
    np.testing.assert_array_equal(np.asarray(got.views0),
                                  np.asarray(want.views0))


# ---------------------------------------------------------------------------
# SSP bounded staleness (property test; stub replays a fixed sample offline)
# ---------------------------------------------------------------------------
_PROP_FNS = {}


def _prop_fn(quad_app, model):
    if model not in _PROP_FNS:
        _PROP_FNS[model] = make_run_fn(
            quad_app, ssp(0, window=10) if model == "ssp"
            else essp(0, window=10), 15, mesh=ps_mesh_for(quad_app.n_workers))
    return _PROP_FNS[model]


@settings(max_examples=8, deadline=None)
@given(s=st.integers(min_value=0, max_value=7),
       push_prob=st.floats(min_value=0.2, max_value=1.0),
       straggler_prob=st.floats(min_value=0.0, max_value=0.5),
       model=st.sampled_from(["ssp", "essp"]),
       seed=st.integers(min_value=0, max_value=99))
def test_staleness_bound_property(quad_app, s, push_prob, straggler_prob,
                                  model, seed):
    """A read at clock c includes every update of clocks <= c-s-1 and never
    claims freshness beyond the barrier — for any knob draw.  The fixed
    ring window keeps all draws inside two compiled programs."""
    mk = ssp if model == "ssp" else essp
    cfg = mk(s, window=10).replace(push_prob=push_prob,
                                   straggler_prob=straggler_prob)
    tr = _prop_fn(quad_app, model)(seed, cfg)
    chk = check_staleness_bound(tr, cfg)
    assert chk["violations"] == 0, (model, s, chk)
    assert chk["max"] == -1                     # reads always lag the barrier


# ---------------------------------------------------------------------------
# VAP value bound + async finiteness via the cross_validate API
# ---------------------------------------------------------------------------
def test_vap_value_bound_and_decisions(quad_app, quad_runtime):
    cfg = vap(0.5, staleness=4)
    out = cross_validate(quad_app, cfg, 20, runtime=quad_runtime, seed=1)
    assert out["ok"], out
    # decisions match the oracle exactly; floats within the strict ulp
    # budget (multi-device backend codegen — see psrun/validate.py)
    got = quad_runtime.run(quad_app, cfg, 20, seed=1)
    want = oracle(quad_app, cfg, 20, 1)
    for name in ("staleness", "forced", "delivered"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))
    from repro.psrun.validate import VAP_ULP_BUDGET, trace_max_ulp
    ulps = trace_max_ulp(got, want)
    assert max(ulps.values()) <= VAP_ULP_BUDGET, ulps


def test_cross_validate_all_models(quad_app, quad_runtime):
    for cfg in (bsp(), ssp(2), essp(4)):
        out = cross_validate(quad_app, cfg, 15, runtime=quad_runtime)
        assert out["ok"], out


# ---------------------------------------------------------------------------
# determinism + compile reuse + API guards
# ---------------------------------------------------------------------------
def test_determinism_under_reseed(quad_app, quad_runtime):
    a = quad_runtime.run(quad_app, essp(3), 20, seed=7)
    b = quad_runtime.run(quad_app, essp(3), 20, seed=7)
    assert_bit_identical(a, b, context="reseed(7,7)")
    c = quad_runtime.run(quad_app, essp(3), 20, seed=8)
    assert np.abs(np.asarray(a.x_final) - np.asarray(c.x_final)).max() > 0


def test_knob_changes_reuse_compile(quad_app, quad_runtime):
    fn = quad_runtime.run_fn(quad_app, essp(3), 12)
    fn(0, essp(3))                               # warm
    n0 = trace_count()
    for cfg in (essp(1), essp(5, push_prob=0.4),
                essp(2, straggler_prob=0.3, straggler_workers=2)):
        tr = fn(0, cfg.replace(window=essp(3).effective_window))
        assert np.isfinite(np.asarray(tr.loss_ref)).all()
    assert trace_count() == n0                   # no retrace for knob moves


def test_window_mismatch_raises(quad_app, quad_runtime):
    fn = quad_runtime.run_fn(quad_app, essp(3), 5)
    with pytest.raises(ValueError, match="ring window"):
        fn(0, essp(7))                           # different ring window


def test_worker_divisibility_guard():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices to build a non-dividing mesh")
    app = PSApp(name="q3", dim=8, n_workers=3, x0=jnp.zeros((8,)),
                local0={"_": jnp.zeros((3, 1))},
                worker_update=lambda v, l, w, c, r: (v * 0.0, l),
                loss=lambda x, l: jnp.sum(x))
    with pytest.raises(ValueError, match="must divide"):
        make_run_fn(app, bsp(), 3, mesh=make_ps_mesh(data=2, model=1))
