"""Optimizers, schedules, and the SSP gradient FIFO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (adamw, apply_updates, cosine_schedule,
                                    inv_sqrt_schedule, momentum, sgd)
from repro.psdist.grad_sync import (GradSync, bucket_assignment, init_fifo,
                                    push_pop, sync_gradients)


def _quad_min(opt, steps=200):
    params = {"w": jnp.ones((8,)) * 3.0, "b": jnp.ones((1,))}
    state = opt.init(params)

    def grad_fn(p):
        return jax.grad(lambda q: jnp.sum(jnp.square(q["w"]))
                        + jnp.sum(jnp.square(q["b"])))(p)

    @jax.jit
    def step(params, state):
        upd, state = opt.update(grad_fn(params), state, params)
        return apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return params


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adamw(0.05)])
def test_optimizers_minimize_quadratic(opt):
    params = _quad_min(opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_bf16_states():
    opt = adamw(0.05, state_dtype=jnp.bfloat16)
    params = _quad_min(opt)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    st = opt.init({"w": jnp.ones((4,))})
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cos(jnp.int32(0))) < 0.2
    assert float(cos(jnp.int32(10))) == pytest.approx(1.0, abs=0.05)
    assert float(cos(jnp.int32(99))) < 0.2
    inv = inv_sqrt_schedule(1.0)
    assert float(inv(jnp.int32(0))) == 1.0
    assert float(inv(jnp.int32(3))) == 0.5


def test_fifo_warmup_and_order():
    """SSP FIFO: nothing applied for the first s steps; order preserved."""
    sync = GradSync("ssp", staleness=2)
    params = {"w": jnp.zeros((3,))}
    fifo = init_fifo(sync, params)

    g1 = {"w": jnp.ones((3,)) * 1}
    g2 = {"w": jnp.ones((3,)) * 2}
    g3 = {"w": jnp.ones((3,)) * 3}

    out1, fifo, v1 = push_pop(fifo, g1)
    out2, fifo, v2 = push_pop(fifo, g2)
    out3, fifo, v3 = push_pop(fifo, g3)
    assert float(v1) == 0.0   # warm-up
    assert float(v2) == 0.0   # warm-up
    assert float(v3) == 1.0
    np.testing.assert_allclose(np.asarray(out3["w"]), 1.0)  # stalest first


def test_sync_gradients_bsp_identity():
    sync = GradSync("bsp")
    g = {"w": jnp.arange(4.0)}
    out, fifo, scale = sync_gradients(sync, g, None, data_axes=())
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(4.0))
    assert float(scale) == 1.0


def test_bucket_assignment_balanced():
    grads = {f"p{i}": jnp.zeros((sz,)) for i, sz in
             enumerate([100, 90, 50, 40, 30, 10, 5, 5])}
    assign = bucket_assignment(grads, 4)
    loads = [0] * 4
    import numpy as np_
    for (_k, v), b in zip(grads.items(), assign, strict=True):
        loads[b] += v.size
    assert max(loads) <= 2 * min(l for l in loads if l > 0)
    assert len(set(assign)) == 4


def test_essp_bucketed_psum_equals_fused():
    """Under shard_map on a 1-device mesh, bucketed pmean == fused pmean."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.psdist.grad_sync import psum_mean_bucketed

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"a": jnp.arange(8.0), "b": jnp.ones((4,)) * 2}

    def run(n_buckets):
        f = shard_map(
            lambda t: psum_mean_bucketed(t, ("data",), n_buckets),
            mesh=mesh, in_specs=(P(),), out_specs=P())
        return f(g)

    r1, r4 = run(1), run(4)
    for k in g:
        np.testing.assert_allclose(np.asarray(r1[k]), np.asarray(r4[k]))
        np.testing.assert_allclose(np.asarray(r1[k]), np.asarray(g[k]))


def test_vap_schedule_utils(quad_app):
    from repro.core import vap as vap_mk, simulate
    from repro.core.valuebound import check_condition, sync_cost, v_schedule
    tr = jax.jit(lambda: simulate(quad_app, vap_mk(0.3, staleness=6), 50))()
    chk = check_condition(tr, 0.3)
    assert chk["violations"] == 0
    sc = sync_cost(tr)
    assert sc["forced_per_clock"] >= 0
    assert v_schedule(1.0, "constant")(100) == 1.0
    assert v_schedule(1.0, "inv_t")(0) == 1.0


def test_essp_exposure_model():
    """Eager bucketing reduces exposed collective time monotonically while
    total payload is fixed (the Fig 1-right intuition on pods)."""
    from repro.psdist.schedules import ScheduleModel, exposure_table
    rows = exposure_table(compute_s=1.0, collective_s=0.8)
    exposed = [r["exposed_s"] for r in rows]
    assert all(a >= b - 1e-9
               for a, b in zip(exposed, exposed[1:], strict=False))
    assert exposed[0] == pytest.approx(0.8)          # lazy: fully exposed
    # many buckets: only the last bucket's tail spills past compute
    assert exposed[-1] < 0.25
    # collective-dominated regime: overlap can't hide everything
    m = ScheduleModel(compute_s=0.2, collective_s=1.0, n_buckets=16)
    assert m.exposed_s() > 0.75
