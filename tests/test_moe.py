"""MoE layer semantics vs an explicit per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import moe_forward, moe_spec, _capacity
from repro.models.params import init_params


def oracle(p, cfg, x):
    """Per-token dense oracle: run every expert, mix by normalized top-k."""
    B, S, d = x.shape
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # dense: every expert on every token
    h_g = jnp.einsum("bsd,edf->bsef", x, p["wi_gate"])
    h_u = jnp.einsum("bsd,edf->bsef", x, p["wi_up"])
    h = jax.nn.silu(h_g) * h_u
    ye = jnp.einsum("bsef,efd->bsed", h, p["wo"])          # [B,S,E,d]
    sel = jnp.take_along_axis(ye, idx[..., None], axis=2)  # [B,S,K,d]
    y = jnp.sum(sel * gate[..., None], axis=2)
    if "shared_wi_gate" in p:
        sg = x @ p["shared_wi_gate"]
        su = x @ p["shared_wi_up"]
        y = y + (jax.nn.silu(sg) * su) @ p["shared_wo"]
    return y


def _setup(cfg, B=2, S=32, d=64, seed=0):
    specs = moe_spec(cfg, d)
    p = init_params(specs, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, d))
    return p, x


def test_moe_matches_oracle_with_slack_capacity():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=0,
                    capacity_factor=8.0)   # no drops
    p, x = _setup(cfg)
    y, aux = moe_forward(p, cfg, x)
    yw = oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)
    assert float(aux) >= 0


def test_moe_shared_experts():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                    capacity_factor=8.0)
    p, x = _setup(cfg)
    y, _ = moe_forward(p, cfg, x)
    yw = oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)


def test_moe_drops_at_tight_capacity():
    """With capacity_factor << 1 some assignments must drop: outputs differ
    from the dense oracle but remain finite, and dropped tokens pass
    through with (at most) the shared-expert contribution."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=0,
                    capacity_factor=0.25)
    p, x = _setup(cfg)
    y, _ = moe_forward(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    yw = oracle(p, cfg, x)
    assert float(jnp.max(jnp.abs(y - yw))) > 1e-4  # drops happened


def test_moe_capacity_formula():
    cfg = MoEConfig(n_experts=128, top_k=8, capacity_factor=1.25)
    C = _capacity(4096, cfg)
    assert C % 4 == 0
    assert 256 <= C <= 512
    assert _capacity(1, cfg) == 1


def test_router_aux_loss_balanced_vs_skewed():
    """Aux loss is ~1*weight for a balanced router and larger when skewed."""
    cfg = MoEConfig(n_experts=8, top_k=1, d_ff_expert=16, n_shared=0,
                    router_aux_weight=1.0, capacity_factor=4.0)
    p, x = _setup(cfg, B=4, S=64, d=32)
    # balanced: random router
    _, aux_bal = moe_forward(p, cfg, x)
    # skewed: bias router to expert 0
    p_skew = dict(p, router=p["router"] * 0.0 +
                  jnp.zeros_like(p["router"]).at[:, 0].set(5.0))
    _, aux_skew = moe_forward(p_skew, cfg, x)
    assert float(aux_skew) > float(aux_bal) * 1.5
    assert 0.5 < float(aux_bal) < 2.0


def test_moe_gradients_flow():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=0,
                    capacity_factor=2.0)
    p, x = _setup(cfg)

    def loss(p):
        y, aux = moe_forward(p, cfg, x)
        return jnp.mean(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    gn = {k: float(jnp.linalg.norm(v)) for k, v in g.items()}
    assert all(np.isfinite(list(gn.values())))
    assert gn["wi_gate"] > 0
    assert gn["router"] > 0
