"""Tests for repro.analysis: fixture catches, self-scan, model checker.

The analyzer is pure-AST (no jax import needed at analysis time), so these
tests are fast — the heaviest item is the exhaustive staleness model check.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

import pytest

from repro.analysis import (analyze_paths, extract_bound_model,
                            extract_bound_model_from_source,
                            extract_enforcement, model_check)
from repro.analysis.staleness_check import ExtractionError

HERE = os.path.dirname(__file__)
REPO = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC = os.path.join(REPO, "src", "repro")

FAMILIES = ("recompile", "rng", "collectives", "pytree", "pallas",
            "callbacks")


def _expected_violations(path):
    out = []
    with open(path, encoding="utf-8") as fh:
        for ln, line in enumerate(fh, 1):
            m = re.search(r"# VIOLATION: ([\w-]+)", line)
            if m:
                out.append((ln, m.group(1)))
    return sorted(out)


@pytest.mark.parametrize("family", FAMILIES)
def test_bad_fixture_caught(family):
    """Every marked violation is reported with its exact rule id + line."""
    path = os.path.join(FIXTURES, f"bad_{family}.py")
    expected = _expected_violations(path)
    assert expected, f"fixture {path} carries no VIOLATION markers"
    got = sorted((f.line, f.rule)
                 for f in analyze_paths([path], model_check=False))
    assert got == expected


@pytest.mark.parametrize("family", FAMILIES)
def test_good_fixture_clean(family):
    """The clean counterpart of each family produces zero findings."""
    path = os.path.join(FIXTURES, f"good_{family}.py")
    findings = analyze_paths([path], model_check=False)
    assert findings == [], [str(f) for f in findings]


def test_suppression_comment():
    """An inline reasoned ignore silences exactly its rule on its line."""
    path = os.path.join(FIXTURES, f"bad_rng.py")
    src = open(path, encoding="utf-8").read()
    patched = src.replace(
        "# VIOLATION: rng-reuse",
        "# analysis: ignore[rng-reuse] -- fixture", 1)
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "patched.py")
        with open(p, "w", encoding="utf-8") as fh:
            fh.write(patched)
        findings = analyze_paths([p], model_check=False)
        rules = sorted((f.rule) for f in findings)
        assert rules == ["rng-reuse", "rng-reuse"]  # 3 - 1 suppressed
        # strict mode rejects reason-less ignores
        bare = patched.replace("-- fixture", "")
        with open(p, "w", encoding="utf-8") as fh:
            fh.write(bare)
        strict = analyze_paths([p], strict=True, model_check=False)
        assert any(f.rule == "bare-ignore" for f in strict)


def test_self_scan_clean():
    """src/repro is violation-free (modulo inline reasoned ignores)."""
    findings = analyze_paths([SRC], strict=True)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_codes():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis", SRC, "--strict"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         os.path.join(FIXTURES, "bad_rng.py"), "--no-model-check"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert dirty.returncode == 1
    assert "rng-reuse" in dirty.stdout
    rules = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert rules.returncode == 0
    for rule_id in ("traced-branch", "rng-reuse", "unmasked-gather",
                    "pytree-frozen", "pallas-ref", "host-callback",
                    "staleness-contract"):
        assert rule_id in rules.stdout


# ------------------------------------------------------------------ model


PRODUCERS = [
    ("core/ps.py", os.path.join(SRC, "core", "ps.py")),
    ("psrun/runtime.py", os.path.join(SRC, "psrun", "runtime.py")),
    ("pods/runtime.py", os.path.join(SRC, "pods", "runtime.py")),
]


def test_bound_extraction_matches_declared_algebra():
    bm = extract_bound_model(os.path.join(SRC, "core", "delays.py"))
    for s in range(3):
        for sx in range(3):
            for agg in (1, 2, 3):
                assert bm.bound("intra", s, sx, agg) == s
                assert bm.bound("xpod", s, sx, agg) == s + sx
                assert bm.bound("xpod-wired", s, sx, agg) \
                    == s + sx + agg - 1


@pytest.mark.parametrize(("producer", "path"), PRODUCERS,
                         ids=[p for p, _ in PRODUCERS])
def test_model_check_verifies_producer(producer, path):
    """The exhaustive small-config grid finds no contract violation, and
    the pods runtime is recognized as delegating to the psrun body."""
    bm = extract_bound_model(os.path.join(SRC, "core", "delays.py"))
    enf = extract_enforcement(path, producer)
    assert enf.trigger_offset == 1
    assert enf.refresh_lag == 1
    assert enf.xpod_refresh_shipped
    assert enf.delivery_shipped
    if producer == "pods/runtime.py":
        assert enf.delegate == "psrun/runtime.py"
    ces = model_check(bm, enf)
    assert ces == [], "\n".join(str(c) for c in ces)


def test_model_check_detects_widening_mutant():
    """An off-by-one in the widening (`agg_clocks - 2`) is caught: the
    post-refresh shipment lag on the wired cross-pod channel exceeds the
    (mutated) bound, so the checker must produce counterexamples."""
    src = open(os.path.join(SRC, "core", "delays.py"),
               encoding="utf-8").read()
    mutant = src.replace("(cfg.agg_clocks - 1)", "(cfg.agg_clocks - 2)")
    assert mutant != src, "widening expression not found to mutate"
    bm = extract_bound_model_from_source(mutant)
    enf = extract_enforcement(os.path.join(SRC, "psrun", "runtime.py"),
                              "psrun/runtime.py")
    ces = model_check(bm, enf)
    assert ces, "mutant bound not detected"
    # the faulted cross-pod channel shares the agg widening, so the
    # mutant now falls on both wired channels — but nowhere else
    chans = {c.channel for c in ces}
    assert "xpod-wired" in chans
    assert chans <= {"xpod-wired", "xpod-faulted"}
    # and the un-mutated bound still verifies on the same extraction
    assert model_check(extract_bound_model_from_source(src), enf) == []


def test_model_check_detects_retry_budget_mutant():
    """An off-by-one in the lossy-wire widening (`retry_budget - 1`) is
    refuted: two flight windows stack (ship gating reads start-of-clock
    lane idleness), so the full ``2 * flight_budget`` is exactly tight —
    counterexamples must land on the faulted channel and only there."""
    src = open(os.path.join(SRC, "core", "delays.py"),
               encoding="utf-8").read()
    mutant = src.replace("+ retry_budget", "+ (retry_budget - 1)")
    assert mutant != src, "retry_budget widening not found to mutate"
    bm = extract_bound_model_from_source(mutant)
    enf = extract_enforcement(os.path.join(SRC, "psrun", "runtime.py"),
                              "psrun/runtime.py")
    ces = model_check(bm, enf)
    assert ces, "retry_budget mutant not detected"
    # the same mutated expression also evaluates at retry_budget=0 on
    # the plain wired channel (where it degenerates to the agg - 2
    # mutant); the new evidence is the faulted-channel refutation at
    # flight >= 1, which exercises the two-flight-window stacking
    faulted = [c for c in ces if c.channel == "xpod-faulted"]
    assert faulted, "no counterexample on the faulted channel"
    # the grid breaks per config at the first failing flight (0 here,
    # where the mutant degenerates to agg - 2); pin the nonzero-flight
    # tightness directly: at flight=1 the mutant bound (2F - 1) is one
    # short of the stacked two-window worst case, the true bound holds
    from repro.analysis.staleness_check import check_channel_faulted

    good = extract_bound_model_from_source(src)
    config = (12, 4, 0, 0, 1)      # (T, P, s, s_xpod, agg): tight corner
    assert check_channel_faulted(bm, enf, config, flight=1) is not None
    assert check_channel_faulted(good, enf, config, flight=1) is None
    # and the un-mutated bound still verifies on the same extraction
    assert model_check(good, enf) == []


def test_faulted_extraction_requires_wire_tip_caps():
    """Both producers cap faulted refresh/delivery on ``wire_tip``; a
    producer that drops either cap must fail extraction loudly (the cap
    guards against reading unarrived ring content, which the staleness
    lag invariant alone cannot observe)."""
    from repro.analysis import extract_enforcement_from_source

    for producer in ("core/ps.py", "psrun/runtime.py"):
        path = os.path.join(SRC, *producer.split("/"))
        src = open(path, encoding="utf-8").read()
        enf = extract_enforcement_from_source(src, producer)
        assert enf.xpod_refresh_capped and enf.delivery_capped
        uncapped = src.replace('cst["wire_tip"]', 'cst["pend_clock"]')
        assert uncapped != src
        with pytest.raises(ExtractionError):
            extract_enforcement_from_source(uncapped, producer)


def test_extraction_is_brittle_on_drift():
    """If a producer's enforcement pattern drifts, extraction fails loudly
    rather than silently verifying stale algebra."""
    src = open(os.path.join(SRC, "psrun", "runtime.py"),
               encoding="utf-8").read()
    drifted = src.replace("forced = cview < (c - s_eff - 1)",
                          "forced = cview <= (c - s_eff - 1)")
    assert drifted != src
    from repro.analysis import extract_enforcement_from_source
    with pytest.raises(ExtractionError):
        extract_enforcement_from_source(drifted, "psrun/runtime.py")


def test_model_check_covers_churn_outages():
    """Dead-reader windows are part of the grid: freezing cview during an
    outage and forcing on rejoin stays within bound (and a refresh that
    failed to fire on rejoin would be caught)."""
    bm = extract_bound_model(os.path.join(SRC, "core", "delays.py"))
    enf = extract_enforcement(os.path.join(SRC, "psrun", "runtime.py"),
                              "psrun/runtime.py")
    assert model_check(bm, enf, churn=True) == []
    # sanity: the adversary space is non-trivial — with a broken refresh
    # (refresh to c - 3 instead of c - 1) the bound must break
    import dataclasses
    broken = dataclasses.replace(enf, refresh_lag=3)
    assert model_check(bm, broken), \
        "checker failed to refute a lagging refresh"
