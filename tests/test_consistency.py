"""Consistency-model semantics: the paper's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bsp, essp, ssp, vap, simulate, staleness
from repro.core.consistency import ConsistencyConfig


def run(app, cfg, T=40, seed=0):
    return jax.jit(lambda: simulate(app, cfg, T, seed=seed))()


def test_config_validation():
    with pytest.raises(ValueError, match="unknown consistency model"):
        ConsistencyConfig(model="nope")
    with pytest.raises(ValueError, match="staleness"):
        ConsistencyConfig(model="ssp", staleness=-1)
    with pytest.raises(ValueError, match="v0"):
        ConsistencyConfig(model="vap", v0=0.0)
    assert bsp().effective_window == 2
    assert ssp(3).effective_window == 5


def test_bsp_staleness_always_minus_one(quad_app):
    """Paper Fig 1: 'on BSP the staleness is always -1'."""
    tr = run(quad_app, bsp())
    diffs = staleness.clock_differentials(tr)
    assert (diffs == -1).all()


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(s=st.integers(0, 6), push=st.floats(0.3, 0.95),
       strag=st.floats(0.0, 0.3), seed=st.integers(0, 3))
def test_ssp_bound_invariant(quad_app, s, push, strag, seed):
    """SSP condition: a read at clock c sees all updates of clocks
    <= c - s - 1, i.e. the clock differential never falls below -(s+1)."""
    for model in ("ssp", "essp"):
        cfg = ConsistencyConfig(model=model, staleness=s, push_prob=push,
                                straggler_prob=strag)
        tr = run(quad_app, cfg, T=30, seed=seed)
        diffs = staleness.clock_differentials(tr)
        assert diffs.min() >= -(s + 2), (model, s, diffs.min())
        # reads can never be fresher than last clock
        assert diffs.max() <= -1


def test_ssp_uniform_vs_essp_concentrated(quad_app):
    """Paper Fig 1-left: lazy SSP differentials ~uniform over the window;
    ESSP concentrates at -1."""
    s = 5
    tr_ssp = run(quad_app, ssp(s), T=80)
    tr_essp = run(quad_app, essp(s), T=80)
    _, p_ssp = staleness.histogram(tr_ssp, lo=-(s + 2))
    _, p_essp = staleness.histogram(tr_essp, lo=-(s + 2))
    # ESSP: most mass at -1 (last bin is diff=0 which never occurs)
    assert p_essp[-2] > 0.6
    # SSP: spread out — no bin dominates
    assert p_ssp.max() < 0.4
    # mean staleness strictly better under ESSP
    assert (staleness.summary(tr_essp)["mean"]
            > staleness.summary(tr_ssp)["mean"])


def test_essp_same_guarantee_as_ssp(quad_app):
    """ESSP provides no *guarantee* beyond SSP — both respect the bound;
    ESSP is empirically fresher."""
    s = 3
    for seed in range(2):
        tr = run(quad_app, essp(s, push_prob=0.5, straggler_prob=0.4),
                 seed=seed)
        assert staleness.clock_differentials(tr).min() >= -(s + 2)


def test_vap_condition_enforced(quad_app):
    """VAP: in-transit aggregated updates bounded by v_t = v0/sqrt(t+1)."""
    v0 = 0.3
    tr = run(quad_app, vap(v0, staleness=6), T=60)
    it = np.asarray(tr.intransit_inf)
    vt = v0 / np.sqrt(np.arange(1, 61))
    # measured at read time of clock c -> bound with t=c
    viol = it[1:] > vt[:-1] + 1e-6
    assert viol.mean() == 0.0, f"VAP violations: {viol.mean()}"


def test_vap_sync_cost_grows_as_bound_shrinks(quad_app):
    """The paper's impracticality argument: v_thr -> 0 degenerates VAP to
    strong consistency (forced synchronous deliveries explode)."""
    forced = []
    for v0 in (3.0, 0.3, 0.003):
        tr = run(quad_app, vap(v0, staleness=6), T=50)
        forced.append(float(np.asarray(tr.forced).sum()))
    assert forced[0] < forced[1] < forced[2]
    # tightening the bound by 100x at least doubles the forced syncs
    # (updates shrink as the run converges, so not every clock forces)
    assert forced[2] > 2.0 * forced[0] + 10


def test_async_can_exceed_ssp_bound(quad_app):
    cfg = ConsistencyConfig(model="async", staleness=2, push_prob=0.2,
                            straggler_prob=0.5)
    tr = run(quad_app, cfg, T=60)
    diffs = staleness.clock_differentials(tr)
    assert diffs.min() < -(2 + 1)   # no bound respected


def test_read_my_writes():
    import jax
    from repro.core.ps import PSApp

    P, d = 3, 4

    def worker_update(_view, local, wid, _clock, _rng):
        u = jnp.zeros((d,)).at[wid].set(1.0)
        return u, local

    app = PSApp(name="rmw", dim=d, n_workers=P, x0=jnp.zeros((d,)),
                local0={"_": jnp.zeros((P, 1))},
                worker_update=worker_update,
                loss=lambda x, l: jnp.sum(x))
    cfg = ssp(4, read_my_writes=True)
    tr = jax.jit(lambda: simulate(app, cfg, 10, record_views=True))()
    # worker 0's view of its own coordinate at clock c = c (its own writes)
    views = np.asarray(tr.views0)
    assert np.allclose(views[5, 0], 5.0)
