"""Lossy-wire fault injection: the self-healing shipment contract.

What is pinned here (see ``comm/wire.py``):

- **Neutral identity**: a `WireFaults` schedule with no drops, no dups,
  no delays and no retry budget is *bit-identical* to running without
  faults at all, on all three producers, dense and compressed — the
  fault layer is provably pay-for-what-you-use.
- **Cross-producer bit-identity**: under arbitrary seeded drop/dup/
  reorder masks plus a burst regime, the simulator oracle, `PSRuntime`
  and `PodsRuntime` produce identical traces (the acceptance contract
  extended to the faulted regime).
- **Mass conservation** (the PR 5 error-feedback residual made
  self-healing): for every producer, ``acc + res + pend + ring`` equals
  the exact sum of its updates under any fault mask — bitwise in f32 —
  while the ``heal=False`` contrast arm provably *loses* the given-up
  mass (hypothesis property; the offline stub replays fixed samples).
- **ARQ mechanics**: dedup-on-fold rejects the duplicate echo,
  exhausted backoff gives up into the residual, retransmissions are
  charged into ``ship_floats`` (and hence `TimeModel` seconds).
- **Widened staleness contract**: under a *conforming* fault schedule
  (every shipment arrives within the flight budget) the SSP/ESSP read
  bound widens by exactly ``retry_budget = 2 * flight_budget``
  (`core.delays.staleness_bound_matrix`), checked on real traces.
- **Checkpoint**: the wire state (seq/ack/in-flight lane) rides the
  `PSState` ``comm`` leaf — a save/restore *mid-retransmit* resumes bit
  for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import wire
from repro.core import ps
from repro.core.consistency import ConsistencyConfig
from repro.core.ps import PSApp
from repro.launch.mesh import make_ps_mesh
from repro.pods import PodsRuntime, default_pods_mesh
from repro.psrun import PSRuntime, make_run_fn
from repro.psrun.runtime import default_mesh as ps_mesh_for
from repro.psrun.validate import TRACE_FIELDS, check_staleness_bound


def assert_bit_identical(got, want, context=""):
    for name in TRACE_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")


def make_quad(P, d=24, eta=0.3):
    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        step = eta / jnp.sqrt(1.0 + clock)
        return -step * g / P, local

    return PSApp(name=f"quad{P}", dim=d, n_workers=P,
                 x0=jnp.ones((d,)) * 2.0, local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


def pods_runtime_for(n_workers, n_pods):
    n = len(jax.devices())
    if n >= 2 * n_pods and n % n_pods == 0:
        return PodsRuntime(default_pods_mesh(n_workers, n_pods=n_pods))
    return PSRuntime(ps_mesh_for(n_workers))


def wired_cfg(**kw):
    base = dict(model="essp", staleness=2, n_pods=2, s_xpod=1, wire=True,
                agg_clocks=2)
    base.update(kw)
    return ConsistencyConfig(**base)


def heavy_faults(T, P, **kw):
    args = dict(seed=5, drop_rate=0.35, dup_rate=0.25, delay_rate=0.3,
                max_delay=1, max_retries=2, bursts=((6, 9, 0.9),))
    args.update(kw)
    return wire.make_faults(T, P, **args)


@pytest.fixture(scope="module")
def quad8():
    return make_quad(8)


# ---------------------------------------------------------------------------
# neutral identity: zero-fault schedule == no schedule, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(("quant", "topk"), [("f32", 1.0), ("int8", 0.5)])
def test_neutral_faults_bit_identical(quad8, quant, topk):
    T, cfg = 12, wired_cfg(quant=quant, topk_frac=topk)
    nf = wire.no_faults(T, quad8.n_workers)
    assert nf.retry_budget == 0 and nf.flight_budget == 0
    base = ps.simulate_jit(quad8, cfg, T, seed=2, record_views=True)
    neut = ps.simulate_jit(quad8, cfg, T, seed=2, record_views=True,
                           faults=nf)
    assert_bit_identical(neut, base, context=f"sim-neutral-{quant}")
    rt = PSRuntime(ps_mesh_for(quad8.n_workers))
    base_rt = rt.run(quad8, cfg, T, seed=2, record_views=True)
    neut_rt = rt.run(quad8, cfg, T, seed=2, record_views=True, faults=nf)
    assert_bit_identical(neut_rt, base_rt, context=f"rt-neutral-{quant}")


# ---------------------------------------------------------------------------
# faulted cross-producer bit-identity (dense + compressed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(("quant", "topk"), [("f32", 1.0), ("int8", 0.5)])
def test_faulted_cross_producer_bit_identical(quad8, quant, topk):
    T, P = 14, quad8.n_workers
    flt = heavy_faults(T, P)
    cfg = wired_cfg(quant=quant, topk_frac=topk)
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    tr_sim = ps.simulate_jit(quad8, cfg, T, seed=2, record_views=True,
                             faults=flt)
    rt = PSRuntime(ps_mesh_for(P))
    tr_rt = rt.run(quad8, cfg, T, seed=2, record_views=True, faults=flt)
    assert_bit_identical(tr_rt, tr_sim, context=f"psrun-faulted-{quant}")
    pr = pods_runtime_for(P, 2)
    tr_pod = pr.run(quad8, cfg, T, seed=2, record_views=True, faults=flt)
    assert_bit_identical(tr_pod, tr_sim, context=f"pods-faulted-{quant}")
    # the faulted run differs from the lossless one (faults really bite)
    tr_clean = ps.simulate_jit(quad8, cfg, T, seed=2, record_views=True)
    assert not np.array_equal(np.asarray(tr_sim.ship_floats),
                              np.asarray(tr_clean.ship_floats))


# ---------------------------------------------------------------------------
# mass conservation: acc + res + pend + ring == exact update sum
# ---------------------------------------------------------------------------
def _one_hot_app(P, d, T):
    """Worker ``p`` contributes exactly ``val(p, c) * e_c`` at clock
    ``c`` — disjoint supports, so any correct accounting is float-exact
    (no reordering can change a sum with one addend per coordinate)."""

    def worker_update(view, local, wid, clock, rng):
        val = ((jnp.asarray(wid, jnp.float32) + 1.0) * T
               + jnp.asarray(clock, jnp.float32) + 1.0)
        u = jnp.zeros((d,), jnp.float32).at[clock].set(val)
        return u, local

    return PSApp(name=f"onehot{P}", dim=d, n_workers=P,
                 x0=jnp.zeros((d,)), local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(x))


def _final_comm(app, cfg, T, faults, seed=0):
    fn = make_run_fn(app, cfg, T, mesh=ps_mesh_for(app.n_workers),
                     faults=faults)
    _, state = fn.run_from(fn.init_state(seed), cfg, None, faults)
    return state.comm


def _conservation_delta(comm, P, T):
    """``expected - (acc + res + pend + ring)`` per producer, restricted
    to the first ``T`` coordinates (the only ones ever touched)."""
    total = (np.asarray(comm["acc"], np.float64)
             + np.asarray(comm["res"], np.float64)
             + np.asarray(comm["pend"], np.float64)
             + np.asarray(comm["xring"], np.float64).sum(axis=0))
    expected = np.zeros_like(total)
    for p in range(P):
        for c in range(T):
            expected[p, c] = (p + 1) * T + (c + 1)
    assert np.array_equal(total[:, T:], np.zeros_like(total[:, T:]))
    return expected[:, :T] - total[:, :T]


@given(seed=st.integers(0, 10 ** 6),
       drop=st.sampled_from([0.2, 0.5, 0.9]),
       dup=st.sampled_from([0.0, 0.4]),
       delayed=st.booleans())
@settings(max_examples=12, deadline=None)
def test_mass_conservation_under_arbitrary_masks(seed, drop, dup, delayed):
    T, P = 10, 4
    app = _one_hot_app(P, d=16, T=T)
    flt = wire.make_faults(T, P, seed=seed, drop_rate=drop, dup_rate=dup,
                           delay_rate=0.5 if delayed else 0.0,
                           max_delay=2 if delayed else 0, max_retries=2)
    cfg = wired_cfg()
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    assert T < cfg.window, "test premise: nothing may fold out of the ring"
    comm = _final_comm(app, cfg, T, flt, seed=seed % 7)
    delta = _conservation_delta(comm, P, T)
    assert np.array_equal(delta, np.zeros_like(delta)), \
        f"mass leaked under drop={drop} dup={dup} delayed={delayed}"


def test_heal_false_loses_exactly_the_given_up_mass():
    """The contrast arm: with ``heal=False`` the exhausted-backoff mass
    is discarded instead of folded into the residual — conservation
    must fail by a *positive* deficit, and only when give-ups fired."""
    T, P = 10, 4
    app = _one_hot_app(P, d=16, T=T)
    flt = wire.make_faults(T, P, seed=3, drop_rate=0.95, max_retries=1,
                           heal=False)
    cfg = wired_cfg()
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    comm = _final_comm(app, cfg, T, flt)
    assert int(np.asarray(comm["n_giveup"]).sum()) > 0, \
        "premise: a 95% drop rate with one retry must exhaust backoff"
    delta = _conservation_delta(comm, P, T)
    assert np.all(delta >= 0.0) and np.any(delta > 0.0)
    # the healing twin conserves under the identical mask
    comm_h = _final_comm(app, cfg, T,
                         wire.make_faults(T, P, seed=3, drop_rate=0.95,
                                          max_retries=1, heal=True))
    delta_h = _conservation_delta(comm_h, P, T)
    assert np.array_equal(delta_h, np.zeros_like(delta_h))


# ---------------------------------------------------------------------------
# ARQ mechanics: dedup, give-up, retransmit charging
# ---------------------------------------------------------------------------
def test_arq_counters_and_retransmit_charging(quad8):
    T, P = 12, quad8.n_workers
    flt = heavy_faults(T, P)
    cfg = wired_cfg()
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    fn = make_run_fn(quad8, cfg, T, mesh=ps_mesh_for(P), faults=flt)
    tr, state = fn.run_from(fn.init_state(2), cfg, None, flt)
    comm = state.comm
    assert int(np.asarray(comm["n_retx"]).sum()) > 0
    assert int(np.asarray(comm["n_duprej"]).sum()) > 0
    # every retransmission is charged at the shipment's packed size:
    # the faulted run ships strictly more floats than the lossless one
    clean = make_run_fn(quad8, cfg, T, mesh=ps_mesh_for(P))
    tr0 = clean(2, cfg)
    assert (float(np.asarray(tr.ship_floats).sum())
            > float(np.asarray(tr0.ship_floats).sum()))


# ---------------------------------------------------------------------------
# widened staleness bound on conforming schedules
# ---------------------------------------------------------------------------
def test_conforming_faults_respect_widened_bound(quad8):
    """Drop every even-clock transmission: each first attempt at an even
    boundary retransmits once and lands within the flight budget; no
    give-up is ever reached, so the widened SSP/ESSP bound must hold on
    the real trace (and the *unwidened* bound must not)."""
    T, P = 16, quad8.n_workers
    drop = np.zeros((T, P), np.bool_)
    drop[::2, :] = True
    flt = wire.WireFaults(drop=jnp.asarray(drop),
                          dup=jnp.zeros((T, P), jnp.bool_),
                          delay=jnp.zeros((T, P), jnp.int32),
                          rto0=1, max_retries=2, max_delay=0)
    assert flt.retry_budget == 2 * flt.flight_budget
    cfg = wired_cfg(staleness=1, s_xpod=0, agg_clocks=1)
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    tr = ps.simulate_jit(quad8, cfg, T, seed=4, record_views=True,
                         faults=flt)
    wide = check_staleness_bound(tr, cfg, retry_budget=flt.retry_budget)
    assert wide["violations"] == 0, f"widened bound violated: {wide}"
    narrow = check_staleness_bound(tr, cfg)
    assert narrow["violations"] > 0, \
        "faults never stretched staleness past the unwidened bound — " \
        "test is vacuous"


# ---------------------------------------------------------------------------
# checkpoint: bit-for-bit resume mid-retransmit
# ---------------------------------------------------------------------------
def test_checkpoint_resume_mid_retransmit(quad8, tmp_path):
    from repro.checkpoint import io as ckpt

    T, mid, P = 14, 7, quad8.n_workers
    flt = heavy_faults(T, P, drop_rate=0.6)
    cfg = wired_cfg()
    cfg = cfg.replace(window=wire.required_window(cfg, flt))
    rt = PSRuntime(ps_mesh_for(P))
    full, _ = rt.run_fn(quad8, cfg, T, faults=flt).run_from(
        rt.init_state(quad8, cfg, seed=3, faults=flt), cfg, None, flt)
    tr1, state_mid = rt.run_from(
        quad8, cfg, mid, rt.init_state(quad8, cfg, seed=3, faults=flt),
        faults=flt)
    # the pin is only meaningful if a retransmission is actually in
    # flight at the cut: some producer lane must be busy
    assert bool(np.any(np.asarray(state_mid.comm["pend_clock"]) >= 0)), \
        "no shipment in flight at the checkpoint clock"
    path = str(tmp_path / "mid.npz")
    ckpt.save_runtime(path, state_mid)
    restored = ckpt.restore_runtime(
        path, rt.init_state(quad8, cfg, seed=0, faults=flt))
    # wire leaves round-tripped bit for bit
    for k in wire.WIRE_KEYS:
        np.testing.assert_array_equal(np.asarray(state_mid.comm[k]),
                                      np.asarray(restored.comm[k]),
                                      err_msg=f"wire leaf {k}")
    tr2, _ = rt.run_from(quad8, cfg, T - mid, restored, faults=flt)
    for name in TRACE_FIELDS:
        a = np.asarray(getattr(full, name))
        if a.ndim and a.shape[0] == T:     # per-clock: both legs stitched
            b = np.concatenate([np.asarray(getattr(tr1, name)),
                                np.asarray(getattr(tr2, name))])
        else:                              # final snapshot: second leg
            b = np.asarray(getattr(tr2, name))
        np.testing.assert_array_equal(a, b, err_msg=f"resumed:{name}")


# ---------------------------------------------------------------------------
# schedule validation
# ---------------------------------------------------------------------------
def test_validate_faults_rejects_undersized_window(quad8):
    T, P = 12, quad8.n_workers
    flt = heavy_faults(T, P)
    cfg = wired_cfg()
    need = wire.required_window(cfg, flt)
    with pytest.raises(ValueError):
        ps.simulate(quad8, cfg.replace(window=need - 1), T, seed=0,
                    faults=flt)
    with pytest.raises(ValueError):
        # schedule shaped for the wrong worker count
        ps.simulate(quad8, cfg.replace(window=need), T, seed=0,
                    faults=wire.no_faults(T, P + 1))
