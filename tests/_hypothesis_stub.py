"""Minimal deterministic stand-in for `hypothesis`, used only when the real
package is unavailable (offline containers).  CI installs the real one via
``pip install -e .[test]``; this stub keeps the property tests *collectable
and meaningful* offline by replaying a fixed pseudo-random sample of each
strategy (``max_examples`` draws, seeded once per test).

Only the surface this repo uses is provided: ``given``, ``settings``, and
``strategies.{integers,floats,booleans,sampled_from}``.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


def settings(max_examples: int = DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_EXAMPLES)
            rnd = random.Random(0)
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis does the same).
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco


def install() -> None:
    """Register this stub as the importable `hypothesis` package."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    strat = types.ModuleType("hypothesis.strategies")
    for fn in (integers, floats, booleans, sampled_from):
        setattr(strat, fn.__name__, fn)
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
