"""Batched sweep engine + Pallas ps_view kernels.

The engine contract: a batched `sweep` is *bit-identical* (same seed, same
config, same ring window) to a standalone `simulate` call, for every
consistency model, while compiling once per config family.  The Pallas
ring-view / suffix-norm bodies must match the jnp references under
``interpret=True``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsp, essp, simulate, ssp, vap
from repro.core.consistency import ConsistencyConfig
from repro.core.sweep import family_window, stack_configs, sweep, trace_count
from repro.kernels import ops, ps_view, ref

FLOAT_FIELDS = ("loss_ref", "loss_view", "u_l2", "intransit_inf", "x_final")
INT_FIELDS = ("staleness", "forced", "delivered")


def assert_traces_identical(got, want, context=""):
    for name in INT_FIELDS + FLOAT_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")


def assert_traces_close(got, want, context=""):
    """Decisions exact, floats within a strict ulp budget — the contract
    for VAP under a *multi-device* sweep: XLA's backend instruction-selects
    the scan body differently when the enforcement graph is present
    (replaying the worker update on bit-identical recorded inputs
    reproduces the plain-jit value, and optimization barriers leave the
    drift byte-identical — backend codegen, not semantic drift; see
    `psrun.validate`).  App-dependent: MF/LDA are exactly stable
    (`test_sweep_vap_mf_bit_identical_sharded`), the quad app drifts
    ~ulp/clock.  Single-device sweeps stay bit-identical."""
    from repro.psrun.validate import VAP_ULP_BUDGET, trace_max_ulp
    for name in INT_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")
    ulps = trace_max_ulp(got, want)   # field-scale ulp (see its docstring)
    worst = max(ulps.values())
    assert worst <= VAP_ULP_BUDGET, (context, ulps)


FAMILY_CASES = [
    ("bsp", [bsp(), bsp(push_prob=0.5)]),
    ("ssp", [ssp(2), ssp(5)]),
    ("essp", [essp(2, push_prob=0.6), essp(5)]),
    ("async", [ConsistencyConfig(model="async", push_prob=0.4),
               ConsistencyConfig(model="async", push_prob=0.9)]),
    ("vap", [vap(0.3, staleness=5), vap(1.0, staleness=5)]),
]


@pytest.mark.parametrize(("model", "configs"),
                         FAMILY_CASES, ids=[m for m, _ in FAMILY_CASES])
def test_sweep_bit_identical_to_simulate(quad_app, model, configs):
    """Each (config, seed) trace of a batched sweep equals a standalone
    `simulate` run bit for bit (with the family's harmonized window)."""
    seeds = [0, 3]
    res = sweep(quad_app, configs, 25, seeds=seeds)
    assert res.n_compiles == 1
    check = (assert_traces_close
             if model == "vap" and len(jax.devices()) > 1
             else assert_traces_identical)
    for i, _cfg in enumerate(configs):
        assert res.harmonized[i].effective_window == family_window(configs)
        for j, sd in enumerate(seeds):
            want = jax.jit(
                lambda c=res.harmonized[i], s=sd:
                simulate(quad_app, c, 25, seed=s))()
            check(res.trace(i, j), want,
                  context=f"{model}[{i}] seed={sd}")


def test_sweep_vap_mf_bit_identical_sharded():
    """The acceptance app (MF) is *bit-identical* under a sharded VAP sweep
    — the multi-device codegen drift pinned above is quad-app-specific, and
    this holds the line on the apps the paper's claims are measured on."""
    from repro.apps.matfact import MFConfig, make_mf_app
    app = make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                               n_workers=4, batch=64, lr=0.5))
    configs = [vap(0.5, staleness=4), vap(1.0, staleness=4)]
    res = sweep(app, configs, 12, seeds=[0, 3])
    for i in range(len(configs)):
        for j, sd in enumerate([0, 3]):
            want = jax.jit(
                lambda c=res.harmonized[i], s=sd:
                simulate(app, c, 12, seed=s))()
            assert_traces_identical(res.trace(i, j), want,
                                    context=f"mf vap[{i}] seed={sd}")


def test_sweep_groups_mixed_families(quad_app):
    """Configs interleaved across families come back aligned, one compile
    per family."""
    configs = [bsp(), ssp(3), essp(3), ssp(6), bsp(push_prob=0.5)]
    n0 = trace_count()
    res = sweep(quad_app, configs, 15, seeds=2)
    assert res.n_compiles == 3                    # bsp, ssp, essp
    assert trace_count() - n0 == 3
    # ssp members share one harmonized window; bsp keeps its own
    assert res.harmonized[1].window == res.harmonized[3].window == 8
    assert res.harmonized[0].window == 2
    # alignment: each row reproduces its own config
    want = jax.jit(lambda: simulate(quad_app, res.harmonized[3], 15, seed=1))()
    assert_traces_identical(res.trace(3, 1), want, context="mixed ssp(6)")


def test_sweep_knobs_are_traced_not_recompiled(quad_app):
    """The whole point: varying every numeric knob stays inside one
    compiled program."""
    configs = [essp(s, push_prob=p, straggler_prob=q,
                    straggler_workers=w, straggler_rate=0.3)
               for s, p, q, w in [(1, 0.9, 0.0, 0), (4, 0.5, 0.2, 1),
                                  (7, 0.7, 0.1, 2), (2, 0.3, 0.3, 3)]]
    n0 = trace_count()
    res = sweep(quad_app, configs, 10, seeds=3)
    assert res.n_compiles == 1
    assert trace_count() - n0 == 1
    assert np.isfinite(np.asarray(res.traces[0].loss_ref)).all()


def test_stack_configs_rejects_cross_family():
    with pytest.raises(ValueError, match="across families"):
        stack_configs([bsp(), ssp(3)])


def test_config_window_required_when_staleness_traced():
    cfg = ssp(3).replace(staleness=jnp.asarray([1, 2]))
    with pytest.raises(ValueError, match="effective_window"):
        _ = cfg.effective_window
    assert cfg.replace(window=9).effective_window == 9


def _ring_inputs(W=7, P=8, d=256, c=13, seed=0):
    rng = np.random.default_rng(seed)
    uring = jnp.asarray(rng.normal(size=(W, P, d)).astype(np.float32))
    clocks = c - 1 - rng.permutation(W)            # distinct ring clocks
    clocks[rng.random(W) < 0.3] = -(10**9)         # some empty slots
    uclock = jnp.asarray(clocks.astype(np.int32))
    cview = jnp.asarray(rng.integers(-1, c, size=(P, P)).astype(np.int32))
    base = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    return base, uring, uclock, cview, jnp.int32(c)


@pytest.mark.parametrize("shape", [(7, 8, 256), (3, 4, 128), (12, 16, 512)])
def test_ring_view_kernel_matches_ref(shape):
    W, P, d = shape
    base, uring, uclock, cview, _ = _ring_inputs(W, P, d)
    want = ref.ring_view(base, uring, uclock, cview)
    got = ps_view.ring_view(base, uring, uclock, cview, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(7, 8, 256), (3, 4, 128), (12, 16, 512)])
def test_vap_suffix_norms_kernel_matches_ref(shape):
    W, P, d = shape
    _, uring, uclock, _, c = _ring_inputs(W, P, d)
    want = ref.vap_suffix_norms(uring, uclock, c)
    got = ps_view.vap_suffix_norms(uring, uclock, c, interpret=True)
    assert got.shape == (W + 1, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_suffix_norms_semantics():
    """norms[k,q] really is the inf-norm of the k-newest-clock aggregate."""
    W, P, d = 4, 2, 128
    c = 10
    uring = jnp.zeros((W, P, d)).at[:, :, 0].set(
        jnp.asarray([[1.0, -1.0], [2.0, 0.5], [-4.0, 0.25], [8.0, 0.125]]))
    uclock = jnp.asarray([c - 1, c - 2, c - 3, c - 4], jnp.int32)
    norms = np.asarray(ref.vap_suffix_norms(uring, uclock, jnp.int32(c)))
    np.testing.assert_allclose(norms[:, 0], [0, 1, 3, 1, 7])
    np.testing.assert_allclose(norms[:, 1], [0, 1, 0.5, 0.25, 0.125])


def test_ops_dispatch_ps_view():
    """`ops.set_backend("pallas_interpret")` routes the simulator's hot path
    through the Pallas bodies; traces must match the ref backend."""
    base, uring, uclock, cview, c = _ring_inputs()
    try:
        ops.set_backend("pallas_interpret")
        got_v = ops.ring_view(base, uring, uclock, cview)
        got_n = ops.vap_suffix_norms(uring, uclock, c)
        ops.set_backend("ref")
        want_v = ops.ring_view(base, uring, uclock, cview)
        want_n = ops.vap_suffix_norms(uring, uclock, c)
    finally:
        ops.set_backend("auto")
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_n), np.asarray(want_n),
                               rtol=1e-5, atol=1e-6)


def test_simulate_through_pallas_interpret_backend():
    """Full simulate with the Pallas bodies (interpret) vs the jnp ref, on a
    kernel-aligned app (d % 128 == 0)."""
    P, d = 8, 128

    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        return -(0.3 / jnp.sqrt(1.0 + clock)) * g / P, local

    from repro.core.ps import PSApp
    app = PSApp(name="quad128", dim=d, n_workers=P, x0=jnp.ones((d,)) * 2.0,
                local0={"_": jnp.zeros((P, 1))},
                worker_update=worker_update,
                loss=lambda x, l: jnp.sum(jnp.square(x)))
    cfg = vap(0.5, staleness=4)
    try:
        ops.set_backend("ref")
        want = jax.jit(lambda: simulate(app, cfg, 6))()
        ops.set_backend("pallas_interpret")
        got = jax.jit(lambda: simulate(app, cfg, 6))()
    finally:
        ops.set_backend("auto")
    for name in FLOAT_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            rtol=1e-5, atol=1e-5, err_msg=name)
