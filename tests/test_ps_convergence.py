"""Convergence behaviour of the PS apps under the consistency models —
the paper's C2/C3/C4/C5 claims at test scale."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import bsp, essp, ssp, vap, simulate
from repro.core import theory
from repro.apps.matfact import MFConfig, make_mf_app
from repro.apps.lda import LDAConfig, make_lda_app


MF = MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8, n_workers=4,
              batch=64, lr=0.5)


@pytest.fixture(scope="module")
def mf_app():
    return make_mf_app(MF)


def losses(app, cfg, T=120, seed=0):
    tr = jax.jit(lambda: simulate(app, cfg, T, seed=seed))()
    return np.asarray(tr.loss_ref)


def test_mf_bsp_converges(mf_app):
    l = losses(mf_app, bsp())
    assert l[-1] < 0.25 * l[0]
    assert np.isfinite(l).all()


def test_mf_essp_converges_close_to_bsp(mf_app):
    lb = losses(mf_app, bsp())
    le = losses(mf_app, essp(3))
    assert le[-1] < 0.3 * le[0]
    assert le[-1] < 2.5 * lb[-1] + 1e-3


@pytest.mark.slow
def test_mf_essp_beats_ssp_per_clock(mf_app):
    """C2: eager propagation converges faster (or equal) per iteration."""
    ls = losses(mf_app, ssp(7))
    le = losses(mf_app, essp(7))
    # compare average loss over the last third of training
    tail = slice(80, None)
    assert le[tail].mean() <= ls[tail].mean() * 1.1


def test_mf_vap_converges(mf_app):
    lv = losses(mf_app, vap(0.5, staleness=6))
    assert lv[-1] < 0.3 * lv[0]


@pytest.mark.slow
def test_regret_decays(mf_app):
    """C4/C5: R[X]/T decays like O(T^-1/2) (fit exponent clearly < 0)."""
    tr = jax.jit(lambda: simulate(mf_app, essp(3), 150))()
    lv = np.asarray(tr.loss_view)
    curve = theory.regret_curve(lv, loss_star=float(lv.min()))
    expo = theory.sqrt_decay_fit(curve, skip=15)
    assert expo < -0.25, expo


def test_variance_decreasing_and_essp_leq_ssp(quad_app):
    """C4 (Thm 6): iterate variance decreases near the optimum, and the
    fresher staleness profile (ESSP) has lower variance than lazy SSP.

    Measured on the convex quadratic app — Theorem 6 assumes a unique
    optimum; on MF the claim is refuted by rotational symmetry (different
    seeds converge to different factorizations; see EXPERIMENTS.md C4)."""
    v_ssp = theory.variance_trace(quad_app, ssp(5), n_clocks=60, n_seeds=6)
    v_essp = theory.variance_trace(quad_app, essp(5), n_clocks=60, n_seeds=6)
    # decreasing towards the end vs the early phase
    assert v_essp[40:].mean() < v_essp[5:15].mean()
    # ESSP variance no worse than SSP late in training
    assert v_essp[40:].mean() <= v_ssp[40:].mean() * 1.2


@pytest.mark.slow
def test_lda_improves_under_all_models():
    app = make_lda_app(LDAConfig(n_docs=32, doc_len=64, vocab=100,
                                 n_topics=8, true_topics=8, n_workers=4))
    for cfg in (bsp(), ssp(5), essp(5)):
        tr = jax.jit(lambda c=cfg: simulate(app, c, 40))()
        l = np.asarray(tr.loss_ref)
        assert l[-1] < l[0] - 0.05, (cfg.model, l[0], l[-1])
        assert np.isfinite(l).all()


def test_theorem5_bound_shape():
    b1 = theory.theorem5_bound(T=1000, s=3, P=8, eta=0.1, L=1.0, F=1.0,
                               mu_gamma=2.0, sigma_gamma=1.0, tau=0.05)
    b2 = theory.theorem5_bound(T=1000, s=3, P=8, eta=0.1, L=1.0, F=1.0,
                               mu_gamma=6.0, sigma_gamma=4.0, tau=0.05)
    # larger staleness moments -> larger deviation threshold & fatter tail
    assert b2["threshold"] > b1["threshold"]
    assert b2["tail_prob"] >= b1["tail_prob"]
    b3 = theory.theorem5_bound(T=1000, s=3, P=8, eta=0.1, L=1.0, F=1.0,
                               mu_gamma=2.0, sigma_gamma=1.0, tau=0.2)
    assert b3["tail_prob"] < b1["tail_prob"]
