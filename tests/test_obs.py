"""The telemetry substrate (`repro.obs`): the contracts this PR pins.

- **Bit-identity**: every Trace producer (simulator, `PSRuntime`,
  `PodsRuntime`) emits a bit-identical `Trace` (including the RNG-driven
  fields — same stream) with obs on vs off, across dense, compressed
  hierarchical, and churned runs.  Disabled obs compiles the exact
  pre-obs program; enabled obs must not perturb it either.
- **Accumulator correctness**: the on-device accumulators equal an
  independent host-side recomputation from the Trace arrays, and agree
  across producers.
- **Stream/The exporters**: JSONL schema round-trip, validator
  rejections, a byte-pinned Perfetto golden
  (``REPRO_REGEN_GOLDEN=1`` regenerates), report rendering.
- **Overhead budget**: obs-on sweep within 5% of obs-off — asserted on
  the forced-device CI lanes (``REPRO_FORCE_HOST_DEVICES``), where the
  topology is deliberate.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import essp, simulate, ssp
from repro.core.consistency import compressed, podded
from repro.core.delays import make_churn, same_pod_mask
from repro.core.sweep import sweep
from repro.core.timemodel import TimeModel
from repro.obs import (DEFAULT_LAG_BUCKETS, MetricsRegistry, ObsSpec,
                       drain_device, record_compiles, record_timing)
from repro.obs import events as obs_events
from repro.obs import perfetto as obs_perfetto
from repro.obs import report as obs_report
from repro.obs.events import SchemaError
from repro.pods import PodsRuntime, default_pods_mesh
from repro.psrun import PSRuntime
from repro.psrun.runtime import default_mesh as flat_mesh_for
from repro.psrun.validate import TRACE_FIELDS

from conftest import PSApp

HERE = os.path.dirname(__file__)
GOLDEN = os.path.join(HERE, "golden", "perfetto_small.json")

T = 12


def make_quad(P, d=16, noisy=True):
    def worker_update(view, local, _wid, clock, rng):
        g = view + (0.05 * jax.random.normal(rng, view.shape)
                    if noisy else 0.0)
        return -(0.3 / jnp.sqrt(1.0 + clock)) * g / P, local

    return PSApp(name=f"quad{P}{'n' if noisy else 'd'}", dim=d,
                 n_workers=P, x0=jnp.ones((d,)) * 2.0,
                 local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


# (name, cfg for P workers, schedule for P workers): flat dense, dense
# hierarchical push, the compressed wire (the wired scan-carry branch),
# and churn.  The pods runtime requires a hierarchical config, so the
# flat scenario runs on the other two producers only.
SCENARIOS = {
    "flat": (lambda P: essp(2), lambda P: None),
    "dense": (lambda P: podded(essp(2), 2, s_xpod=2), lambda P: None),
    "compressed": (lambda P: compressed(
        podded(essp(2), 2, s_xpod=2), agg_clocks=2, topk_frac=0.5,
        quant="int8"), lambda P: None),
    "churn": (lambda P: podded(ssp(1), 2, s_xpod=2),
              lambda P: make_churn(T, P, worker_outages=((1, 3, 8),
                                                         (P - 1, 5, 10)))),
}


def pods_runtime_for(P, n_pods=2):
    n = len(jax.devices())
    if n >= 2 * n_pods and n % n_pods == 0:
        return PodsRuntime(default_pods_mesh(P, n_pods=n_pods))
    return PSRuntime(flat_mesh_for(P))


def _run(producer, app, cfg, sched, obs):
    if producer == "sim":
        return simulate(app, cfg, T, seed=0, schedule=sched, obs=obs)
    if producer == "pods" and cfg.n_pods == 1:
        pytest.skip("the pods runtime requires a hierarchical config")
    rt = (PSRuntime(flat_mesh_for(app.n_workers)) if producer == "psrun"
          else pods_runtime_for(app.n_workers))
    return rt.run(app, cfg, T, seed=0, schedule=sched, obs=obs)


def assert_traces_equal(a, b, context=""):
    for name in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{context}:{name}")


@pytest.mark.parametrize("producer", ["sim", "psrun", "pods"])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_bit_identity_obs_on_off(producer, scenario):
    """Obs on vs off: bit-identical Trace (and RNG stream — the noisy
    gradient draws land in loss/x_final) for every producer x scenario."""
    P = 8
    mk_cfg, mk_sched = SCENARIOS[scenario]
    app = make_quad(P)
    cfg, sched = mk_cfg(P), mk_sched(P)
    tr_off = _run(producer, app, cfg, sched, None)
    tr_on = _run(producer, app, cfg, sched, ObsSpec())
    assert tr_off.obs is None
    assert tr_on.obs is not None
    assert_traces_equal(tr_on, tr_off, f"{producer}/{scenario}")


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_accumulators_agree_across_producers(scenario):
    """All three producers return identical accumulator pytrees."""
    P = 8
    mk_cfg, mk_sched = SCENARIOS[scenario]
    app = make_quad(P)
    cfg, sched = mk_cfg(P), mk_sched(P)
    producers = ("sim", "psrun") if cfg.n_pods == 1 \
        else ("sim", "psrun", "pods")
    accs = {prod: _run(prod, app, cfg, sched, ObsSpec()).obs
            for prod in producers}
    ref = accs["sim"]
    for prod in producers[1:]:
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(ref[k]), np.asarray(accs[prod][k]),
                err_msg=f"{prod}:{k}")


def test_accumulators_match_trace_recomputation():
    """The on-device accumulators equal an independent numpy recomputation
    from the Trace arrays (churned hierarchical run: exercises the
    live-reader masking and the intra/xpod forced split)."""
    P = 8
    cfg = podded(ssp(1), 2, s_xpod=2)
    sched = make_churn(T, P, worker_outages=((1, 3, 8), (6, 5, 10)))
    app = make_quad(P)
    tr = simulate(app, cfg, T, seed=0, schedule=sched, obs=ObsSpec())
    acc = {k: np.asarray(v) for k, v in tr.obs.items()}

    stal = np.asarray(tr.staleness)
    forced = np.asarray(tr.forced)
    delivered = np.asarray(tr.delivered)
    live = np.asarray(tr.live)
    ship = np.asarray(tr.ship_floats)
    lag = -1 - stal
    w = live[:, :, None]                       # live reader rows
    in_pod = np.broadcast_to(
        np.asarray(same_pod_mask(P, cfg.n_pods))[None], forced.shape)
    NB = DEFAULT_LAG_BUCKETS
    hist = np.bincount(np.clip(lag, 0, NB - 1)[np.broadcast_to(
        w, lag.shape)], minlength=NB)
    f = forced & np.broadcast_to(w, forced.shape)

    assert acc["clocks"] == T
    np.testing.assert_array_equal(acc["lag_hist"], hist)
    assert acc["lag_max"] == np.where(np.broadcast_to(w, lag.shape),
                                      lag, 0).max()
    assert acc["forced_intra"] == (f & in_pod).sum()
    assert acc["forced_xpod"] == (f & ~in_pod).sum()
    assert acc["delivered"] == (delivered
                                & np.broadcast_to(w, forced.shape)).sum()
    np.testing.assert_allclose(acc["ship_floats"], ship.sum(axis=0),
                               rtol=1e-6)
    assert acc["dead_worker_clocks"] == (~live).sum()


def test_sweep_threads_obs_bit_identically():
    """`core.sweep` with obs on returns the same traces as off, and each
    point's Trace carries its accumulators."""
    app = make_quad(4)
    cfgs = [essp(2), ssp(3)]
    off = sweep(app, cfgs, T, seeds=[0, 1])
    on = sweep(app, cfgs, T, seeds=[0, 1], obs=ObsSpec())
    for i in range(len(cfgs)):
        assert_traces_equal(on.trace(i), off.trace(i), f"sweep:{i}")
        assert on.trace(i).obs is not None and off.trace(i).obs is None


# ------------------------------------------------------------- registry


def test_registry_counters_gauges_hists():
    reg = MetricsRegistry()
    reg.counter_add("a/n", 2)
    reg.counter_add("a/n", np.int64(3))
    reg.gauge_set("a/g", jnp.float32(1.5))
    reg.hist_add("a/h", [1, 0, 2])
    reg.hist_add("a/h", [0, 1, 0])
    d = reg.to_dict()
    assert d["counters"]["a/n"] == 5
    assert d["gauges"]["a/g"] == 1.5
    assert d["hists"]["a/h"]["counts"] == [1, 1, 2]
    assert d["hists"]["a/h"]["buckets"] == ["0", "1", "2+"]
    flat = reg.flat()
    assert flat["a/h/total"] == 4.0
    assert flat["a/h/mean"] == pytest.approx((0 * 1 + 1 * 1 + 2 * 2) / 4)
    with pytest.raises(ValueError):
        reg.hist_add("a/h", [1, 2])            # bucket count changed


def test_drain_device_and_compile_gauges():
    app = make_quad(4)
    tr = simulate(app, essp(2), T, seed=0, obs=ObsSpec())
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        drain_device(reg, None)
    drain_device(reg, tr.obs)
    record_compiles(reg)
    record_timing(reg, tr, "essp", TimeModel(), fold=(0, 0))
    flat = reg.flat()
    assert flat["ps/clocks"] == T
    assert flat["ps/staleness_lag/total"] == T * 4 * 4
    assert isinstance(flat["compiles/sweep_traces"], int)
    assert isinstance(flat["compiles/runtime_traces"], int)
    assert flat["ps/modeled_wall_s"] > 0
    assert "ps/worker00/modeled_comp_s" in flat


# ------------------------------------------------------- events / stream


def _small_stream(registry=None):
    """A tiny deterministic churned hierarchical run -> event stream."""
    app = make_quad(4, noisy=False)
    cfg = podded(essp(1), 2, s_xpod=1)
    sched = make_churn(6, 4, worker_outages=((2, 2, 5),))
    tr = simulate(app, cfg, 6, seed=0, schedule=sched, obs=ObsSpec())
    tm = TimeModel(straggler_sigma=0.0)        # degenerate draws: exact
    ev = obs_events.collect_events(tr, cfg, tm, schedule=sched,
                                   run="golden", registry=registry)
    return ev


def test_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter_add("ps/forced_intra", 3)
    reg.hist_add("ps/staleness_lag", [4, 2, 0, 1])
    ev = _small_stream(registry=reg)
    obs_events.validate_events(ev)
    path = tmp_path / "events.jsonl"
    obs_events.write_jsonl(ev, path)
    assert obs_events.read_jsonl(path) == ev
    types = {e["type"] for e in ev}
    assert {"run_start", "clock", "worker_span", "churn", "shipment",
            "metrics", "run_end"} <= types


def test_validator_rejections():
    ev = _small_stream()
    with pytest.raises(SchemaError):
        obs_events.validate_events([])
    with pytest.raises(SchemaError):
        obs_events.validate_events(ev[1:])              # no run_start
    with pytest.raises(SchemaError):
        obs_events.validate_events(ev[:-1])             # no run_end
    with pytest.raises(SchemaError):
        obs_events.validate_events(
            [dict(ev[0], v=99)] + ev[1:])               # version mismatch
    with pytest.raises(SchemaError):
        obs_events.validate_events(
            ev[:-1] + [{"type": "mystery"}, ev[-1]])    # unknown type
    clock = next(i for i, e in enumerate(ev) if e["type"] == "clock")
    broken = dict(ev[clock])
    del broken["loss_ref"]
    with pytest.raises(SchemaError):
        obs_events.validate_events(
            ev[:clock] + [broken] + ev[clock + 1:])     # missing field
    last = next(i for i in range(len(ev) - 1, -1, -1)
                if ev[i].get("t", None) not in (None, 0))
    with pytest.raises(SchemaError):
        obs_events.validate_events(
            ev[:last] + [dict(ev[last], t=0)] + ev[last + 1:])  # t order


def test_version_check():
    """Major mismatch is rejected outright; the pair comes back for
    consumers to key on."""
    ev = _small_stream()
    assert obs_events.check_version(ev) == (obs_events.SCHEMA_VERSION,
                                            obs_events.SCHEMA_MINOR)
    with pytest.raises(SchemaError, match="major"):
        obs_events.check_version([dict(ev[0],
                                       v=obs_events.SCHEMA_VERSION + 1)]
                                 + ev[1:])
    with pytest.raises(SchemaError, match="empty"):
        obs_events.check_version([])


def test_forward_compat_unknown_fields():
    """Unknown keys on known events are a newer producer's optional
    fields: accepted under the same major.  Known optional fields are
    still type-checked when present."""
    ev = _small_stream()
    ci = next(i for i, e in enumerate(ev) if e["type"] == "clock")
    obs_events.validate_events(
        ev[:ci] + [dict(ev[ci], from_the_future=1.5)] + ev[ci + 1:])
    obs_events.validate_events(
        [dict(ev[0], adaptive_budget=3)] + ev[1:])
    with pytest.raises(SchemaError, match="lag_p99"):
        obs_events.validate_events(
            ev[:ci] + [dict(ev[ci], lag_p99="high")] + ev[ci + 1:])


def test_forward_compat_newer_minor_event_types():
    """Unknown event *types* pass only when the stream's minor version
    is newer than ours — same-or-older minors using one are corrupt."""
    ev = _small_stream()
    alien = {"type": "adaptive_hint", "t": 0, "ts": 0.0}
    newer = [dict(ev[0], vm=obs_events.SCHEMA_MINOR + 1), alien,
             *ev[1:]]
    obs_events.validate_events(newer)
    with pytest.raises(SchemaError, match="unknown type"):
        obs_events.validate_events([ev[0], alien, *ev[1:]])


def test_declared_bound_on_header():
    """The minor-1 header carries the staleness contract the SLO monitor
    checks against; unbounded families carry none."""
    ev = _small_stream()
    cfg = podded(essp(1), 2, s_xpod=1)
    assert ev[0]["vm"] == obs_events.SCHEMA_MINOR
    assert ev[0]["bound"] == obs_events.declared_bound(cfg)
    from repro.core.consistency import ConsistencyConfig
    assert obs_events.declared_bound(
        ConsistencyConfig(model="async")) is None
    clocks = [e for e in ev if e["type"] == "clock"]
    assert all("lag_p99" in c and "lag_max" in c for c in clocks
               if c["live"] > 0)


def test_perfetto_golden(tmp_path):
    """Byte-pinned Perfetto export of the small deterministic stream.
    Regenerate after an intentional schema/export change with
    ``REPRO_REGEN_GOLDEN=1 pytest tests/test_obs.py -k golden``."""
    ev = _small_stream()
    path = tmp_path / "trace.perfetto.json"
    obs_perfetto.write_trace(ev, path)
    got = path.read_text()
    if os.environ.get("REPRO_REGEN_GOLDEN"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            f.write(got)
    with open(GOLDEN) as f:
        want = f.read()
    assert got == want, "Perfetto export drifted from the golden " \
                        "(REPRO_REGEN_GOLDEN=1 to re-pin intentionally)"
    # structural spot checks so the golden itself stays honest
    trace = json.loads(got)
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"clocks", "worker 0", "worker 3", "producer 0"} <= lanes
    cats = {e.get("cat") for e in trace["traceEvents"]}
    assert {"clock", "worker", "wire", "churn"} <= cats
    outages = [e for e in trace["traceEvents"] if e.get("cat") == "churn"]
    assert len(outages) == 1 and outages[0]["tid"] == 2 + 1


def test_report_renders():
    app = make_quad(4)
    cfg = podded(essp(1), 2, s_xpod=1)
    tr = simulate(app, cfg, T, seed=0, obs=ObsSpec())
    tm = TimeModel()
    s = obs_report.trace_summary(tr, cfg, tm, label="essp", fold=(0, 0))
    reg = MetricsRegistry()
    drain_device(reg, tr.obs)
    md = obs_report.render_report("unit report", [s], registry=reg,
                                  notes=("one run",))
    for token in ("# unit report", "## Staleness", "## Throughput",
                  "## Wire", "## Metrics", "| essp |", "> one run"):
        assert token in md, token
    grid = {"essp": {"baseline": {"clocks_to_thresh": 9, "lost_clocks": 0},
                     "churn": {"clocks_to_thresh": None,
                               "lost_clocks": None, "diverged": True}}}
    table = obs_report.churn_grid_table(grid)
    assert "| essp | 9 | ∞ DIV |" in table


# ------------------------------------------------------------- overhead


def test_overhead_budget():
    """Obs-on within 5% of obs-off (+ absolute timer-jitter slack).
    Asserted only where the topology is deliberate (the CI forced-device
    lanes) — on shared dev hosts the timing is reported, not gated.
    Delegates to the bench's interleaved min-of-N measurement: min of
    alternating executions isolates the accumulators' deterministic
    device work from host scheduling noise, which a tiny test app timed
    back-to-back cannot (the budget is a *ratio*, so the smaller the
    denominator the louder the jitter)."""
    if not os.environ.get("REPRO_FORCE_HOST_DEVICES"):
        pytest.skip("overhead budget gated on the forced-device CI lanes")
    from benchmarks.obs_bench import measure_overhead
    rec = measure_overhead(reps=7)
    if not rec["ok"]:                           # one retry absorbs a GC
        rec = measure_overhead(reps=7, seed=1)  # pause / noisy neighbor
    assert rec["ok"], \
        f"obs overhead {rec['overhead_ratio'] - 1:+.1%} exceeds the 5% " \
        f"budget (off={rec['t_obs_off_s']:.4f}s on={rec['t_obs_on_s']:.4f}s)"
