"""The streaming health layer (`repro.obs.monitor` / `repro.obs.diff`):
the contracts this PR pins.

- **Detector soundness**: neutral (no-churn) streams raise ZERO alarms
  at *any* timeout setting (hypothesis-swept) — in the lockstep model a
  live worker spans every clock, so healthy ``missed`` is identically 0.
- **Detector completeness**: seeded outages are detected within the
  claimed clock budget (``timeout_clocks``, inside ``s + agg_clocks``),
  workers/pods recover with ``worker_up``/``pod_up``, and the oracle
  scorer (`core.delays.score_detections`) grades it all with zero false
  alarms.
- **SLO agreement**: staleness verdicts match a Trace-derived ground
  truth recomputation window for window; throughput/wire monitors fire
  at exactly the configured thresholds; ``slo_violation`` events splice
  back into a stream that still validates and round-trips through JSONL.
- **Attribution**: `diff` profiles rank the component that actually
  changed; the wall-second split is exact; BENCH diffs pin flipped
  claims to their component.
- **Exporter/CLI**: byte-pinned OpenMetrics golden
  (``REPRO_REGEN_GOLDEN=1`` re-pins), and every ``python -m repro.obs``
  subcommand exercised in-process, including the false-alarm exit gate
  the CI obs lane relies on.
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import essp, simulate
from repro.core.consistency import podded
from repro.core.delays import (make_churn, outage_windows,
                               score_detections)
from repro.core.timemodel import TimeModel
from repro.obs import MetricsRegistry, ObsSpec, drain_device, promtext
from repro.obs import events as obs_events
from repro.obs import report as obs_report
from repro.obs.__main__ import main as obs_cli
from repro.obs.diff import diff_bench, diff_streams, explain, run_profile
from repro.obs.monitor import (DetectorParams, SLOParams,
                               live_from_events, monitor_stream,
                               stream_summary)

from test_obs import make_quad

HERE = os.path.dirname(__file__)
PROM_GOLDEN = os.path.join(HERE, "golden", "promtext_small.txt")

T, P = 12, 4
# pod 1 = workers {2, 3}; both dead on [5, 9) -> pod_down, then pod_up
OUTAGES = ((2, 3, 9), (3, 5, 9))
BUDGET = 2          # s + agg_clocks for podded essp(1), dense (agg = 1)


def _stream(schedule=None, with_registry=False, run="mon"):
    app = make_quad(P, noisy=False)
    cfg = podded(essp(1), 2, s_xpod=1)
    tr = simulate(app, cfg, T, seed=0, schedule=schedule, obs=ObsSpec())
    tm = TimeModel(straggler_sigma=0.0)
    registry = None
    if with_registry:
        registry = MetricsRegistry()
        drain_device(registry, tr.obs)
    return obs_events.collect_events(tr, cfg, tm, schedule=schedule,
                                     run=run, registry=registry), tr, cfg


@pytest.fixture(scope="module")
def neutral():
    return _stream(with_registry=True, run="neutral")


@pytest.fixture(scope="module")
def churned():
    sched = make_churn(T, P, worker_outages=OUTAGES)
    ev, tr, cfg = _stream(schedule=sched, run="churned")
    return ev, tr, cfg, sched


# ------------------------------------------------------------ detector


@settings(max_examples=20, deadline=None)
@given(timeout=st.integers(min_value=1, max_value=8),
       window=st.integers(min_value=1, max_value=16))
def test_neutral_stream_zero_alarms_any_timeout(neutral, timeout,
                                                window):
    """The soundness property: a healthy fleet spans every clock, so no
    timeout setting — however aggressive — may raise an alarm."""
    ev, _, _ = neutral
    res = monitor_stream(ev, DetectorParams(timeout_clocks=timeout),
                         SLOParams(window=window))
    assert res.health["n_worker_down"] == 0
    assert res.health["n_pod_down"] == 0
    assert res.health["suspected_at_end"] == []


def test_seeded_outages_detected_in_budget(churned):
    ev, _, _, sched = churned
    res = monitor_stream(ev, DetectorParams(timeout_clocks=2))
    downs = [v for v in res.verdicts if v["kind"] == "worker_down"]
    ups = [v for v in res.verdicts if v["kind"] == "worker_up"]
    assert {v["worker"] for v in downs} == {2, 3}
    assert {v["worker"] for v in ups} == {2, 3}
    # latency: outage at t0 is alarmed at the clock where missed hits 2
    for w, t0, _t1 in OUTAGES:
        alarm = next(v for v in downs if v["worker"] == w)
        assert alarm["t"] - t0 == 2 <= BUDGET

    score = score_detections(np.asarray(sched.live), res.verdicts,
                             BUDGET)
    assert score["n_outages"] == 2
    assert score["n_false_alarms"] == 0
    assert score["n_missed"] == 0
    assert score["max_latency"] == 2
    assert score["all_detected_in_budget"]


def test_pod_verdicts(churned):
    ev, _, _, _ = churned
    res = monitor_stream(ev, DetectorParams(timeout_clocks=2))
    kinds = [(v["kind"], v.get("pod")) for v in res.verdicts
             if "pod" in v]
    assert ("pod_down", 1) in kinds
    assert ("pod_up", 1) in kinds
    assert res.health["suspected_at_end"] == []


def test_outage_windows_and_false_alarm_scoring():
    live = np.ones((20, 4), bool)
    live[5:12, 2] = False
    live[16:, 0] = False                        # open at the horizon
    assert outage_windows(live) == [(0, 16, 20), (2, 5, 12)]

    verdicts = [
        {"kind": "worker_down", "worker": 2, "t": 7, "missed": 2},
        {"kind": "worker_down", "worker": 0, "t": 18, "missed": 2},
        # worker 1 never dies: the silence window holds no dead clock
        {"kind": "worker_down", "worker": 1, "t": 9, "missed": 2},
    ]
    score = score_detections(live, verdicts, budget_clocks=2)
    assert score["n_false_alarms"] == 1
    assert score["false_alarms"][0]["worker"] == 1
    assert score["n_detected"] == 2 and score["n_missed"] == 0
    assert not score["all_detected_in_budget"]  # the false alarm spoils it
    clean = score_detections(live, verdicts[:2], budget_clocks=2)
    assert clean["all_detected_in_budget"]
    assert clean["latencies"] == {"w0@16": 2, "w2@5": 2}


def test_detector_rejects_headless_stream(churned):
    ev, _, _, _ = churned
    with pytest.raises(ValueError, match="run_start"):
        monitor_stream(ev[1:])


def test_live_from_events(churned):
    ev, _, _, sched = churned
    live = live_from_events(ev)
    assert np.array_equal(np.asarray(live), np.asarray(sched.live))


# ----------------------------------------------------------------- SLO


def _gt_windows(trace, bound, window):
    """Trace-side ground truth (mirrors benchmarks.detect_bench)."""
    staleness = np.asarray(trace.staleness)
    live = np.asarray(trace.live)
    p99 = []
    for t in range(staleness.shape[0]):
        stats = obs_events.clock_lag_stats(staleness[t], live[t])
        p99.append(None if stats is None else stats[0])
    out = []
    for w0 in range(0, len(p99), window):
        chunk = [v for v in p99[w0:w0 + window] if v is not None]
        if chunk and max(chunk) > bound:
            out.append(min(w0 + window, len(p99)) - 1)
    return out


@pytest.mark.parametrize("bound", [None, 0])
def test_slo_staleness_matches_trace_ground_truth(churned, bound):
    """Verdicts under the declared contract AND under a deliberately
    tight bound both agree, window for window, with the Trace."""
    ev, tr, cfg, _ = churned
    window = 4
    res = monitor_stream(ev, slo=SLOParams(window=window,
                                           staleness_bound=bound))
    got = [v["t"] for v in res.violations if v["slo"] == "staleness"]
    eff = obs_events.declared_bound(cfg) if bound is None else bound
    assert got == _gt_windows(tr, eff, window)
    if bound == 0:
        assert got, "tight bound must fire (non-vacuous agreement)"


def test_slo_throughput_and_wire_thresholds():
    """Synthetic stream with exact numbers: both monitors trip at their
    configured limits, with window-closing clocks and rounded values."""
    head = {"type": "run_start", "v": 1, "vm": 1, "run": "slo",
            "model": "essp", "family": "f", "n_workers": 2, "n_pods": 1,
            "n_clocks": 4, "ts": 0.0}
    clocks = [{"type": "clock", "t": t, "ts": float(t), "dur": 1.0,
               "loss_ref": 1.0, "forced": 0, "delivered": 0, "live": 2,
               "ship_floats": 100.0 * (t + 1)} for t in range(4)]
    end = {"type": "run_end", "ts": 4.0, "wall_s": 4.0, "comp_s": 2.0,
           "comm_s": 2.0, "wire_s": 0.0, "clocks": 4}
    ev = [head, *clocks, end]
    obs_events.validate_events(ev)

    res = monitor_stream(ev, slo=SLOParams(
        window=2, min_clocks_per_s=2.0, max_floats_per_clock=250.0))
    by_slo = {}
    for v in res.violations:
        by_slo.setdefault(v["slo"], []).append(v)
    # throughput: both windows run at 1 clock/s < 2
    assert [v["t"] for v in by_slo["throughput"]] == [1, 3]
    assert by_slo["throughput"][0]["value"] == 1.0
    assert by_slo["throughput"][0]["limit"] == 2.0
    # wire: only the second window (mean 350 floats/clock) exceeds 250
    assert [v["t"] for v in by_slo["wire"]] == [3]
    assert by_slo["wire"][0]["value"] == 350.0
    assert by_slo["wire"][0]["window"] == 2


def test_slo_violation_splice_and_roundtrip(churned, tmp_path):
    ev, _, _, _ = churned
    res = monitor_stream(ev, slo=SLOParams(window=4, staleness_bound=0))
    assert res.violations
    obs_events.validate_events(res.events)      # spliced stream is valid
    spliced = [e for e in res.events if e["type"] == "slo_violation"]
    assert spliced == res.violations
    # each violation sits directly after its window-closing clock event
    for v in res.violations:
        i = res.events.index(v)
        prior = [e for e in res.events[:i] if e["type"] == "clock"]
        assert prior[-1]["t"] == v["t"]
    path = tmp_path / "spliced.jsonl"
    obs_events.write_jsonl(res.events, path)
    assert obs_events.read_jsonl(path) == res.events


def test_stream_summary_agrees_with_stream(neutral):
    ev, tr, _ = neutral
    s = stream_summary(ev)
    assert s["clocks"] == T
    assert s["loss_final"] == pytest.approx(
        float(np.asarray(tr.loss_ref)[-1]))
    assert s["dead_worker_clocks"] == 0
    assert s["forced_intra"] is not None        # registry rode along
    assert s["wall_s"] == pytest.approx(s["comp_s"] + s["comm_s"])


# --------------------------------------------------------- attribution


def test_diff_streams_ranks_churn(neutral, churned):
    ev0, _, _ = neutral
    ev1, _, _, _ = churned
    d = diff_streams(ev0, ev1)
    assert d["target"] == "wall_s"
    churn = d["components"]["churn"]
    assert churn["indicator"] == "dead_frac"
    assert churn["base"] == 0.0 and churn["cur"] > 0
    assert churn["share"] > 0
    # the wall split is exact: delta wall == delta comp + delta comm
    w = d["wall"]
    assert w["wall_s"]["delta"] == pytest.approx(
        w["comp_s"]["delta"] + w["comm_s"]["delta"], abs=1e-6)
    shares = [c["share"] for c in d["components"].values()]
    assert sum(shares) == pytest.approx(1.0)
    assert explain(d)


def test_run_profile_clocks_to_loss(neutral):
    ev, tr, _ = neutral
    loss = np.asarray(tr.loss_ref)
    thresh = float(loss[T // 2])
    prof = run_profile(ev, loss_thresh=thresh)
    assert prof["clocks_to_loss"] == int(np.argmax(loss <= thresh)) + 1
    assert run_profile(ev)["clocks_to_loss"] is None


def test_diff_bench_flipped_claim_pins_component():
    base = {"bench": "detect",
            "metrics": {"eager/pod_outage/detect_latency_clocks": 2,
                        "eager/worker_churn/max_healthy_phi": 0.2},
            "claim": {"zero_false_alarms_neutral": True}}
    cur = {"bench": "detect",
           "metrics": {"eager/pod_outage/detect_latency_clocks": 5,
                       "eager/worker_churn/max_healthy_phi": 0.2},
           "claim": {"zero_false_alarms_neutral": False}}
    d = diff_bench(base, cur)
    assert d["flipped_claims"] == [("zero_false_alarms_neutral",
                                    "churn")]
    assert d["ranked"][0] == "churn"
    lines = explain(d)
    assert any("flipped" in line for line in lines)
    md = obs_report.attribution_table(d)
    assert "| churn |" in md and "flipped" in md


def test_attribution_table_streams(neutral, churned):
    ev0, _, _ = neutral
    ev1, _, _, _ = churned
    md = obs_report.attribution_table(diff_streams(ev0, ev1))
    assert "## Attribution: neutral -> churned" in md
    assert "### Wall split (exact)" in md
    assert "| churn | dead_frac |" in md


# ------------------------------------------------------ promtext / CLI


def _prom_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter_add("ps/forced_intra", 3)
    reg.counter_add("ps/ship_floats_total", 2.5)
    reg.gauge_set("ps/clocks", 6)
    reg.hist_add("ps/staleness_lag", [4, 2, 0, 1])
    return reg


def test_promtext_golden():
    """Byte-pinned OpenMetrics export.  Regenerate intentionally with
    ``REPRO_REGEN_GOLDEN=1 pytest tests/test_monitor.py -k golden``."""
    got = promtext.render(_prom_registry())
    if os.environ.get("REPRO_REGEN_GOLDEN"):
        os.makedirs(os.path.dirname(PROM_GOLDEN), exist_ok=True)
        with open(PROM_GOLDEN, "w") as f:
            f.write(got)
    with open(PROM_GOLDEN) as f:
        want = f.read()
    assert got == want, "OpenMetrics export drifted from the golden " \
                        "(REPRO_REGEN_GOLDEN=1 to re-pin intentionally)"
    # structural honesty checks on the golden itself
    assert got.endswith("# EOF\n")
    assert "ps_forced_intra_total 3" in got
    # counter family name must not double the _total suffix
    assert "ps_ship_floats_total 2.5" in got
    assert "_total_total" not in got
    assert 'ps_staleness_lag_bucket{le="+Inf"} 7' in got
    assert "ps_staleness_lag_count 7" in got
    assert "ps_staleness_lag_sum 5" in got      # 0*4 + 1*2 + 3*1


def test_promtext_accepts_registry_snapshot():
    reg = _prom_registry()
    assert promtext.render(reg) == promtext.render(reg.to_dict())


def test_promtext_from_drained_device(neutral):
    ev, tr, _ = neutral
    reg = MetricsRegistry()
    drain_device(reg, tr.obs)
    text = promtext.render(reg)
    assert "# TYPE ps_forced_intra counter" in text
    assert "# TYPE ps_staleness_lag histogram" in text
    assert text.endswith("# EOF\n")


@pytest.fixture(scope="module")
def stream_files(neutral, churned, tmp_path_factory):
    d = tmp_path_factory.mktemp("streams")
    paths = {"neutral": d / "neutral.jsonl",
             "churned": d / "churned.jsonl"}
    obs_events.write_jsonl(neutral[0], paths["neutral"])
    obs_events.write_jsonl(churned[0], paths["churned"])
    return paths


def test_cli_validate_tail_report(stream_files, capsys):
    assert obs_cli(["validate", str(stream_files["churned"])]) == 0
    assert "OK" in capsys.readouterr().out
    assert obs_cli(["tail", str(stream_files["churned"]),
                    "--type", "churn"]) == 0
    out = capsys.readouterr().out
    assert out.count("churn") == 4              # 2 downs + 2 ups
    assert obs_cli(["report", str(stream_files["neutral"])]) == 0
    assert "## Staleness" in capsys.readouterr().out


def test_cli_validate_rejects(tmp_path, capsys):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "run_start", "v": 99}\n')
    assert obs_cli(["validate", str(bad)]) == 1
    assert "INVALID" in capsys.readouterr().err


def test_cli_monitor_gates(stream_files, capsys):
    # churned stream, scored against its own churn events: all detected,
    # no false alarms -> exit 0 even with the gate on
    assert obs_cli(["monitor", str(stream_files["churned"]), "--score",
                    "--fail-on-false-alarm", "--budget",
                    str(BUDGET)]) == 0
    out = capsys.readouterr().out
    assert '"all_detected_in_budget": true' in out
    # neutral stream: any alarm fails, none fire -> exit 0
    assert obs_cli(["monitor", str(stream_files["neutral"]),
                    "--fail-on-alarm"]) == 0
    capsys.readouterr()


def test_cli_monitor_false_alarm_exit(stream_files, tmp_path, capsys):
    """Strip the churn events from the churned stream: the detector's
    (correct) verdicts become false alarms against the now-all-live
    oracle, and the CI gate must exit nonzero."""
    ev = obs_events.read_jsonl(stream_files["churned"])
    stripped = [e for e in ev if e["type"] != "churn"]
    path = tmp_path / "stripped.jsonl"
    obs_events.write_jsonl(stripped, path)
    assert obs_cli(["monitor", str(path), "--score",
                    "--fail-on-false-alarm"]) == 1
    capsys.readouterr()


def test_cli_monitor_emits_spliced_stream(stream_files, tmp_path,
                                          capsys):
    out_path = tmp_path / "spliced.jsonl"
    assert obs_cli(["monitor", str(stream_files["churned"]),
                    "--staleness-bound", "0", "--window", "4",
                    "--emit", str(out_path)]) == 0
    capsys.readouterr()
    ev = obs_events.read_jsonl(out_path)
    assert any(e["type"] == "slo_violation" for e in ev)


def test_cli_diff_and_prom(stream_files, capsys):
    assert obs_cli(["diff", str(stream_files["neutral"]),
                    str(stream_files["churned"])]) == 0
    assert "churn" in capsys.readouterr().out
    assert obs_cli(["prom", str(stream_files["neutral"])]) == 0
    out = capsys.readouterr().out
    assert out.endswith("# EOF\n")
    assert "# TYPE ps_forced_intra counter" in out


def test_cli_diff_bench_records(tmp_path, capsys):
    for name, lat, claim in (("base", 2, True), ("cur", 5, False)):
        with open(tmp_path / f"{name}.json", "w") as f:
            json.dump({"bench": "detect",
                       "metrics": {"pod_outage/detect_latency_clocks":
                                   lat},
                       "claim": {"all_outages_detected_in_budget":
                                 claim}}, f)
    assert obs_cli(["diff", str(tmp_path / "base.json"),
                    str(tmp_path / "cur.json"), "--markdown"]) == 0
    out = capsys.readouterr().out
    assert "## Attribution: BENCH_detect" in out
    assert "flipped" in out
