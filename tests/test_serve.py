"""Serving paths: prefill/decode consistency with the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.data.synthetic import modality_stub
from repro.models.registry import build_model
from repro.serve.decode import generate_scan


def _f32_cfg(arch):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    if cfg.moe is not None:
        # avoid capacity dropping so decode matches forward exactly
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


# Heavy archs decode in the scheduled lane only; the per-push lane keeps
# small dense + SSD representatives (same split as test_models_smoke.py's
# _HEAVY_SMOKE).
_HEAVY_SERVE = {"jamba-1.5-large-398b", "llama-3.2-vision-11b",
                "whisper-medium", "deepseek-v2-lite-16b",
                "qwen3-moe-30b-a3b", "qwen3-4b"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SERVE
             else a for a in ARCHS])
def test_prefill_then_decode_matches_forward(arch):
    cfg = _f32_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    extra = modality_stub(cfg, B, jnp.float32)
    cache = model.init_cache(B, 32, jnp.float32)
    lg_pre, cache = jax.jit(model.prefill)(params,
                                           {"tokens": toks, **extra}, cache)
    lg_full, _ = jax.jit(model.forward)(params, {"tokens": toks, **extra})
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_full[:, -1:]),
                               atol=1e-3)

    nxt = jnp.argmax(lg_pre[:, -1], -1)
    lg_dec, cache = jax.jit(model.decode_step)(
        params, {"tokens": nxt[:, None]}, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    lg_full2, _ = jax.jit(model.forward)(params, {"tokens": toks2, **extra})
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(lg_full2[:, -1:]), atol=5e-3)


@pytest.mark.slow
def test_multi_step_decode_consistency():
    """Five decode steps stay consistent with the growing-context forward."""
    cfg = _f32_cfg("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    cache = model.init_cache(B, S + 6, jnp.float32)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    decode = jax.jit(model.decode_step)
    cur = toks
    for _ in range(5):
        nxt = jnp.argmax(logits[:, -1], -1)
        logits, cache = decode(params, {"tokens": nxt[:, None]}, cache)
        cur = jnp.concatenate([cur, nxt[:, None]], 1)
        full, _ = jax.jit(model.forward)(params, {"tokens": cur})
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1:]), atol=5e-3)


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer cache with window < context equals windowed attention."""
    cfg = _f32_cfg("llama3-8b")
    cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=8))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 1, 12                     # context longer than the window
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    cache = model.init_cache(B, 64, jnp.float32)  # cache C = window = 8
    assert jax.tree.leaves(cache)[0].shape[2] == 8
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    full, _ = jax.jit(model.forward)(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1:]),
                               atol=1e-3)
    # one decode step past the window boundary
    nxt = jnp.argmax(logits[:, -1], -1)
    lg_dec, cache = jax.jit(model.decode_step)(params,
                                               {"tokens": nxt[:, None]}, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    full2, _ = jax.jit(model.forward)(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full2[:, -1:]),
                               atol=1e-3)


def test_generate_scan_shapes():
    cfg = _f32_cfg("mamba2-130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                              cfg.vocab_size)
    out = generate_scan(model, params, toks, max_new=6)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
