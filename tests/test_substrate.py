"""Params system, data pipeline, checkpointing, losses, HLO analyzer,
time model — the remaining substrate."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.io import restore, save
from repro.core import essp, ssp, simulate
from repro.core.timemodel import TimeModel
from repro.data.synthetic import TokenGenConfig, token_batch, token_batches
from repro.models.params import (ParamSpec, init_params, param_count,
                                 shape_structs, spec)
from repro.train.losses import shift_labels, softmax_xent
from repro.utils.hlo import analyze, count_op, shape_bytes
from repro.utils.tree import tree_bytes, tree_norm, tree_size


# ---------------- params ---------------------------------------------------
def test_param_spec_validation():
    with pytest.raises(ValueError, match="rank mismatch"):
        ParamSpec((2, 3), ("a",))


def test_init_deterministic_and_counts():
    specs = {"layer": {"w": spec((8, 16), ("embed", "mlp")),
                       "b": spec((16,), ("mlp",), init="zeros")},
             "emb": spec((32, 8), ("vocab", "embed"), init="embed")}
    p1 = init_params(specs, jax.random.PRNGKey(0))
    p2 = init_params(specs, jax.random.PRNGKey(0))
    p3 = init_params(specs, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(p1["layer"]["w"]),
                                  np.asarray(p2["layer"]["w"]))
    assert float(jnp.abs(p1["layer"]["w"] - p3["layer"]["w"]).max()) > 0
    assert float(jnp.abs(p1["layer"]["b"]).max()) == 0
    assert param_count(specs) == 8 * 16 + 16 + 32 * 8
    structs = shape_structs(specs)
    assert structs["emb"].shape == (32, 8)


def test_fan_in_scaling():
    specs = {"w": spec((1024, 64), ("embed", "mlp"))}
    p = init_params(specs, jax.random.PRNGKey(0))
    std = float(jnp.std(p["w"]))
    assert 0.5 / np.sqrt(1024) < std < 1.5 / np.sqrt(1024)


# ---------------- data -----------------------------------------------------
def test_token_batch_deterministic_and_learnable():
    cfg = TokenGenConfig(vocab_size=512, seq_len=64, batch=4)
    b1 = token_batch(cfg, 3)
    b2 = token_batch(cfg, 3)
    b3 = token_batch(cfg, 4)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert float(jnp.abs(b1 - b3).sum()) > 0
    assert b1.shape == (4, 64)
    assert b1.dtype == jnp.int32
    assert int(b1.max()) < 256  # v_eff slice
    # affine rule: consecutive-token pairs repeat within a sequence
    seq = np.asarray(b1[0])
    pairs = {}
    consistent = 0
    for a, b in zip(seq[:-1], seq[1:], strict=True):
        if a in pairs and pairs[a] == b:
            consistent += 1
        pairs[a] = b
    assert consistent > 5   # structure present despite 5% noise


def test_token_batches_iterator():
    cfg = TokenGenConfig(vocab_size=128, seq_len=16, batch=2)
    batches = list(token_batches(cfg, 3, extra={"flag": 1}))
    assert len(batches) == 3
    assert batches[0]["flag"] == 1


# ---------------- checkpoint ------------------------------------------------
def test_checkpoint_roundtrip():
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3),
                  "n": jnp.arange(4, dtype=jnp.int32)},
            "b": [jnp.ones((2,), jnp.bfloat16) * 1.5]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, tree)
        back = restore(path, jax.tree.map(lambda x: x, tree))
    np.testing.assert_array_equal(np.asarray(back["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    assert back["b"][0].dtype == jnp.bfloat16
    assert float(back["b"][0][0]) == 1.5


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.npz")
        save(path, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError, match="shape"):
            restore(path, {"w": jnp.zeros((3, 3))})


# ---------------- losses ----------------------------------------------------
def test_softmax_xent_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 7))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 7)
    got = softmax_xent(logits, labels, z_loss=0.0)
    probs = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(probs, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_shift_labels():
    t = jnp.array([[1, 2, 3]])
    np.testing.assert_array_equal(np.asarray(shift_labels(t)),
                                  [[2, 3, 0]])


# ---------------- hlo analyzer ----------------------------------------------
def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[128]") == 256
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("token[]") == 0


def test_analyzer_counts_scan_multiplicity():
    def f(n):
        def step(x, _):
            return x @ x, None
        def run(x):
            y, _ = jax.lax.scan(step, x, None, length=n)
            return y.sum()
        return run

    flops = {}
    for n in (2, 8):
        c = jax.jit(f(n)).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        flops[n] = analyze(c.as_text()).flops
    assert flops[2] == pytest.approx(2 * 2 * 64**3)
    assert flops[8] == pytest.approx(8 * 2 * 64**3)


def test_count_op():
    c = jax.jit(lambda x: (x @ x) @ x).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    assert count_op(c.as_text(), "dot") == 2


# ---------------- time model -------------------------------------------------
def test_essp_smaller_comm_share_than_ssp(quad_app):
    tm = TimeModel()
    tr_ssp = jax.jit(lambda: simulate(quad_app, ssp(4), 60))()
    tr_essp = jax.jit(lambda: simulate(quad_app, essp(4), 60))()
    b_ssp = tm.breakdown(tr_ssp, "ssp")
    b_essp = tm.breakdown(tr_essp, "essp")
    assert b_essp["comm_frac"] < b_ssp["comm_frac"]
    assert b_essp["total_s"] < b_ssp["total_s"]


# ---------------- tree utils --------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 5), m=st.integers(1, 5))
def test_tree_utils(n, m):
    tree = {"a": jnp.ones((n, m)), "b": [jnp.zeros((m,))]}
    assert tree_size(tree) == n * m + m
    assert tree_bytes(tree) == 4 * (n * m + m)
    assert float(tree_norm(tree)) == pytest.approx(np.sqrt(n * m))


def test_analyzer_scatter_charges_update_not_table():
    """KV-cache style .at[].set must be charged the update, not the table
    (with donation — as in the serve path — the defensive copy is elided
    and only the written region counts)."""
    def f(t, upd):
        return t.at[jnp.array([3])].set(upd)

    c = jax.jit(f, donate_argnums=0).lower(
        jax.ShapeDtypeStruct((1024, 256), jnp.float32),
        jax.ShapeDtypeStruct((1, 256), jnp.float32)).compile()
    st = analyze(c.as_text())
    # full-table charging would be ~2MB; update-charging is ~2KB
    assert st.bytes_accessed < 64 * 1024, st.bytes_accessed
