"""Multi-pod dry-run machinery (subprocess: needs 512 fake devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(code: str, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_mesh_construction_and_dryrun_decode():
    """End-to-end: 512 fake devices, both meshes build, and one cheap
    (arch x shape) pair lowers + compiles on each mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, json
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(m1.shape) == {"data": 16, "model": 16}
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
assert len(jax.devices()) == 512

from repro.launch.dryrun import lower_one
r1 = lower_one("qwen3-0.6b", "decode_32k", save=False)
r2 = lower_one("qwen3-0.6b", "decode_32k", multi_pod=True, save=False)
print(json.dumps({"single": r1["flops_per_device"],
                  "multi": r2["flops_per_device"],
                  "mem_single": r1["memory"]["total_bytes"],
                  "mem_multi": r2["memory"]["total_bytes"],
                  "chips": [r1["chips"], r2["chips"]]}))
"""
    res = _run(code)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["chips"] == [256, 512]
    assert out["single"] > 0
    # multi-pod shards the work further: per-device flops must not grow
    assert out["multi"] <= out["single"] * 1.1


def test_dryrun_train_step_lowering():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_one
r = lower_one("mamba2-130m", "train_4k", save=False)
print(json.dumps({"flops": r["flops_per_device"],
                  "coll": r["collectives"]["total_bytes"],
                  "mem": r["memory"]["total_bytes"]}))
"""
    res = _run(code)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["flops"] > 1e9
    assert out["coll"] > 0          # gradient all-reduces must appear
    assert out["mem"] < 16 * 2**30  # 130M model fits v5e easily


def test_essp_schedule_changes_collective_count():
    """ESSP bucketing appears in the compiled collective schedule."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_one
r_bsp = lower_one("qwen3-0.6b", "train_4k", sync_mode="bsp", save=False)
r_essp = lower_one("qwen3-0.6b", "train_4k", sync_mode="essp",
                   staleness=0, n_buckets=8, save=False)
print(json.dumps({"bsp": r_bsp["collectives"]["total_count"],
                  "essp": r_essp["collectives"]["total_count"],
                  "bsp_bytes": r_bsp["collectives"]["total_bytes"],
                  "essp_bytes": r_essp["collectives"]["total_bytes"]}))
"""
    res = _run(code)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # same payload (within tolerance), different schedule granularity
    assert out["essp_bytes"] == pytest.approx(out["bsp_bytes"], rel=0.25)


def test_ssp_fifo_in_train_state():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_one
r = lower_one("qwen3-0.6b", "train_4k", sync_mode="ssp", staleness=2,
              save=False)
print(json.dumps({"mem": r["memory"]["total_bytes"]}))
"""
    res = _run(code)
    assert res.returncode == 0, res.stderr[-3000:]
