"""Per-assigned-architecture smoke tests (reduced configs, CPU):
forward shapes + no NaNs + one train step (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data.synthetic import modality_stub
from repro.models.registry import build_model
from repro.optim.optimizers import adamw
from repro.psdist.grad_sync import GradSync
from repro.train.state import init_state, make_train_step


# Heavy smoke configs go to the scheduled (full) CI lane; the per-push lane
# keeps small dense + SSD representatives for coverage (same split as
# test_serve.py's _HEAVY_SERVE).
_HEAVY_SMOKE = {"jamba-1.5-large-398b", "llama-3.2-vision-11b",
                "whisper-medium", "deepseek-v2-lite-16b",
                "qwen3-moe-30b-a3b", "qwen3-4b"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE
             else a for a in ARCHS])
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 10
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, **modality_stub(cfg, B)}

    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = adamw(1e-3)
    sync = GradSync("bsp")
    state = init_state(model, opt, sync, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(model, opt, sync))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    state, m2 = step(state, batch)
    assert float(m2["loss"]) < float(metrics["loss"]) + 1.0  # sane scale


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "whisper-medium": dict(n_layers=24, d_model=1024, vocab_size=51865),
        "qwen3-4b": dict(n_layers=36, d_model=2560, d_ff=9728,
                         vocab_size=151936),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048,
                                     vocab_size=102400),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, d_ff=24576,
                                     vocab_size=65536),
        "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, d_ff=14336,
                                     vocab_size=128256),
        "stablelm-3b": dict(n_layers=32, d_model=2560, d_ff=6912,
                            vocab_size=50304),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab_size=50280),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048,
                                  vocab_size=151936),
        "llama3-8b": dict(n_layers=32, d_model=4096, d_ff=14336,
                          vocab_size=128256),
        "qwen3-0.6b": dict(n_layers=28, d_model=1024, d_ff=3072,
                           vocab_size=151936),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source


def test_assigned_attention_settings():
    c = get_config("qwen3-4b")
    assert c.attn.n_heads == 32
    assert c.attn.n_kv_heads == 8
    assert c.attn.qk_norm
    c = get_config("deepseek-v2-lite-16b")
    assert c.attn.mla is not None
    assert c.attn.mla.kv_lora_rank == 512
    assert c.moe.n_experts == 64
    assert c.moe.top_k == 6
    assert c.moe.n_shared == 2
    c = get_config("jamba-1.5-large-398b")
    assert c.attn_every == 8
    assert c.moe.n_experts == 16
    assert c.moe.top_k == 2
    c = get_config("qwen3-moe-30b-a3b")
    assert c.moe.n_experts == 128
    assert c.moe.top_k == 8
    c = get_config("mamba2-130m")
    assert c.attn is None
    assert c.mamba.d_state == 128
    c = get_config("llama-3.2-vision-11b")
    assert c.vision.cross_attn_every == 5
    c = get_config("whisper-medium")
    assert c.encoder.n_layers == 24
    assert c.encoder.n_ctx == 1500


def test_param_counts_match_scale():
    """Full-config parameter counts land near the advertised sizes."""
    expect = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "llama3-8b": (7e9, 9e9),
        "qwen3-4b": (3.3e9, 5e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "llama-3.2-vision-11b": (9e9, 12e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "stablelm-3b": (2.5e9, 4e9),
        "whisper-medium": (0.6e9, 0.95e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).n_params
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
