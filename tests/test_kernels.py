"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per instructions: sweep shapes/dtypes and assert_allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mf_sgd import mf_sgd_block
from repro.kernels.ssd_scan import ssd
from repro.kernels import ops


def _attn_inputs(B, Sq, Sk, H, Hkv, Dk, Dv, dtype, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (B, Sq, H, Dk), dtype)
    k = jax.random.normal(kk, (B, Sk, Hkv, Dk), dtype)
    v = jax.random.normal(kv, (B, Sk, Hkv, Dv), dtype)
    qp = jnp.broadcast_to(jnp.arange(Sk - Sq, Sk), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    return q, k, v, qp, kp


ATTN_CASES = [
    # B, Sq, Sk, H, Hkv, Dk, Dv, causal, window, dtype
    (2, 128, 128, 4, 2, 32, 32, True, None, jnp.float32),
    (1, 200, 200, 8, 8, 64, 64, True, None, jnp.float32),
    (2, 64, 256, 4, 1, 32, 16, True, None, jnp.float32),   # MQA, Dv != Dk
    (2, 128, 128, 4, 2, 32, 32, True, 48, jnp.float32),    # sliding window
    (2, 128, 128, 4, 2, 32, 32, False, None, jnp.float32),
    (2, 128, 128, 8, 4, 64, 64, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_dense(case):
    B, Sq, Sk, H, Hkv, Dk, Dv, causal, window, dtype = case
    q, k, v, qp, kp = _attn_inputs(B, Sq, Sk, H, Hkv, Dk, Dv, dtype)
    scale = 1.0 / np.sqrt(Dk)
    want = ref.attention_dense(q, k, v, scale=scale, q_pos=qp, kv_pos=kp,
                               causal=causal, window=window)
    got = flash_attention(q, k, v, scale=scale, q_pos=qp, kv_pos=kp,
                          causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    tol = 6e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_blocked_ref_matches_dense():
    """The production (CPU) blocked path equals the quadratic oracle."""
    q, k, v, qp, kp = _attn_inputs(2, 96, 96, 4, 2, 32, 32, jnp.float32)
    want = ref.attention_dense(q, k, v, scale=0.18, q_pos=qp, kv_pos=kp)
    got = ref.attention(q, k, v, scale=0.18, q_pos=qp, kv_pos=kp,
                        kv_chunk=32, q_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([32, 64, 96]), sk=st.sampled_from([64, 128]),
       hkv=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2]),
       causal=st.booleans(), seed=st.integers(0, 3))
def test_flash_attention_hypothesis(sq, sk, hkv, rep, causal, seed):
    if sq > sk:
        sq = sk
    q, k, v, qp, kp = _attn_inputs(1, sq, sk, hkv * rep, hkv, 32, 32,
                                   jnp.float32, seed)
    want = ref.attention_dense(q, k, v, scale=0.2, q_pos=qp, kv_pos=kp,
                               causal=causal)
    got = flash_attention(q, k, v, scale=0.2, q_pos=qp, kv_pos=kp,
                          causal=causal, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


SSD_CASES = [
    (2, 128, 4, 32, 2, 32, 32, jnp.float32),
    (1, 256, 8, 64, 1, 64, 64, jnp.float32),
    (2, 128, 4, 32, 4, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_ref(case):
    b, s, h, p, g, n, chunk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n), dtype)
    C = jax.random.normal(ks[4], (b, s, g, n), dtype)
    yw, stw = ref.ssd_chunked(x, dt, A, B, C, chunk)
    yg, stg = ssd(x, dt, A, B, C, chunk=chunk, interpret=True)
    yw, yg = np.asarray(yw, np.float32), np.asarray(yg, np.float32)
    scale = max(1.0, np.abs(yw).max())
    rtol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    assert np.abs(yw - yg).max() / scale < rtol
    np.testing.assert_allclose(np.asarray(stg), np.asarray(stw),
                               atol=scale * rtol)


def test_ssd_ref_matches_naive_recurrence():
    """The chunked dual form equals the exact token-by-token recurrence."""
    b, s, h, p, g, n, chunk = 1, 64, 2, 16, 1, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))

    y_chunk, st_chunk = ref.ssd_chunked(x, dt, A, B, C, chunk)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ref.ssd_recurrent(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                      state)
        ys.append(yt)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(state),
                               atol=2e-4)


@pytest.mark.parametrize(("N", "M", "K"), [(256, 256, 16), (128, 384, 32),
                                   (128, 128, 8)])
def test_mf_sgd_kernel_matches_ref(N, M, K):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    L = jax.random.normal(ks[0], (N, K))
    R = jax.random.normal(ks[1], (K, M))
    D = jax.random.normal(ks[2], (N, M))
    mask = jax.random.bernoulli(ks[3], 0.3, (N, M))
    dLw, dRw, lw = ref.mf_sgd_block(L, R, D, mask, 0.1, 1e-3)
    dLg, dRg, lg = mf_sgd_block(L, R, D, mask, 0.1, 1e-3, interpret=True)
    np.testing.assert_allclose(np.asarray(dLg), np.asarray(dLw), atol=1e-3)
    np.testing.assert_allclose(np.asarray(dRg), np.asarray(dRw), atol=1e-3)
    assert abs(float(lw - lg)) < 1e-3


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(nb=st.sampled_from([1, 2]), mb=st.sampled_from([1, 3]),
       k=st.sampled_from([8, 16]), density=st.floats(0.05, 0.9),
       seed=st.integers(0, 2))
def test_mf_sgd_hypothesis(nb, mb, k, density, seed):
    N, M = 128 * nb, 128 * mb
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    L = jax.random.normal(ks[0], (N, k))
    R = jax.random.normal(ks[1], (k, M))
    D = jax.random.normal(ks[2], (N, M))
    mask = jax.random.bernoulli(ks[3], density, (N, M))
    dLw, dRw, lw = ref.mf_sgd_block(L, R, D, mask, 0.05, 1e-4)
    dLg, dRg, lg = mf_sgd_block(L, R, D, mask, 0.05, 1e-4, interpret=True)
    np.testing.assert_allclose(np.asarray(dLg), np.asarray(dLw), atol=1e-3)
    np.testing.assert_allclose(np.asarray(dRg), np.asarray(dRw), atol=1e-3)


def test_ops_backend_dispatch():
    ops.set_backend("ref")
    try:
        q, k, v, qp, kp = _attn_inputs(1, 32, 32, 2, 2, 16, 16, jnp.float32)
        out = ops.attention(q, k, v, scale=0.25, q_pos=qp, kv_pos=kp)
        assert out.shape == (1, 32, 2, 16)
        ops.set_backend("pallas_interpret")
        out2 = ops.attention(q, k, v, scale=0.25, q_pos=qp, kv_pos=kp)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                                   atol=3e-5)
    finally:
        ops.set_backend("auto")


def test_static_causal_prefix_matches_dense():
    """§Perf static-causal path: identical numerics, fewer KV blocks."""
    q, k, v, qp, kp = _attn_inputs(2, 96, 96, 4, 2, 32, 32, jnp.float32)
    for win in (None, 24):
        want = ref.attention_dense(q, k, v, scale=0.2, q_pos=qp, kv_pos=kp,
                                   window=win)
        got = ref.attention(q, k, v, scale=0.2, q_pos=qp, kv_pos=kp,
                            window=win, kv_chunk=16, q_chunk=32,
                            assume_prefix=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5)


def test_static_causal_flag_dispatch():
    from repro.kernels import ops as _ops
    q, k, v, qp, kp = _attn_inputs(1, 64, 64, 2, 2, 16, 16, jnp.float32)
    base = _ops.attention(q, k, v, scale=0.25, q_pos=qp, kv_pos=kp,
                          q_chunk=32, kv_chunk=32)
    _ops.set_flag("static_causal", True)
    try:
        opt = _ops.attention(q, k, v, scale=0.25, q_pos=qp, kv_pos=kp,
                             q_chunk=32, kv_chunk=32)
    finally:
        _ops.set_flag("static_causal", False)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), atol=3e-5)


def test_flash_attention_decode_ring_buffer_layout():
    """Serving path on TPU: single-token decode against a ring-buffer KV
    cache.  Slot validity/window are encoded in kv_pos (-1 = empty slot);
    the flash kernel must match the dense decode reference exactly."""
    B, C, H, Hkv, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, C, Hkv, D))
    v = jax.random.normal(ks[2], (B, C, Hkv, D))
    pos = jnp.array([37, 80])                       # wrapped for sample 1
    # ring-buffer slot positions (as computed by gqa_decode)
    slots = jnp.arange(C)[None, :]
    wraps = (pos[:, None] - slots + C) // C
    slot_pos = slots + wraps * C - C
    slot_pos = jnp.where(slot_pos == pos[:, None], pos[:, None], slot_pos)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    kv_pos = jnp.where(valid, slot_pos, -1)
    qp = pos[:, None]

    want = ref.attention_dense(q, k, v, scale=0.18, q_pos=qp, kv_pos=kv_pos,
                               causal=True)
    got = flash_attention(q, k, v, scale=0.18, q_pos=qp, kv_pos=kv_pos,
                          causal=True, block_q=8, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
    # and with a sliding window shorter than the filled cache
    want_w = ref.attention_dense(q, k, v, scale=0.18, q_pos=qp,
                                 kv_pos=kv_pos, causal=True, window=24)
    got_w = flash_attention(q, k, v, scale=0.18, q_pos=qp, kv_pos=kv_pos,
                            causal=True, window=24, block_q=8, block_k=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               atol=3e-5)
