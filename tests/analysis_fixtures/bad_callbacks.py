"""Fixture: host callbacks landed inside traced contexts."""
import jax
import jax.numpy as jnp
from jax.experimental import io_callback


@jax.jit
def step(x):
    jax.debug.print("x = {}", x)  # VIOLATION: host-callback
    io_callback(lambda v: v, jax.ShapeDtypeStruct((), x.dtype), x)  # VIOLATION: host-callback
    return x * 2


def body(carry, t):
    jax.debug.callback(lambda v: None, carry)  # VIOLATION: host-callback
    probe = jax.pure_callback(  # VIOLATION: host-callback
        lambda v: v, jax.ShapeDtypeStruct((), jnp.float32.dtype), carry)
    return carry + t + probe, t


def run(xs):
    return jax.lax.scan(body, 0.0, xs)
