"""Fixture: PRNG-key reuse — the decode/correlation bug shapes."""
import jax
import jax.numpy as jnp


def double_sample(rng):
    a = jax.random.normal(rng, (4,))
    b = jax.random.uniform(rng, (4,))  # VIOLATION: rng-reuse
    return a + b


def split_after_use(rng):
    tok = jax.random.categorical(rng, jnp.zeros((2, 8)))
    keys = jax.random.split(rng, 4)  # VIOLATION: rng-reuse
    return tok, keys


def loop_reuse(rng, n):
    out = 0.0
    for _ in range(n):
        out = out + jax.random.normal(rng, ())  # VIOLATION: rng-reuse
    return out
