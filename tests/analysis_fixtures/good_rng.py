"""Fixture: disciplined key handling — no findings."""
import jax


def double_sample(rng):
    k_a, k_b = jax.random.split(rng)
    a = jax.random.normal(k_a, (4,))
    b = jax.random.uniform(k_b, (4,))
    return a + b


def per_step_streams(rng, n):
    out = 0.0
    for i in range(n):
        k = jax.random.fold_in(rng, i)
        out = out + jax.random.normal(k, ())
    return out


def loop_over_split(rng, n):
    out = 0.0
    for k in jax.random.split(rng, n):
        out = out + jax.random.normal(k, ())
    return out


def branch_separated(rng, kind):
    if kind == "a":
        return jax.random.normal(rng, ())
    return jax.random.uniform(rng, ())
