"""Fixture: clean collective usage — no findings."""
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map


def body(u, x):
    live_now, died = churn_live(schedule, c)  # noqa: F821 (fixture shape)
    u = jnp.where(live_now[:, None], u, 0.0)     # mask BEFORE the gather
    total = jax.lax.psum(x, "model")
    u_all = jax.lax.all_gather(u, "data", axis=0, tiled=True)
    return total, u_all


run = shard_map(body, mesh=None, in_specs=None, out_specs=None)


def generic(x, axis_names):
    # dynamic axis binding (psdist.grad_sync idiom): not refutable
    return jax.lax.pmean(x, axis_names)
