"""Fixture: recompile hazards — traced knobs hit Python control flow.

Lines tagged ``# VIOLATION: <rule-id>`` are asserted caught (exact rule
and line) by tests/test_analysis.py.
"""
import jax
import jax.numpy as jnp  # noqa: F401


def make_step(cfg):
    def step(cfg, carry, c):
        if cfg.staleness > 0:  # VIOLATION: traced-branch
            carry = carry + 1
        w = int(cfg.agg_clocks)  # VIOLATION: traced-coerce
        return carry + w * c

    return step


g = jax.jit(lambda cfg, x: x * cfg.v0, static_argnames="push_prob")  # VIOLATION: traced-static-arg


wrapped = jax.jit(lambda a, b: a + b, static_argnums=(1,))


def call_site(cfg, x):
    return wrapped(x, cfg.topk_frac)  # VIOLATION: traced-static-arg
