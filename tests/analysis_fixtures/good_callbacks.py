"""Fixture: telemetry accumulated on device; host callbacks stay host-side."""
import jax
import jax.numpy as jnp


def device_update(acc, lag):
    # the sanctioned route: accumulate in the carry, drain after the run
    return {"lag_max": jnp.maximum(acc["lag_max"], lag.max())}


@jax.jit
def step(acc, x):
    lag = jnp.abs(x)
    acc = device_update(acc, lag)
    jax.debug.print("lag={}", lag)  # analysis: ignore[host-callback] -- one-off kernel debugging probe
    return acc, x * 2


def report(acc):
    # host side, never traced: printing here is fine
    jax.debug.print("final lag_max = {}", acc["lag_max"])
    print("report done")
