"""Fixture: clean registered-pytree usage — no findings."""
import dataclasses
from dataclasses import dataclass

import jax


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FrozenState:
    clock: jax.Array
    base: jax.Array


def advance(state: FrozenState):
    return dataclasses.replace(state, clock=state.clock + 1)


@dataclass
class PlainConfig:
    # not a registered pytree: plain mutable dataclasses are fine
    name: str = "x"
