"""Fixture: the clean counterparts of bad_recompile — no findings."""
import jax
import jax.numpy as jnp


def make_step(cfg):
    def step(cfg, carry, c):
        # traced select instead of a Python branch
        carry = jnp.where(cfg.staleness > 0, carry + 1, carry)
        # static META knobs may branch freely (per-family specialization)
        if cfg.model == "bsp":
            carry = carry * 2
        w = jnp.asarray(cfg.agg_clocks)
        return carry + w * c

    return step


# static_argnames on genuinely static structure is fine
h = jax.jit(lambda cfg, n: jnp.zeros(n) + cfg.v0, static_argnames="n")
