"""Fixture: axis hygiene + the masked-before-all-gather churn rule."""
import jax
from jax.experimental.shard_map import shard_map


def body(u, x):
    live_now, died = churn_live(schedule, c)  # noqa: F821 (fixture shape)
    total = jax.lax.psum(x, "rows")  # VIOLATION: axis-unbound
    u_all = jax.lax.all_gather(u, "data", axis=0, tiled=True)  # VIOLATION: unmasked-gather
    return total, u_all


run = shard_map(body, mesh=None, in_specs=None, out_specs=None)


def stray(x):
    return jax.lax.pmax(x, "model")  # VIOLATION: collective-outside-shardmap
