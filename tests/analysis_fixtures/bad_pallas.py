"""Fixture: Pallas hygiene violations."""
import jax
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def doubled(x):
    W, P = x.shape
    return pl.pallas_call(  # VIOLATION: pallas-ref
        kernel,
        grid=(P,),
        in_specs=[pl.BlockSpec((W, 1), lambda i, j: (0, i))],  # VIOLATION: pallas-blockspec
        out_specs=pl.BlockSpec((W,), lambda i: (0, i)),  # VIOLATION: pallas-blockspec
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,  # VIOLATION: pallas-interpret
    )(x)
