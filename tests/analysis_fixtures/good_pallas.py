"""Fixture: clean Pallas usage — module-local jnp reference, threaded
interpret flag, coherent BlockSpecs."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def doubled_ref(x):
    return x * 2.0


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def doubled(x, interpret: bool = False):
    W, P = x.shape
    return pl.pallas_call(
        kernel,
        grid=(P,),
        in_specs=[pl.BlockSpec((W, 1), lambda i: (0, i))],
        out_specs=pl.BlockSpec((W, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
