"""Fixture: registered-pytree contract violations."""
from dataclasses import dataclass

import jax


@jax.tree_util.register_dataclass
@dataclass
class MutableState:  # VIOLATION: pytree-frozen
    clock: jax.Array
    base: jax.Array


def advance(state: MutableState):
    state.clock = state.clock + 1  # VIOLATION: pytree-mutation
    return state
